//! End-to-end PTQ driver (the repo's E2E validation workload):
//!
//!   1. load the pretrained FP model + the synthetic corpus,
//!   2. run the full AQuant pipeline — activation-scale search, block-wise
//!      reconstruction with the adaptive rounding border (Algorithm 1),
//!      all schedules driven by the Rust coordinator over AOT-compiled
//!      JAX step programs,
//!   3. evaluate FP vs nearest vs AQuant at W2A2 on the test split,
//!   4. print the per-block loss trajectory and the accuracy comparison.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//!   cargo run --release --offline --example ptq_pipeline -- [model] [iters]

use anyhow::Result;

use aquant::config::{Bits, Method, RunConfig};
use aquant::coordinator::chain::QuantCtx;
use aquant::coordinator::state::Knobs;
use aquant::coordinator::Calibrator;
use aquant::eval::eval_quant_accuracy_limited;
use aquant::exp::cell::Ctx;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "mobiles".into());
    let iters: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);
    let bits = Bits::parse("W2A2")?;
    let eval_n = 512;

    let ctx = Ctx::new("artifacts", Some(iters))?;
    println!("== AQuant end-to-end PTQ: {model} @ {} ==", bits.name());
    let fp = ctx.fp_accuracy(&model)?;
    println!("FP baseline: {:.2}%", fp * 100.0);

    let nearest = ctx.run_cell(&model, Method::Nearest, bits)?;
    println!("nearest {}: {:.2}%", bits.name(), nearest * 100.0);

    // Run the calibration explicitly (not via cache) to show the loop.
    let mut cfg = RunConfig::new(&model, Method::AQuant, bits);
    cfg.calib.iters = iters;
    let chain = ctx.chain(&model)?;
    let calibrator = Calibrator::new(chain, cfg.clone());
    let t0 = std::time::Instant::now();
    let (st, reports) = calibrator.run(&ctx.dataset.calib)?;
    println!(
        "calibrated {} units x {iters} iters in {:.1}s:",
        reports.len(),
        t0.elapsed().as_secs_f64()
    );
    for r in &reports {
        println!(
            "  {:<28} loss {:.5} -> {:.5}",
            r.unit, r.first_loss, r.last_loss
        );
    }

    let chain = ctx.chain(&model)?;
    let q = QuantCtx {
        state: &st,
        bits,
        knobs: Knobs::inference(Method::AQuant, bits),
    };
    let aquant = eval_quant_accuracy_limited(&chain, &ctx.dataset.test, &q, eval_n)?;
    println!("\n{:<22} {:>8}", "config", "top-1");
    println!("{:<22} {:>7.2}%", "FP", fp * 100.0);
    println!(
        "{:<22} {:>7.2}%",
        format!("nearest {}", bits.name()),
        nearest * 100.0
    );
    println!(
        "{:<22} {:>7.2}%",
        format!("AQuant {}", bits.name()),
        aquant * 100.0
    );
    println!(
        "\nAQuant recovers {:+.2} points over nearest rounding.",
        (aquant - nearest) * 100.0
    );
    Ok(())
}
