//! Serving example: several quantized models behind ONE dynamic-batching
//! TCP server and one shared worker pool (pure-Rust engines — no Python,
//! no PJRT on the request path), with a multi-client load generator that
//! routes per-model traffic over protocol v2 (plus a v1 client hitting
//! the default model), checks every answer bit-for-bit against the
//! sequential engine, and reports latency, throughput, and the server's
//! per-model batching stats.
//!
//!   cargo run --release --offline --example serve -- \
//!       [specs] [batch] [n_req] [clients] [workers] [max_batch] [wait_us]
//!
//! `specs` is a comma-separated model-spec list (see `aquant help`):
//! synthetic specs (`synth:tiny`, `b=synth:bench:7`, ...) run anywhere;
//! manifest specs need artifacts — quantized ones (`mobiles:nearest:W4A4`)
//! additionally need a build with `--features pjrt`, while full-precision
//! `MODEL:nearest:W32A32` works in every build. Each spec may carry a
//! per-model serving-policy tail, e.g.
//! `'a=synth:tiny;weight=3,b=synth:bench;max_batch=8'` (quote it —
//! `;` is a shell separator) — weights set each model's fair share of
//! pool admission (weighted deficit-round-robin), the other keys
//! override the global batching knobs per model.
//!
//! Defaults: "a=synth:tiny,b=synth:bench", 32-image requests,
//! 8 requests x 4 clients, auto workers, max-batch 64, 200us batch wait.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use aquant::config::{Bits, Method, ModelSpec, ServeConfig};
use aquant::nn::engine::Engine;
use aquant::server::{classify_on, classify_on_v2, Server};
use aquant::util::rng::Rng;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let spec_str = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| "a=synth:tiny,b=synth:bench".into());
    let spec_list: Vec<String> = spec_str.split(',').map(str::to_string).collect();
    let specs = ModelSpec::parse_all(
        &spec_list,
        Some(Method::Nearest),
        Some(Bits::parse("W4A4")?),
    )?;
    let arg_n = |i: usize, d: usize| args.get(i).and_then(|s| s.parse().ok()).unwrap_or(d);
    let batch = arg_n(2, 32).clamp(1, aquant::server::MAX_REQ_IMAGES);
    let n_req = arg_n(3, 8).max(1);
    let clients = arg_n(4, 4).max(1);
    let cfg = ServeConfig {
        workers: arg_n(5, 0),
        max_batch: arg_n(6, 64),
        batch_wait_us: arg_n(7, 200) as u64,
        // bounded run: the event loop accepts one connection per client
        // thread plus a final nudge connection (opened after the live
        // stats scrape below), then drains and returns
        max_accepts: Some(clients + 1),
        // live observability on an ephemeral port, same event loop
        stats_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    };

    // same spec→registry entry point as `aquant serve` (60-iter
    // calibration keeps a pjrt-build demo quick; ignored without pjrt)
    let registry = Arc::new(aquant::server::registry_from_specs(
        &specs,
        "artifacts",
        Some(60),
        false,
    )?);
    let engines: Vec<Arc<Engine>> = registry.iter().map(|(_, e)| e.engine.clone()).collect();
    let n_models = registry.len();

    let srv = Server::bind(registry, "127.0.0.1:0", cfg)?;
    let addr = srv.local_addr()?;
    let stats_addr = srv.stats_local_addr().expect("stats endpoint configured");
    println!("stats endpoint: http://{stats_addr}/stats (?fmt=text for plaintext)");
    let stats = srv.stats(); // live handle, before the accept loop starts
    for (spec, policy) in specs.iter().zip(srv.policies()) {
        println!("policy {}: {}", spec.name, policy.describe());
    }
    let server = std::thread::spawn(move || srv.run());

    // Load generators: `clients` connections, `n_req` pipelined batched
    // requests each — concurrent enough for the batcher to coalesce.
    // Client c talks to model c % n_models over protocol v2 (client 0
    // uses bare v1 headers: the backward-compat path to model id 0),
    // and checks every prediction against its model's sequential engine.
    let t_start = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let model_id = (c % n_models) as u16;
        let engine = engines[model_id as usize].clone();
        joins.push(std::thread::spawn(
            move || -> Result<(Vec<Duration>, usize)> {
                let mut stream = std::net::TcpStream::connect(addr)?;
                let img_elems = engine.img_elems();
                let mut rng = Rng::new(0xC11E27 + c as u64);
                let mut lat = Vec::new();
                let mut mismatches = 0usize;
                for _ in 0..n_req {
                    let images: Vec<f32> =
                        (0..batch * img_elems).map(|_| rng.normal()).collect();
                    let t0 = Instant::now();
                    let preds = if c == 0 {
                        classify_on(&mut stream, &images, batch)?
                    } else {
                        classify_on_v2(&mut stream, model_id, &images, batch)?
                    };
                    lat.push(t0.elapsed());
                    let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
                    let want = engine.classify_batch(&refs)?;
                    // a short (or long) response is itself a mismatch —
                    // zip alone would silently skip the missing tail
                    mismatches += preds.len().abs_diff(want.len());
                    mismatches += preds
                        .iter()
                        .zip(&want)
                        .filter(|(p, w)| **p != **w as u32)
                        .count();
                }
                Ok((lat, mismatches))
            },
        ));
    }
    let mut lat = Vec::new();
    let mut mismatches = 0usize;
    for j in joins {
        let (l, m) = j.join().expect("client thread")?;
        lat.extend(l);
        mismatches += m;
    }
    let wall = t_start.elapsed();

    // Scrape the live endpoint exactly the way an external collector
    // would (the server is still running: the load connections are
    // gone but the accept budget has one connection left).
    let scraped = scrape_text(stats_addr)?;
    // One empty connection spends the final accept so the bounded
    // event loop drains and returns; closing it is a clean EOF.
    drop(std::net::TcpStream::connect(addr)?);
    server.join().expect("server thread")?;

    lat.sort();
    let sum: Duration = lat.iter().sum();
    println!("\n== serving report ==");
    println!(
        "requests: {clients} clients x {n_req} x batch {batch} across {n_models} model(s)"
    );
    println!(
        "latency  p50 {:?}  p95 {:?}  mean {:?}",
        lat[lat.len() / 2],
        lat[((lat.len() as f64 * 0.95) as usize).min(lat.len() - 1)],
        sum / lat.len() as u32
    );
    println!(
        "throughput: {:.0} images/s (wall clock, all clients)",
        (clients * n_req * batch) as f64 / wall.as_secs_f64()
    );
    println!("{}", stats.report());
    println!("\n== live /stats?fmt=text scrape ==\n{scraped}");
    if mismatches > 0 {
        bail!("{mismatches} served predictions diverged from the sequential engine");
    }
    println!("bit-identity: every served prediction matches the sequential engine");
    Ok(())
}

/// Fetch `GET /stats?fmt=text` like any external scraper: one request,
/// read to EOF, strip the HTTP head.
fn scrape_text(addr: std::net::SocketAddr) -> Result<String> {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr)?;
    s.write_all(b"GET /stats?fmt=text HTTP/1.0\r\n\r\n")?;
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or(raw);
    Ok(body)
}
