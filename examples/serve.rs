//! Serving example: quantized inference behind a TCP server (pure-Rust
//! engine — no Python, no PJRT on the request path), with a load-generating
//! client reporting latency and throughput.
//!
//!   cargo run --release --offline --example serve -- [model] [bits] [batch] [n_req]

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use aquant::config::{Bits, Method};
use aquant::exp::cell::{build_quantized_engine, Ctx};
use aquant::server;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "mobiles".into());
    let bits = Bits::parse(&args.get(2).cloned().unwrap_or_else(|| "W4A4".into()))?;
    let batch: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(32);
    let n_req: usize = args.get(4).and_then(|s| s.parse().ok()).unwrap_or(8);

    let ctx = Ctx::new("artifacts", Some(60))?;
    println!("building quantized engine: {model} nearest {}", bits.name());
    let engine = Arc::new(build_quantized_engine(&ctx, &model, Method::Nearest, bits)?);
    let test = ctx.dataset.test.clone();
    let img_elems = test.img_elems();

    let addr = "127.0.0.1:7311";
    let srv_engine = engine.clone();
    let handle = std::thread::spawn(move || server::serve(srv_engine, addr, Some(1)));
    std::thread::sleep(std::time::Duration::from_millis(300));

    // Load generator: n_req batched requests over one connection.
    let mut lat = Vec::new();
    let mut hits = 0usize;
    let mut total = 0usize;
    use std::io::{Read, Write};
    let mut stream = std::net::TcpStream::connect(addr)?;
    for r in 0..n_req {
        let idx: Vec<usize> = (r * batch..(r + 1) * batch).map(|i| i % test.n).collect();
        let images = test.gather(&idx);
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(4 + images.len() * 4);
        out.extend_from_slice(&(batch as u32).to_le_bytes());
        for v in &images {
            out.extend_from_slice(&v.to_le_bytes());
        }
        stream.write_all(&out)?;
        let mut hdr = [0u8; 4];
        stream.read_exact(&mut hdr)?;
        let m = u32::from_le_bytes(hdr) as usize;
        let mut buf = vec![0u8; m * 4];
        stream.read_exact(&mut buf)?;
        lat.push(t0.elapsed());
        let preds: Vec<u32> = buf
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        for (&i, &p) in idx.iter().zip(&preds) {
            total += 1;
            if test.labels[i] == p {
                hits += 1;
            }
        }
    }
    drop(stream);
    let _ = handle.join();

    lat.sort();
    let sum: std::time::Duration = lat.iter().sum();
    println!("\n== serving report ==");
    println!("requests: {n_req} x batch {batch}  ({img_elems} f32/image)");
    println!(
        "latency  p50 {:?}  p95 {:?}  mean {:?}",
        lat[lat.len() / 2],
        lat[((lat.len() as f64 * 0.95) as usize).min(lat.len() - 1)],
        sum / lat.len() as u32
    );
    println!(
        "throughput: {:.0} images/s",
        (n_req * batch) as f64 / sum.as_secs_f64()
    );
    println!("accuracy over served batches: {:.2}%", hits as f64 / total as f64 * 100.0);
    Ok(())
}
