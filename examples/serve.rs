//! Serving example: quantized inference behind the dynamic-batching TCP
//! server (pure-Rust engine — no Python, no PJRT on the request path),
//! with a multi-client load generator reporting latency, throughput, and
//! the server's own batching stats.
//!
//!   cargo run --release --offline --example serve -- \
//!       [model] [bits] [batch] [n_req] [clients] [workers] [max_batch] [wait_us]
//!
//! Defaults: mobiles W4A4, 32-image requests, 8 requests x 4 clients,
//! auto workers, max-batch 64, 200us batch wait.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use aquant::config::{Bits, Method, ServeConfig};
use aquant::exp::cell::{build_quantized_engine, Ctx};
use aquant::server::{classify_on, Server};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "mobiles".into());
    let bits = Bits::parse(&args.get(2).cloned().unwrap_or_else(|| "W4A4".into()))?;
    let arg_n = |i: usize, d: usize| args.get(i).and_then(|s| s.parse().ok()).unwrap_or(d);
    let batch = arg_n(3, 32).clamp(1, aquant::server::MAX_REQ_IMAGES);
    let n_req = arg_n(4, 8).max(1);
    let clients = arg_n(5, 4).max(1);
    let cfg = ServeConfig {
        workers: arg_n(6, 0),
        max_batch: arg_n(7, 64),
        batch_wait_us: arg_n(8, 200) as u64,
        max_conns: Some(clients),
        ..ServeConfig::default()
    };

    let ctx = Ctx::new("artifacts", Some(60))?;
    println!("building quantized engine: {model} nearest {}", bits.name());
    let engine = Arc::new(build_quantized_engine(&ctx, &model, Method::Nearest, bits)?);
    // read-only test split shared across client threads (cloning the
    // full image buffer per client would multiply memory by `clients`)
    let test = Arc::new(ctx.dataset.test.clone());
    let img_elems = test.img_elems();

    let srv = Server::bind(engine, "127.0.0.1:0", cfg)?;
    let addr = srv.local_addr()?;
    let stats = srv.stats(); // live handle, before the accept loop starts
    let server = std::thread::spawn(move || srv.run());

    // Load generators: `clients` connections, `n_req` pipelined batched
    // requests each — concurrent enough for the batcher to coalesce.
    let t_start = Instant::now();
    let mut workers_joins = Vec::new();
    for c in 0..clients {
        let test = test.clone();
        workers_joins.push(std::thread::spawn(move || -> Result<(Vec<Duration>, usize, usize)> {
            let mut stream = std::net::TcpStream::connect(addr)?;
            let mut lat = Vec::new();
            let (mut hits, mut total) = (0usize, 0usize);
            for r in 0..n_req {
                let base = (c * n_req + r) * batch;
                let idx: Vec<usize> = (base..base + batch).map(|i| i % test.n).collect();
                let images = test.gather(&idx);
                let t0 = Instant::now();
                let preds = classify_on(&mut stream, &images, batch)?;
                lat.push(t0.elapsed());
                for (&i, &p) in idx.iter().zip(&preds) {
                    total += 1;
                    if test.labels[i] == p {
                        hits += 1;
                    }
                }
            }
            Ok((lat, hits, total))
        }));
    }
    let mut lat = Vec::new();
    let (mut hits, mut total) = (0usize, 0usize);
    for j in workers_joins {
        let (l, h, t) = j.join().expect("client thread")?;
        lat.extend(l);
        hits += h;
        total += t;
    }
    let wall = t_start.elapsed();
    server.join().expect("server thread")?;

    lat.sort();
    let sum: Duration = lat.iter().sum();
    println!("\n== serving report ==");
    println!(
        "requests: {clients} clients x {n_req} x batch {batch}  ({img_elems} f32/image)"
    );
    println!(
        "latency  p50 {:?}  p95 {:?}  mean {:?}",
        lat[lat.len() / 2],
        lat[((lat.len() as f64 * 0.95) as usize).min(lat.len() - 1)],
        sum / lat.len() as u32
    );
    println!(
        "throughput: {:.0} images/s (wall clock, all clients)",
        (clients * n_req * batch) as f64 / wall.as_secs_f64()
    );
    println!("server: {}", stats.report());
    println!(
        "accuracy over served batches: {:.2}%",
        hits as f64 / total as f64 * 100.0
    );
    Ok(())
}
