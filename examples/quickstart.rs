//! Quickstart: load the AOT artifacts, inspect the model zoo, and compare
//! full-precision vs nearest-rounding quantized accuracy on a slice of the
//! test set — no calibration involved.
//!
//! Run after `make artifacts && cargo build --release`:
//!   cargo run --release --offline --example quickstart

use anyhow::Result;

use aquant::config::{Bits, Method};
use aquant::exp::cell::Ctx;

fn main() -> Result<()> {
    let ctx = Ctx::new("artifacts", None)?;
    println!("platform: {}", ctx.rt.platform());
    println!("models: {:?}", ctx.models());

    let model = "mobiles"; // smallest — quickest demo
    let topo = ctx.topo(model)?;
    println!(
        "\n{model}: {} blocks / {} layers / {} params",
        topo.blocks.len(),
        topo.all_layers().len(),
        topo.all_layers().iter().map(|l| l.weight_elems()).sum::<usize>()
    );

    let fp = ctx.fp_accuracy(model)?;
    println!("FP accuracy:            {:.2}%", fp * 100.0);

    // Nearest rounding needs no calibration — just scale search.
    for bits_s in ["W4A4", "W2A2"] {
        let bits = Bits::parse(bits_s)?;
        let acc = ctx.run_cell(model, Method::Nearest, bits)?;
        println!("nearest {bits_s} accuracy:  {:.2}%", acc * 100.0);
    }
    println!("\nNext: `aquant eval --model {model} --method aquant --bits W2A2`");
    Ok(())
}
