"""PTQ graph builders: block-reconstruction step functions and quantized
forward programs, in the flattened-argument convention consumed by the
Rust coordinator.

Argument naming convention (recorded in the manifest; the Rust side is
fully generic over it):

  ``w:<layer>.w``, ``w:<layer>.b``      folded FP weights (constant inputs)
  ``state:<layer>.V``                   AdaRound soft-rounding logits
  ``state:<layer>.s_w``                 per-out-channel weight scales (fixed)
  ``state:<layer>.s_a``                 activation scale (learned, scalar)
  ``state:<layer>.bp``                  border params (R, 4): b0 b1 b2 α
  ``adam:...m`` / ``adam:...v``         Adam moments for each learned leaf
  ``adam:t``                            global step counter
  ``batch:x_in|x_fp|y_fp|mask``         calibration batch (mask = QDrop)
  ``hyper:bits``                        (L, 4): qmin_a qmax_a qmin_w qmax_w
  ``hyper:knobs``                       (12,): lr_v lr_s lr_b α_round β λ
                                        wq_en aq_en border_en fuse_en b2_en _

Step programs return the updated ``state:``/``adam:`` tensors under the
same names plus ``out:loss``; the coordinator writes results back into its
state store by name (see rust/src/coordinator/).

Forward programs (`q_L`, `fp_L`, `q_full`, `fp_full`) never apply the
*deferred* relu of residual blocks — for per-layer programs the Rust side
owns the block wiring (adds + relus); the full-model programs handle it
in-graph.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import quant
from .kernels.border_quant import border_quant_pallas
from .models.defs import BlockSpec, LayerSpec, ModelDef
from .models.forward import block_forward, layer_forward

KNOBS = (
    "lr_v lr_s lr_b alpha_round beta lam wq_en aq_en border_en fuse_en b2_en spare".split()
)

# indices into the knobs vector
K = {name: i for i, name in enumerate(KNOBS)}


@dataclasses.dataclass
class ArgSpec:
    name: str
    shape: tuple[int, ...]
    dtype: str = "f32"


def layer_state_shapes(l: LayerSpec) -> dict[str, tuple[int, ...]]:
    """Shapes of the per-layer quant state."""
    return {
        "V": l.weight_shape,
        "s_w": (l.oc, 1),
        "s_a": (),
        "bp": (l.rows, 4),
    }


LEARNED = ("V", "s_a", "bp")  # leaves Adam updates, with lrs (lr_v, lr_s, lr_b)


# ---------------------------------------------------------------------------
# Quant hooks
# ---------------------------------------------------------------------------


def _act_hook_ste(l: LayerSpec, st, bits_row, knobs):
    """Trainable activation-quant hook for the patches of layer `l`."""

    def hook(pm):
        return quant.act_quant_ste(
            pm,
            st["s_a"],
            st["bp"][:, 0],
            st["bp"][:, 1],
            st["bp"][:, 2],
            st["bp"][:, 3],
            l.k * l.k if l.kind == "conv" else 1,
            bits_row[0],
            bits_row[1],
            knobs[K["border_en"]],
            knobs[K["fuse_en"]],
            knobs[K["b2_en"]],
            knobs[K["aq_en"]],
            knobs[K["alpha_round"]],
        )

    return hook


def _act_hook_hard(l: LayerSpec, st, bits_row, knobs):
    """Inference activation-quant hook — the Pallas kernel."""

    def hook(pm):
        scalars = jnp.concatenate(
            [
                jnp.reshape(st["s_a"], (1,)),
                bits_row[0:1],
                bits_row[1:2],
                knobs[K["border_en"] : K["border_en"] + 1],
                knobs[K["fuse_en"] : K["fuse_en"] + 1],
                knobs[K["b2_en"] : K["b2_en"] + 1],
                knobs[K["aq_en"] : K["aq_en"] + 1],
                jnp.zeros((1,), jnp.float32),
            ]
        )
        k2 = l.k * l.k if l.kind == "conv" else 1
        return border_quant_pallas(pm, st["bp"], scalars, k2)

    return hook


def _weight_hook(l: LayerSpec, st, bits_row, knobs, hard: bool):
    fn = quant.weight_quant_hard if hard else quant.weight_quant_soft

    def hook(w2):
        return fn(w2, st["s_w"], st["V"], bits_row[2], bits_row[3], knobs[K["wq_en"]])

    return hook


# ---------------------------------------------------------------------------
# Block step program
# ---------------------------------------------------------------------------


def make_block_step(model: ModelDef, blk: BlockSpec):
    """Build (fn, arg_specs, result_names) for one block's calibration step.

    The returned ``fn`` takes the flat argument list in arg_specs order and
    returns the flat result tuple. One Adam step on (V, s_a, bp) of every
    layer in the block, minimizing block-output MSE + the AdaRound
    regularizer (Algorithm 1 + Appendix B schedules; all schedule values
    arrive as runtime hypers from the Rust coordinator).
    """
    layers = blk.all_layers()
    shapes = model.shapes()
    c0, h0, w0 = shapes[blk.layers[0].name]
    # block output shape
    hh, ww = h0, w0
    for l in blk.layers:
        hh, ww = l.out_hw(hh, ww)
    oc_out = blk.layers[-1].oc
    batch = BATCH_CALIB

    args: list[ArgSpec] = []
    for l in layers:
        args.append(ArgSpec(f"w:{l.name}.w", l.weight_shape))
        args.append(ArgSpec(f"w:{l.name}.b", (l.oc,)))
    for l in layers:
        for k, shp in layer_state_shapes(l).items():
            args.append(ArgSpec(f"state:{l.name}.{k}", shp))
    for l in layers:
        for leaf in LEARNED:
            shp = layer_state_shapes(l)[leaf]
            args.append(ArgSpec(f"adam:{l.name}.{leaf}.m", shp))
            args.append(ArgSpec(f"adam:{l.name}.{leaf}.v", shp))
    args.append(ArgSpec("adam:t", ()))
    if blk.layers[0].kind == "fc":
        # head block: input is the (N, C, H, W) feature map pre-GAP
        pass
    args.append(ArgSpec("batch:x_in", (batch, c0, h0, w0)))
    args.append(ArgSpec("batch:x_fp", (batch, c0, h0, w0)))
    out_shape = (batch, oc_out) if blk.layers[-1].kind == "fc" else (batch, oc_out, hh, ww)
    args.append(ArgSpec("batch:y_fp", out_shape))
    args.append(ArgSpec("batch:mask", (batch, c0, h0, w0)))
    args.append(ArgSpec("hyper:bits", (len(layers), 4)))
    args.append(ArgSpec("hyper:knobs", (len(KNOBS),)))

    names = [a.name for a in args]
    idx = {n: i for i, n in enumerate(names)}

    result_names = (
        [f"state:{l.name}.{k}" for l in layers for k in LEARNED]
        + [
            f"adam:{l.name}.{leaf}.{mv}"
            for l in layers
            for leaf in LEARNED
            for mv in ("m", "v")
        ]
        + ["adam:t", "out:loss"]
    )

    def fn(*flat):
        def get(n):
            return flat[idx[n]]

        weights = {
            l.name: {"w": get(f"w:{l.name}.w"), "b": get(f"w:{l.name}.b")} for l in layers
        }
        fixed_state = {
            l.name: {k: get(f"state:{l.name}.{k}") for k in ("s_w",)} for l in layers
        }
        learned = {
            l.name: {k: get(f"state:{l.name}.{k}") for k in LEARNED} for l in layers
        }
        knobs = get("hyper:knobs")
        bits = get("hyper:bits")
        x_in, x_fp = get("batch:x_in"), get("batch:x_fp")
        y_fp, mask = get("batch:y_fp"), get("batch:mask")
        # QDrop: elementwise substitution of FP activations at the block input
        x_used = mask * x_fp + (1.0 - mask) * x_in
        lidx = {l.name: i for i, l in enumerate(layers)}

        def loss_fn(learned):
            st = {
                l.name: {**fixed_state[l.name], **learned[l.name]} for l in layers
            }

            def pf(l):
                return _act_hook_ste(l, st[l.name], bits[lidx[l.name]], knobs)

            def wf(l):
                return _weight_hook(l, st[l.name], bits[lidx[l.name]], knobs, hard=False)

            out = block_forward(blk, weights, x_used, patches_fn_for=pf, weight_fn_for=wf)
            mse = jnp.mean((out - y_fp) ** 2)
            reg = sum(
                quant.freg(learned[l.name]["V"], knobs[K["beta"]]) for l in layers
            )
            return mse + knobs[K["lam"]] * knobs[K["wq_en"]] * reg

        grads = jax.grad(loss_fn)(learned)
        loss = loss_fn(learned)

        t = get("adam:t") + 1.0
        b1, b2, eps = 0.9, 0.999, 1e-8
        lrs = {"V": knobs[K["lr_v"]], "s_a": knobs[K["lr_s"]], "bp": knobs[K["lr_b"]]}
        new_state, new_adam = [], []
        for l in layers:
            for leaf in LEARNED:
                g = grads[l.name][leaf]
                m = get(f"adam:{l.name}.{leaf}.m")
                v = get(f"adam:{l.name}.{leaf}.v")
                m1 = b1 * m + (1 - b1) * g
                v1 = b2 * v + (1 - b2) * g * g
                mh = m1 / (1 - b1**t)
                vh = v1 / (1 - b2**t)
                upd = learned[l.name][leaf] - lrs[leaf] * mh / (jnp.sqrt(vh) + eps)
                new_state.append(upd)
                new_adam.extend([m1, v1])
        return tuple(new_state) + tuple(new_adam) + (t, loss)

    return fn, args, result_names


BATCH_CALIB = 32


# ---------------------------------------------------------------------------
# Forward programs
# ---------------------------------------------------------------------------


def make_layer_forward(model: ModelDef, l: LayerSpec, batch: int, quantized: bool):
    """(fn, arg_specs, result_names) for a single layer forward.

    Quantized version uses hard weights + the Pallas border kernel.
    No relu is applied — the Rust coordinator owns inter-layer wiring.
    """
    shapes = model.shapes()
    c, h, w = shapes[l.name]
    args = [
        ArgSpec(f"w:{l.name}.w", l.weight_shape),
        ArgSpec(f"w:{l.name}.b", (l.oc,)),
    ]
    if quantized:
        for k, shp in layer_state_shapes(l).items():
            args.append(ArgSpec(f"state:{l.name}.{k}", shp))
        args.append(ArgSpec("hyper:bits", (1, 4)))
        args.append(ArgSpec("hyper:knobs", (len(KNOBS),)))
    args.append(ArgSpec("batch:x", (batch, c, h, w)))
    names = [a.name for a in args]
    idx = {n: i for i, n in enumerate(names)}

    def fn(*flat):
        def get(n):
            return flat[idx[n]]

        x = get("batch:x")
        pfn = wfn = None
        if quantized:
            st = {k: get(f"state:{l.name}.{k}") for k in layer_state_shapes(l)}
            knobs = get("hyper:knobs")
            bits = get("hyper:bits")
            pfn = _act_hook_hard(l, st, bits[0], knobs)
            wfn = _weight_hook(l, st, bits[0], knobs, hard=True)
        out = layer_forward(
            l, get(f"w:{l.name}.w"), get(f"w:{l.name}.b"), x,
            patches_fn=pfn, weight_fn=wfn, apply_relu=False,
        )
        return (out,)

    return fn, args, ["out:y"]


def make_model_forward(model: ModelDef, batch: int, quantized: bool):
    """(fn, arg_specs, result_names) for the whole-model forward -> logits.

    This is the **request-path** program: hard quantization with the Pallas
    border kernel in every layer (or plain FP when ``quantized=False``).
    """
    layers = model.all_layers()
    args: list[ArgSpec] = []
    for l in layers:
        args.append(ArgSpec(f"w:{l.name}.w", l.weight_shape))
        args.append(ArgSpec(f"w:{l.name}.b", (l.oc,)))
    if quantized:
        for l in layers:
            for k, shp in layer_state_shapes(l).items():
                args.append(ArgSpec(f"state:{l.name}.{k}", shp))
        args.append(ArgSpec("hyper:bits", (len(layers), 4)))
        args.append(ArgSpec("hyper:knobs", (len(KNOBS),)))
    args.append(ArgSpec("batch:x", (batch, model.in_c, *model.in_hw)))
    names = [a.name for a in args]
    idx = {n: i for i, n in enumerate(names)}
    lidx = {l.name: i for i, l in enumerate(layers)}

    def fn(*flat):
        def get(n):
            return flat[idx[n]]

        weights = {
            l.name: {"w": get(f"w:{l.name}.w"), "b": get(f"w:{l.name}.b")} for l in layers
        }
        pf = wf = None
        if quantized:
            knobs = get("hyper:knobs")
            bits = get("hyper:bits")
            st = {
                l.name: {k: get(f"state:{l.name}.{k}") for k in layer_state_shapes(l)}
                for l in layers
            }

            def pf(l):  # noqa: F811
                return _act_hook_hard(l, st[l.name], bits[lidx[l.name]], knobs)

            def wf(l):  # noqa: F811
                return _weight_hook(l, st[l.name], bits[lidx[l.name]], knobs, hard=True)

        h = get("batch:x")
        for blk in model.blocks:
            h = block_forward(blk, weights, h, patches_fn_for=pf, weight_fn_for=wf)
        return (h,)

    return fn, args, ["out:logits"]
