"""AOT compiler: train → fold → lower every program → write artifacts.

Runs ONCE at build time (`make artifacts`); Python is never on the request
path. Outputs under ``artifacts/``:

  data/*.bin                synthetic corpus (shared bytes with Rust)
  ckpt/<model>.npz          raw training checkpoints (cache)
  weights/<model>/*.bin     BN-folded FP weights (Rust reads these)
  qinit/<model>/wbits<M>/   weight scales s_w + AdaRound V init per bit-width
  *.hlo.txt                 lowered programs (HLO text — see below)
  manifest.json             program registry + topology/data/weights meta

HLO **text** is the interchange format, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import ptq, quant, train
from .models import MODELS, ModelDef
from .models.defs import BlockSpec
from .models.forward import fold_bn

WBITS_CONFIGS = (2, 3, 4, 8)
EPOCHS = {"resnet10s": 8, "mobiles": 10, "regnets": 8}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(fn, arg_specs, result_names, name: str, out_dir: str) -> dict:
    """Lower `fn` and return its manifest entry."""
    specs = [jax.ShapeDtypeStruct(tuple(a.shape), jnp.float32) for a in arg_specs]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    path = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *specs)
    results = [
        {"name": rn, "shape": list(s.shape), "dtype": "f32"}
        for rn, s in zip(result_names, out_shapes)
    ]
    return {
        "path": path,
        "args": [
            {"name": a.name, "shape": list(a.shape), "dtype": a.dtype} for a in arg_specs
        ],
        "results": results,
    }


def export_weights(model: ModelDef, folded, out_dir: str) -> dict:
    meta = {}
    wdir = os.path.join(out_dir, "weights", model.name)
    os.makedirs(wdir, exist_ok=True)
    for l in model.all_layers():
        w = np.asarray(folded[l.name]["w"], "<f4")
        b = np.asarray(folded[l.name]["b"], "<f4")
        w.tofile(os.path.join(wdir, f"{l.name}.w.bin"))
        b.tofile(os.path.join(wdir, f"{l.name}.b.bin"))
        meta[l.name] = {
            "w": f"weights/{model.name}/{l.name}.w.bin",
            "w_shape": list(w.shape),
            "b": f"weights/{model.name}/{l.name}.b.bin",
            "b_shape": list(b.shape),
        }
    return meta


def export_qinit(model: ModelDef, folded, out_dir: str) -> dict:
    """Per-bit-width weight scales + AdaRound V init."""
    meta = {}
    for bits in WBITS_CONFIGS:
        qdir = os.path.join(out_dir, "qinit", model.name, f"wbits{bits}")
        os.makedirs(qdir, exist_ok=True)
        bm = {}
        for l in model.all_layers():
            w2 = folded[l.name]["w"]
            s_w = quant.weight_scale_mse(w2, bits)
            v0 = quant.v_init(w2, s_w)
            np.asarray(s_w, "<f4").tofile(os.path.join(qdir, f"{l.name}.s_w.bin"))
            np.asarray(v0, "<f4").tofile(os.path.join(qdir, f"{l.name}.V.bin"))
            bm[l.name] = {
                "s_w": f"qinit/{model.name}/wbits{bits}/{l.name}.s_w.bin",
                "V": f"qinit/{model.name}/wbits{bits}/{l.name}.V.bin",
            }
        meta[str(bits)] = bm
    return meta


def model_topology_meta(model: ModelDef) -> dict:
    shapes = model.shapes()

    def layer_meta(l):
        c, h, w = shapes[l.name]
        ho, wo = l.out_hw(h, w)
        return {
            "name": l.name,
            "kind": l.kind,
            "ic": l.ic,
            "oc": l.oc,
            "k": l.k,
            "stride": l.stride,
            "pad": l.pad,
            "groups": l.groups,
            "relu": l.relu,
            "gap_input": l.gap_input,
            "rows": l.rows,
            "in_chw": [c, h, w],
            "out_chw": [l.oc, ho, wo],
        }

    return {
        "name": model.name,
        "in_c": model.in_c,
        "in_hw": list(model.in_hw),
        "n_classes": model.n_classes,
        "blocks": [
            {
                "name": b.name,
                "residual": b.residual,
                "downsample": b.downsample.name if b.downsample else None,
                "layers": [layer_meta(l) for l in b.layers]
                + ([layer_meta(b.downsample)] if b.downsample else []),
            }
            for b in model.blocks
        ],
    }


def layer_partition(model: ModelDef) -> list[BlockSpec]:
    """Every layer as its own reconstruction unit (AdaRound granularity)."""
    return [
        BlockSpec(name=f"L_{l.name}", layers=(l,), residual=False, downsample=None)
        for l in model.all_layers()
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(MODELS))
    ap.add_argument("--epochs-scale", type=float, default=1.0)
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    model_names = [m for m in args.models.split(",") if m]

    t0 = time.time()
    print("== data ==")
    splits = data_mod.canonical_splits()
    data_meta = data_mod.export(os.path.join(out_dir, "data"), splits)

    programs: dict = {}
    meta: dict = {
        "data": data_meta,
        "models": {},
        "weights": {},
        "qinit": {},
        "knobs": ptq.KNOBS,
        "fp_acc": {},
        "calib_batch": ptq.BATCH_CALIB,
    }

    for name in model_names:
        model = MODELS[name]
        print(f"== model {name} ==")
        ckpt = os.path.join(out_dir, "ckpt", f"{name}.npz")
        if os.path.exists(ckpt):
            params = train.load_ckpt(ckpt)
            acc = train.accuracy(
                model, params, splits["test"].images, splits["test"].labels
            )
            print(f"  loaded checkpoint, test acc {acc * 100:.2f}%")
        else:
            epochs = max(1, int(EPOCHS[name] * args.epochs_scale))
            params, acc = train.train_model(model, splits, epochs=epochs)
            train.save_ckpt(ckpt, params)
        meta["fp_acc"][name] = acc
        folded = fold_bn(model, params)
        meta["weights"][name] = export_weights(model, folded, out_dir)
        meta["qinit"][name] = export_qinit(model, folded, out_dir)
        meta["models"][name] = model_topology_meta(model)

        print("  lowering programs ...")
        b = ptq.BATCH_CALIB
        for l in model.all_layers():
            fn, a, r = ptq.make_layer_forward(model, l, b, quantized=False)
            programs[f"fp_{name}_{l.name}"] = lower_program(
                fn, a, r, f"fp_{name}_{l.name}", out_dir
            )
            fn, a, r = ptq.make_layer_forward(model, l, b, quantized=True)
            programs[f"q_{name}_{l.name}"] = lower_program(
                fn, a, r, f"q_{name}_{l.name}", out_dir
            )
        for blk in model.blocks:
            fn, a, r = ptq.make_block_step(model, blk)
            programs[f"step_{name}_B_{blk.name}"] = lower_program(
                fn, a, r, f"step_{name}_B_{blk.name}", out_dir
            )
        for blk in layer_partition(model):
            fn, a, r = ptq.make_block_step(model, blk)
            programs[f"step_{name}_{blk.name}"] = lower_program(
                fn, a, r, f"step_{name}_{blk.name}", out_dir
            )
        fn, a, r = ptq.make_model_forward(model, b, quantized=False)
        programs[f"fp_full_{name}"] = lower_program(fn, a, r, f"fp_full_{name}", out_dir)
        fn, a, r = ptq.make_model_forward(model, b, quantized=True)
        programs[f"q_full_{name}"] = lower_program(fn, a, r, f"q_full_{name}", out_dir)
        print(f"  done ({time.time() - t0:.0f}s elapsed)")

    manifest = {
        "producer": f"jax {jax.__version__}",
        "programs": programs,
        "meta": meta,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"== wrote {len(programs)} programs to {out_dir} ({time.time() - t0:.0f}s) ==")


if __name__ == "__main__":
    main()
