"""Synthetic image-classification corpus (ImageNet substitute).

The paper evaluates PTQ on ImageNet; this environment has no dataset, so we
generate a deterministic 24-class procedural-texture corpus that exercises
the same code paths: convolutional features, realistic (heavy-tailed,
ReLU-sparse) activation statistics, and enough headroom that low-bit
quantization visibly degrades accuracy.

Each class is an oriented grating with a class-specific (orientation,
frequency, color tint) triple; samples add per-image phase, amplitude
jitter, a random low-frequency illumination gradient, and pixel noise, so
nearest neighbours do not trivially solve it.

Everything is keyed by an integer seed; the exact same bytes are written to
``artifacts/data/*.bin`` for the Rust side (raw little-endian f32 / u32).
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

IMG_H = 24
IMG_W = 24
IMG_C = 3
N_CLASSES = 24


@dataclasses.dataclass(frozen=True)
class Split:
    """One dataset split (images NCHW float32 in [0,1]-ish, labels u32)."""

    images: np.ndarray  # (N, C, H, W) f32
    labels: np.ndarray  # (N,) u32

    @property
    def n(self) -> int:
        return int(self.images.shape[0])


def _class_params(n_classes: int = N_CLASSES):
    """Per-class (orientation, frequency, tint) table — fixed, not random."""
    oris = np.linspace(0.0, np.pi, n_classes, endpoint=False)
    freqs = 2.5 + 1.5 * (np.arange(n_classes) % 3)
    tints = np.stack(
        [
            0.5 + 0.5 * np.cos(2 * np.pi * np.arange(n_classes) / n_classes),
            0.5 + 0.5 * np.sin(2 * np.pi * np.arange(n_classes) / n_classes),
            np.linspace(0.3, 1.0, n_classes),
        ],
        axis=1,
    )
    return oris, freqs, tints


def generate(n: int, seed: int) -> Split:
    """Generate `n` labelled images deterministically from `seed`."""
    rng = np.random.RandomState(seed)
    oris, freqs, tints = _class_params()
    yy, xx = np.meshgrid(
        np.linspace(-1, 1, IMG_H), np.linspace(-1, 1, IMG_W), indexing="ij"
    )
    labels = rng.randint(0, N_CLASSES, size=n).astype(np.uint32)
    images = np.empty((n, IMG_C, IMG_H, IMG_W), dtype=np.float32)
    for i in range(n):
        c = int(labels[i])
        theta = oris[c] + rng.uniform(-0.05, 0.05)
        freq = freqs[c] * rng.uniform(0.92, 1.08)
        phase = rng.uniform(0, 2 * np.pi)
        u = np.cos(theta) * xx + np.sin(theta) * yy
        grating = 0.5 + 0.5 * np.sin(2 * np.pi * freq * u + phase)
        amp = rng.uniform(0.5, 1.0)
        # low-frequency illumination gradient
        gx, gy = rng.uniform(-0.3, 0.3, size=2)
        illum = 0.15 * (gx * xx + gy * yy)
        base = amp * grating + illum
        img = base[None, :, :] * tints[c][:, None, None]
        img += rng.normal(0.0, 0.32, size=img.shape)
        images[i] = img.astype(np.float32)
    return Split(images=images, labels=labels)


# Canonical splits (seeds are part of the experiment definition).
TRAIN_SEED, CALIB_SEED, TEST_SEED = 1001, 2002, 3003
N_TRAIN, N_CALIB, N_TEST = 6144, 256, 1536


def canonical_splits() -> dict[str, Split]:
    return {
        "train": generate(N_TRAIN, TRAIN_SEED),
        "calib": generate(N_CALIB, CALIB_SEED),
        "test": generate(N_TEST, TEST_SEED),
    }


def export(out_dir: str, splits: dict[str, Split]) -> dict:
    """Write raw .bin files + return the manifest meta section."""
    os.makedirs(out_dir, exist_ok=True)
    meta = {
        "h": IMG_H,
        "w": IMG_W,
        "c": IMG_C,
        "n_classes": N_CLASSES,
        "splits": {},
    }
    for name, split in splits.items():
        img_file = f"data/{name}_images.bin"
        lab_file = f"data/{name}_labels.bin"
        split.images.astype("<f4").tofile(os.path.join(out_dir, f"{name}_images.bin"))
        split.labels.astype("<u4").tofile(os.path.join(out_dir, f"{name}_labels.bin"))
        meta["splits"][name] = {
            "images": img_file,
            "labels": lab_file,
            "n": split.n,
        }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


if __name__ == "__main__":
    s = canonical_splits()
    for k, v in s.items():
        print(k, v.images.shape, v.labels[:8])
