"""Pallas kernel: fused adaptive-border activation quantization.

The inference hot-spot of AQuant. One VMEM pass over an im2col'd activation
tile computes the border polynomial, the sigmoid bound, the per-channel
fusion mean, and the round/clip/dequantize — so the border never costs an
extra HBM round-trip. This is the TPU re-expression of the paper's
"fuse B(x) with img2col" CUDA argument (§4.3 / Figure 3); see DESIGN.md
§Hardware-Adaptation.

Tiling: grid over output-pixel columns. Each step holds a ``(R, TILE_P)``
activation block plus the ``(R, 4)`` parameter table in VMEM. For the
largest layer in the zoo (R = 96·9 = 864) and TILE_P = 256 that is
864·256·4 B ≈ 0.9 MB — comfortably inside a TPU core's ~16 MB VMEM with
double-buffering headroom (see DESIGN.md §Perf for the roofline estimate).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 256


def _kernel(x_ref, params_ref, scalars_ref, o_ref, *, k2: int):
    x = x_ref[...]  # (R, TILE_P)
    prm = params_ref[...]  # (R, 4)
    sc = scalars_ref[...]  # (8,)
    s, qmin, qmax = sc[0], sc[1], sc[2]
    border_en, fuse_en, b2_en, aq_en = sc[3], sc[4], sc[5], sc[6]
    b0 = prm[:, 0][:, None]
    b1 = prm[:, 1][:, None]
    b2 = prm[:, 2][:, None]
    alpha = prm[:, 3][:, None]
    r, tp = x.shape
    xs = x / s
    u = (b2_en * b2) * xs * xs + b1 * xs + b0
    be = 0.5 + border_en * (jax.nn.sigmoid(2.5 * u) - 0.5)
    seg = (alpha * be).reshape(r // k2, k2, tp)
    fused = jnp.broadcast_to(
        jnp.mean(seg, axis=1, keepdims=True), seg.shape
    ).reshape(r, tp)
    border = fuse_en * fused + (1.0 - fuse_en) * be
    q = jnp.clip(jnp.ceil(xs - border), qmin, qmax)
    o_ref[...] = aq_en * (s * q) + (1.0 - aq_en) * x


def border_quant_pallas(x, params, scalars, k2: int, tile_p: int = TILE_P):
    """Fused border quantization of im2col'd activations.

    Args mirror :func:`..kernels.ref.border_quant_ref`:
      x:       (N, R, P) f32.
      params:  (R, 4) f32 — [b0, b1, b2, alpha] columns.
      scalars: (8,) f32 — [s, qmin, qmax, border_en, fuse_en, b2_en,
               aq_en, _pad].
      k2:      static segment length (kernel-size²); must divide R.

    Returns (N, R, P) f32.
    """
    n, r, p = x.shape
    if r % k2 != 0:
        raise ValueError(f"R={r} not a multiple of k2={k2}")
    # Collapse batch into the pixel axis so one grid covers everything:
    # (N, R, P) -> (R, N·P), padded to a tile multiple.
    xt = jnp.swapaxes(x, 0, 1).reshape(r, n * p)
    total = n * p
    pad = (-total) % tile_p
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, pad)))
    padded = total + pad
    grid = padded // tile_p

    out = pl.pallas_call(
        functools.partial(_kernel, k2=k2),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((r, tile_p), lambda j: (0, j)),
            pl.BlockSpec((r, 4), lambda j: (0, 0)),
            pl.BlockSpec((8,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((r, tile_p), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, padded), x.dtype),
        interpret=True,
    )(xt, params, scalars)

    out = out[:, :total].reshape(r, n, p)
    return jnp.swapaxes(out, 0, 1)


def make_scalars(s, qmin, qmax, border_en=1.0, fuse_en=1.0, b2_en=1.0, aq_en=1.0):
    """Assemble the kernel's scalar block (helper for tests/aot)."""
    return jnp.asarray([s, qmin, qmax, border_en, fuse_en, b2_en, aq_en, 0.0], jnp.float32)
