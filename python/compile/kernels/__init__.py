"""Pallas kernels (L1) + pure-jnp oracle."""
