"""Pure-jnp oracle for the fused border-quantization kernel.

This is the CORE correctness signal for L1: pytest sweeps shapes and
parameter regimes asserting ``border_quant_pallas == border_quant_ref``
(both are f32 pipelines with identical operation order).

The math is the paper's inference-time activation quantization:

    xs = x / s
    u  = b2·xs² + b1·xs + b0           (quadratic border, Eq. 8)
    Bᴱ = 0.5 + (sigmoid(2.5·u) − 0.5)  (bounded border, Appendix B)
    Bᴵ = per-input-channel mean of α·Bᴱ (border fusion, Eq. 9)
    q  = clip(ceil(xs − B), qmin, qmax)
    x̂  = s·q
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def border_quant_ref(x, params, scalars, k2: int):
    """Oracle. Args mirror the Pallas kernel:

    x:       (N, R, P) im2col'd activations, R = i_c·k².
    params:  (R, 4) columns [b0, b1, b2, alpha].
    scalars: (8,) = [s, qmin, qmax, border_en, fuse_en, b2_en, aq_en, _pad].
    k2:      static kernel-size² (segment length for fusion).
    """
    s, qmin, qmax, border_en, fuse_en, b2_en, aq_en = (scalars[i] for i in range(7))
    b0 = params[:, 0][None, :, None]
    b1 = params[:, 1][None, :, None]
    b2 = params[:, 2][None, :, None]
    alpha = params[:, 3][None, :, None]
    n, r, p = x.shape
    xs = x / s
    u = (b2_en * b2) * xs * xs + b1 * xs + b0
    be = 0.5 + border_en * (jax.nn.sigmoid(2.5 * u) - 0.5)
    seg = (alpha * be).reshape(n, r // k2, k2, p)
    fused = jnp.broadcast_to(jnp.mean(seg, axis=2, keepdims=True), seg.shape).reshape(n, r, p)
    border = fuse_en * fused + (1.0 - fuse_en) * be
    q = jnp.clip(jnp.ceil(xs - border), qmin, qmax)
    return aq_en * (s * q) + (1.0 - aq_en) * x
