"""FP pretraining of the model zoo on the synthetic corpus.

This substitutes for "download a torchvision checkpoint": PTQ needs a
converged full-precision model, and we train one per family at build time
(a couple of minutes each on CPU). Checkpoints are cached under
``artifacts/ckpt/`` so `make artifacts` is incremental.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .models import ModelDef
from .models.forward import init_params, train_forward

TRAIN_SEED = 7


def _loss_fn(model: ModelDef, params, x, y):
    logits, stats = train_forward(model, params, x, train=True)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return loss, stats


def _adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def _adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# BN running stats are *not* gradient-updated; mask them out of Adam.
_TRAINABLE = ("w", "b", "gamma", "beta")


def _split_trainable(params):
    train = {n: {k: v for k, v in p.items() if k in _TRAINABLE} for n, p in params.items()}
    stats = {
        n: {k: v for k, v in p.items() if k not in _TRAINABLE} for n, p in params.items()
    }
    return train, stats


def _merge(train, stats):
    return {n: {**train[n], **stats[n]} for n in train}


def accuracy(model: ModelDef, params, images, labels, batch: int = 256) -> float:
    """Top-1 accuracy with running BN stats (eval mode)."""
    hits = 0

    @jax.jit
    def fwd(x):
        logits, _ = train_forward(model, params, x, train=False)
        return jnp.argmax(logits, axis=1)

    for i in range(0, len(labels), batch):
        x = jnp.asarray(images[i : i + batch])
        pred = np.asarray(fwd(x))
        hits += int((pred == labels[i : i + batch]).sum())
    return hits / len(labels)


def train_model(
    model: ModelDef,
    splits: dict[str, data_mod.Split],
    epochs: int = 8,
    batch: int = 128,
    lr: float = 2e-3,
    verbose: bool = True,
):
    """Train; returns (params_with_stats, test_accuracy)."""
    params = init_params(model, seed=TRAIN_SEED)
    train_p, stats = _split_trainable(params)
    opt = _adam_init(train_p)
    tr = splits["train"]
    rng = np.random.RandomState(11)

    @jax.jit
    def step(train_p, stats, opt, x, y, lr):
        full = _merge(train_p, stats)
        (loss, new_stats), grads = jax.value_and_grad(
            lambda tp: _loss_fn(model, _merge(tp, stats), x, y), has_aux=True
        )(train_p)
        new_train, new_opt = _adam_update(train_p, grads, opt, lr)
        # merge updated running stats back into the static side
        merged_stats = {
            n: {
                **stats[n],
                **(
                    {"rmean": new_stats[n][0], "rvar": new_stats[n][1]}
                    if n in new_stats
                    else {}
                ),
            }
            for n in stats
        }
        del full
        return new_train, merged_stats, new_opt, loss

    n = tr.n
    steps_per_epoch = n // batch
    t0 = time.time()
    for ep in range(epochs):
        perm = rng.permutation(n)
        ep_loss = 0.0
        cur_lr = lr * (0.5 ** (ep // 3))
        for s in range(steps_per_epoch):
            idx = perm[s * batch : (s + 1) * batch]
            x = jnp.asarray(tr.images[idx])
            y = jnp.asarray(tr.labels[idx].astype(np.int32))
            train_p, stats, opt, loss = step(train_p, stats, opt, x, y, jnp.float32(cur_lr))
            ep_loss += float(loss)
        if verbose:
            print(
                f"  [{model.name}] epoch {ep + 1}/{epochs} "
                f"loss {ep_loss / steps_per_epoch:.4f} ({time.time() - t0:.0f}s)"
            )
    params = _merge(train_p, stats)
    acc = accuracy(model, params, splits["test"].images, splits["test"].labels)
    if verbose:
        print(f"  [{model.name}] test accuracy {acc * 100:.2f}%")
    return params, acc


def save_ckpt(path: str, params) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    flat = {}
    for lname, p in params.items():
        for k, v in p.items():
            flat[f"{lname}/{k}"] = np.asarray(v)
    np.savez(path, **flat)


def load_ckpt(path: str):
    z = np.load(path)
    params: dict = {}
    for key in z.files:
        lname, k = key.rsplit("/", 1)
        params.setdefault(lname, {})[k] = jnp.asarray(z[key])
    return params
