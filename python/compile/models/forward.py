"""Forward builders over the model specs.

Two styles:

* **Training forward** (``train_forward``): direct ``lax.conv`` + BatchNorm
  + ReLU, used only by ``train.py`` to pretrain the FP models.
* **Folded forward** (``layer_forward`` / ``block_forward_fp``): the PTQ
  view — BatchNorm folded into weights, every conv expressed as
  im2col patches × matmul (exactly the paper's ``(o_c, i_c·k²) ×
  (i_c·k², h_o·w_o)`` formulation). An optional ``patches_fn`` hook lets
  the PTQ graphs quantize the patches at the layer's input, which is the
  paper's refactored activation-quantization position.

The im2col row ordering (channel-major: row = c·k² + kh·k + kw, groups
contiguous) is verified against ``lax.conv_general_dilated`` in pytest and
is mirrored by the Rust engine (`rust/src/nn/im2col.rs`).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .defs import BlockSpec, LayerSpec, ModelDef

Params = dict  # name -> dict of arrays


# ---------------------------------------------------------------------------
# Training-time forward (BN, lax.conv)
# ---------------------------------------------------------------------------


def init_params(model: ModelDef, seed: int) -> Params:
    """He-init conv/fc weights + BN parameters and running stats."""
    rng = np.random.RandomState(seed)
    params: Params = {}
    for l in model.all_layers():
        fan_in = l.rows_per_group if l.kind == "conv" else l.ic
        std = float(np.sqrt(2.0 / fan_in))
        if l.kind == "conv":
            w = rng.normal(0.0, std, size=(l.oc, l.ic // l.groups, l.k, l.k))
        else:
            w = rng.normal(0.0, std, size=(l.oc, l.ic))
        params[l.name] = {
            "w": jnp.asarray(w, jnp.float32),
            "b": jnp.zeros((l.oc,), jnp.float32),
            # BN (convs only; fc head has no BN)
            "gamma": jnp.ones((l.oc,), jnp.float32),
            "beta": jnp.zeros((l.oc,), jnp.float32),
            "rmean": jnp.zeros((l.oc,), jnp.float32),
            "rvar": jnp.ones((l.oc,), jnp.float32),
        }
    return params


def _conv_raw(l: LayerSpec, w, x):
    return lax.conv_general_dilated(
        x,
        w,
        (l.stride, l.stride),
        [(l.pad, l.pad), (l.pad, l.pad)],
        feature_group_count=l.groups,
    )


def train_forward(model: ModelDef, params: Params, x, train: bool, momentum: float = 0.1):
    """Forward with BatchNorm. Returns (logits, new_running_stats)."""
    new_stats = {}

    def bn_relu(l: LayerSpec, p, h):
        if train:
            mean = jnp.mean(h, axis=(0, 2, 3))
            var = jnp.var(h, axis=(0, 2, 3))
            new_stats[l.name] = (
                (1 - momentum) * p["rmean"] + momentum * mean,
                (1 - momentum) * p["rvar"] + momentum * var,
            )
        else:
            mean, var = p["rmean"], p["rvar"]
        h = (h - mean[None, :, None, None]) / jnp.sqrt(var[None, :, None, None] + 1e-5)
        h = h * p["gamma"][None, :, None, None] + p["beta"][None, :, None, None]
        return h

    h = x
    for blk in model.blocks:
        skip = h
        for i, l in enumerate(blk.layers):
            p = params[l.name]
            if l.kind == "fc":
                if l.gap_input and h.ndim == 4:
                    h = jnp.mean(h, axis=(2, 3))
                h = h @ p["w"].T + p["b"]
            else:
                h = _conv_raw(l, p["w"], h) + p["b"][None, :, None, None]
                h = bn_relu(l, p, h)
                is_last = i == len(blk.layers) - 1
                if l.relu or (is_last and blk.residual):
                    # residual blocks: main-path output stays pre-relu; the
                    # relu after the add is applied below.
                    if l.relu and not (is_last and blk.residual):
                        h = jax.nn.relu(h)
        if blk.residual:
            if blk.downsample is not None:
                d = blk.downsample
                pd = params[d.name]
                sk = _conv_raw(d, pd["w"], skip) + pd["b"][None, :, None, None]
                sk = (sk - pd["rmean"][None, :, None, None]) / jnp.sqrt(
                    pd["rvar"][None, :, None, None] + 1e-5
                ) * pd["gamma"][None, :, None, None] + pd["beta"][None, :, None, None]
                # (training uses batch stats only on the main path for
                # simplicity; the skip projection BN uses running stats —
                # folded identically at export)
                skip = sk
            h = jax.nn.relu(h + skip)
    return h, new_stats


# ---------------------------------------------------------------------------
# BN folding (PTQ starts from folded weights)
# ---------------------------------------------------------------------------


def fold_bn(model: ModelDef, params: Params) -> dict[str, dict[str, jnp.ndarray]]:
    """Fold BN into conv weights; flatten conv weights to (oc, icg·k²).

    Returns name -> {"w": (oc, r), "b": (oc,)} ready for the im2col path.
    """
    folded = {}
    for l in model.all_layers():
        p = params[l.name]
        if l.kind == "fc":
            folded[l.name] = {"w": p["w"], "b": p["b"]}
            continue
        scale = p["gamma"] / jnp.sqrt(p["rvar"] + 1e-5)
        w = p["w"] * scale[:, None, None, None]
        b = p["beta"] + (p["b"] - p["rmean"]) * scale
        folded[l.name] = {"w": w.reshape(l.oc, l.rows_per_group), "b": b}
    return folded


# ---------------------------------------------------------------------------
# Folded (PTQ) forward: im2col patches × matmul
# ---------------------------------------------------------------------------


def extract_patches(l: LayerSpec, x):
    """im2col: (N, ic, H, W) -> (N, ic·k², ho·wo)."""
    patches = lax.conv_general_dilated_patches(
        x, (l.k, l.k), (l.stride, l.stride), padding=[(l.pad, l.pad), (l.pad, l.pad)]
    )
    n = patches.shape[0]
    return patches.reshape(n, l.rows, -1)


def layer_forward(
    l: LayerSpec,
    w2,
    b,
    x,
    patches_fn: Optional[Callable] = None,
    weight_fn: Optional[Callable] = None,
    apply_relu: Optional[bool] = None,
):
    """One folded layer: im2col -> [quantize patches] -> matmul -> bias.

    ``patches_fn``: hook applied to the (N, R, P) patch tensor — the
    activation-quantization node.
    ``weight_fn``: hook applied to the (oc, r) weight matrix — the weight-
    quantization node.
    ``apply_relu``: override the spec's relu (residual blocks defer it).
    """
    relu = l.relu if apply_relu is None else apply_relu
    w_used = weight_fn(w2) if weight_fn is not None else w2
    if l.kind == "fc":
        if l.gap_input and x.ndim == 4:
            x = jnp.mean(x, axis=(2, 3))
        h = x[:, None, :]  # (N, 1, ic) -> rows axis second for the hook
        h = jnp.swapaxes(h, 1, 2)  # (N, ic, 1): R=ic, P=1
        if patches_fn is not None:
            h = patches_fn(h)
        out = jnp.einsum("or,nrp->nop", w_used, h)[:, :, 0] + b
        return jax.nn.relu(out) if relu else out
    n = x.shape[0]
    h_in, w_in = x.shape[2], x.shape[3]
    ho, wo = l.out_hw(h_in, w_in)
    pm = extract_patches(l, x)
    if patches_fn is not None:
        pm = patches_fn(pm)
    if l.groups == 1:
        out = jnp.einsum("or,nrp->nop", w_used, pm)
    else:
        rg = l.rows_per_group
        ocg = l.oc // l.groups
        outs = []
        for g in range(l.groups):
            rows = pm[:, g * rg : (g + 1) * rg, :]
            wg = w_used[g * ocg : (g + 1) * ocg]
            outs.append(jnp.einsum("or,nrp->nop", wg, rows))
        out = jnp.concatenate(outs, axis=1)
    out = out.reshape(n, l.oc, ho, wo) + b[None, :, None, None]
    return jax.nn.relu(out) if relu else out


def block_forward(
    blk: BlockSpec,
    weights: dict,
    x,
    patches_fn_for: Optional[Callable[[LayerSpec], Optional[Callable]]] = None,
    weight_fn_for: Optional[Callable[[LayerSpec], Optional[Callable]]] = None,
):
    """Folded forward of one block (FP when no hooks are given)."""
    pf = patches_fn_for or (lambda l: None)
    wf = weight_fn_for or (lambda l: None)
    h = x
    for i, l in enumerate(blk.layers):
        is_last = i == len(blk.layers) - 1
        relu = l.relu and not (is_last and blk.residual)
        h = layer_forward(
            l, weights[l.name]["w"], weights[l.name]["b"], h,
            patches_fn=pf(l), weight_fn=wf(l), apply_relu=relu,
        )
    if blk.residual:
        skip = x
        if blk.downsample is not None:
            d = blk.downsample
            skip = layer_forward(
                d, weights[d.name]["w"], weights[d.name]["b"], x,
                patches_fn=pf(d), weight_fn=wf(d), apply_relu=False,
            )
        h = jax.nn.relu(h + skip)
    return h


def model_forward(model: ModelDef, weights: dict, x, **hooks):
    """Folded forward of the whole model -> logits."""
    h = x
    for blk in model.blocks:
        h = block_forward(blk, weights, h, **hooks)
    return h
