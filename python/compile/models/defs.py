"""Model definitions as explicit layer/block specs.

The specs are the single source of truth shared by:
  * the JAX forward builders (training, FP reference, quantized PTQ graphs),
  * ``aot.py`` (program lowering + manifest metadata),
  * the Rust side (mirrored from the manifest: the integer inference engine
    and the calibration coordinator read the same topology).

The zoo covers the paper's three CNN design families at laptop scale:
  * ``resnet10s``  — residual blocks (ResNet-18/50 family),
  * ``mobiles``    — depthwise-separable convolutions (MobileNetV2/MNasNet),
  * ``regnets``    — group convolutions (RegNet-600MF/3.2GF).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..data import IMG_C, IMG_H, IMG_W, N_CLASSES


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One matmul-bearing layer (conv expressed as im2col + matmul, or fc).

    ``groups`` follows the usual convention: weights have shape
    ``(oc, (ic // groups) · k²)``; depthwise = ``groups == ic``.
    ``relu`` is the layer's *own* activation; for residual blocks the final
    relu happens after the skip-add and is owned by the block.
    ``gap_input`` (fc only): global-average-pool the (N, C, H, W) input
    before the matmul.
    """

    name: str
    kind: str  # "conv" | "fc"
    ic: int
    oc: int
    k: int = 1
    stride: int = 1
    pad: int = 0
    groups: int = 1
    relu: bool = True
    gap_input: bool = False

    @property
    def rows(self) -> int:
        """im2col row count R = i_c · k² (patch features per output pixel)."""
        return self.ic * self.k * self.k

    @property
    def rows_per_group(self) -> int:
        return (self.ic // self.groups) * self.k * self.k

    @property
    def weight_shape(self) -> tuple[int, int]:
        return (self.oc, self.rows_per_group)

    def out_hw(self, h: int, w: int) -> tuple[int, int]:
        if self.kind == "fc":
            return (1, 1)
        ho = (h + 2 * self.pad - self.k) // self.stride + 1
        wo = (w + 2 * self.pad - self.k) // self.stride + 1
        return (ho, wo)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """A reconstruction unit for block-wise PTQ (BRECQ granularity).

    ``residual``: add the block input to the main-path output, then relu.
    ``downsample``: optional 1×1 projection on the skip path (also
    quantized — it is a conv layer like any other).
    """

    name: str
    layers: tuple[LayerSpec, ...]
    residual: bool = False
    downsample: Optional[LayerSpec] = None

    def all_layers(self) -> list[LayerSpec]:
        out = list(self.layers)
        if self.downsample is not None:
            out.append(self.downsample)
        return out


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    blocks: tuple[BlockSpec, ...]
    in_hw: tuple[int, int] = (IMG_H, IMG_W)
    in_c: int = IMG_C
    n_classes: int = N_CLASSES

    def all_layers(self) -> list[LayerSpec]:
        out: list[LayerSpec] = []
        for b in self.blocks:
            out.extend(b.all_layers())
        return out

    def layer(self, name: str) -> LayerSpec:
        for l in self.all_layers():
            if l.name == name:
                return l
        raise KeyError(name)

    def shapes(self) -> dict[str, tuple[int, int, int]]:
        """Input (C, H, W) of every layer, plus block in/out shapes."""
        shapes: dict[str, tuple[int, int, int]] = {}
        c, h, w = self.in_c, *self.in_hw
        for b in self.blocks:
            if b.downsample is not None:
                shapes[b.downsample.name] = (c, h, w)
            for l in b.layers:
                shapes[l.name] = (c, h, w)
                h, w = l.out_hw(h, w)
                c = l.oc
        return shapes


def _conv(name, ic, oc, k=3, stride=1, groups=1, relu=True) -> LayerSpec:
    return LayerSpec(
        name=name,
        kind="conv",
        ic=ic,
        oc=oc,
        k=k,
        stride=stride,
        pad=k // 2,
        groups=groups,
        relu=relu,
    )


def _resnet10s() -> ModelDef:
    """Residual family: stem + 4 basic blocks + fc head (~330k params)."""

    def basic(name, ic, oc, stride):
        ds = None
        if stride != 1 or ic != oc:
            ds = LayerSpec(
                name=f"{name}_ds",
                kind="conv",
                ic=ic,
                oc=oc,
                k=1,
                stride=stride,
                pad=0,
                relu=False,
            )
        return BlockSpec(
            name=name,
            layers=(
                _conv(f"{name}_c1", ic, oc, stride=stride),
                _conv(f"{name}_c2", oc, oc, relu=False),
            ),
            residual=True,
            downsample=ds,
        )

    blocks = (
        BlockSpec("stem", ( _conv("stem_c", IMG_C, 24),)),
        basic("b1", 24, 24, 1),
        basic("b2", 24, 48, 2),
        basic("b3", 48, 96, 2),
        basic("b4", 96, 96, 1),
        BlockSpec(
            "head",
            (
                LayerSpec(
                    name="head_fc",
                    kind="fc",
                    ic=96,
                    oc=N_CLASSES,
                    k=1,
                    relu=False,
                    gap_input=True,
                ),
            ),
        ),
    )
    return ModelDef("resnet10s", blocks)


def _mobiles() -> ModelDef:
    """Depthwise-separable family (MobileNet-style), ~30k params."""

    def dsblock(name, ic, oc, stride):
        return BlockSpec(
            name=name,
            layers=(
                _conv(f"{name}_dw", ic, ic, stride=stride, groups=ic),
                _conv(f"{name}_pw", ic, oc, k=1),
            ),
        )

    blocks = (
        BlockSpec("stem", (_conv("stem_c", IMG_C, 16),)),
        dsblock("m1", 16, 32, 1),
        dsblock("m2", 32, 64, 2),
        dsblock("m3", 64, 96, 2),
        BlockSpec(
            "head",
            (
                LayerSpec(
                    name="head_fc",
                    kind="fc",
                    ic=96,
                    oc=N_CLASSES,
                    k=1,
                    relu=False,
                    gap_input=True,
                ),
            ),
        ),
    )
    return ModelDef("mobiles", blocks)


def _regnets() -> ModelDef:
    """Group-convolution family (RegNet-style X block), ~180k params."""

    def xblock(name, ic, oc, stride, groups=4):
        ds = None
        if stride != 1 or ic != oc:
            ds = LayerSpec(
                name=f"{name}_ds",
                kind="conv",
                ic=ic,
                oc=oc,
                k=1,
                stride=stride,
                pad=0,
                relu=False,
            )
        return BlockSpec(
            name=name,
            layers=(
                _conv(f"{name}_a", ic, oc, k=1),
                _conv(f"{name}_b", oc, oc, stride=stride, groups=groups),
                _conv(f"{name}_c", oc, oc, k=1, relu=False),
            ),
            residual=True,
            downsample=ds,
        )

    blocks = (
        BlockSpec("stem", (_conv("stem_c", IMG_C, 32),)),
        xblock("x1", 32, 48, 2),
        xblock("x2", 48, 80, 2),
        BlockSpec(
            "head",
            (
                LayerSpec(
                    name="head_fc",
                    kind="fc",
                    ic=80,
                    oc=N_CLASSES,
                    k=1,
                    relu=False,
                    gap_input=True,
                ),
            ),
        ),
    )
    return ModelDef("regnets", blocks)


MODELS: dict[str, ModelDef] = {
    m.name: m for m in (_resnet10s(), _mobiles(), _regnets())
}


def model_by_name(name: str) -> ModelDef:
    if name not in MODELS:
        raise KeyError(f"unknown model {name!r}; have {sorted(MODELS)}")
    return MODELS[name]
