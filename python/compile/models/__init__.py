"""Model zoo: small CNNs covering the paper's three design families."""

from .defs import LayerSpec, BlockSpec, ModelDef, MODELS, model_by_name  # noqa: F401
