"""Quantization math: uniform quantizers, AdaRound soft weight rounding,
and the paper's adaptive rounding border (AQuant).

Conventions
-----------
* Activations are quantized on the **im2col'd patches** of each layer's
  input — the paper's refactored quantization-node position (Appendix B):
  layer ``l`` receives un-quantized activations and AQuant quantizes them at
  the beginning of ``l``, so gradients of the border parameters see the
  layer's weights.
* The border polynomial is evaluated on ``x / s`` (activation in units of
  the quantization step) rather than raw ``x``. This is a reparametrization
  of the paper's ``b2 x² + b1 x + b0`` (absorb powers of ``s`` into ``b:``)
  that keeps the parameters dimensionless and well-conditioned across
  layers with very different dynamic ranges.
* The border offset is bounded to (-0.5, 0.5) with a sigmoid scaled by 2.5,
  exactly as Appendix B prescribes: ``B = 0.5 + sigmoid(2.5·u) - 0.5``.
* Rounding is ``ceil(x/s − B)`` (Definition 2.1); with ``B = 0.5`` (all
  border parameters zero) this is nearest rounding, so an *uncalibrated*
  border is exactly the rounding-to-nearest baseline.

All functions are pure jnp so they trace into HLO; the Pallas kernel in
``kernels/border_quant.py`` implements the same hard forward for the
inference path and is checked against :func:`act_quant_hard` (via
``kernels/ref.py``) in pytest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Straight-through estimators
# ---------------------------------------------------------------------------


def ceil_ste(u):
    """Ceil with a straight-through gradient (d ceil/du ≈ 1)."""
    return u + jax.lax.stop_gradient(jnp.ceil(u) - u)


def floor_ste(u):
    """Floor with a straight-through gradient."""
    return u + jax.lax.stop_gradient(jnp.floor(u) - u)


# ---------------------------------------------------------------------------
# Border function (the paper's contribution)
# ---------------------------------------------------------------------------


def border_offset(u):
    """Bounded border adjustment in (-0.5, 0.5): ``sigmoid(2.5·u) − 0.5``."""
    return jax.nn.sigmoid(2.5 * u) - 0.5


def border_value(xs, b0, b1, b2, alpha, k2, border_en, fuse_en, b2_en):
    """Evaluate the adaptive rounding border for im2col'd activations.

    Args:
      xs: activations in units of scale, shape ``(N, R, P)`` with
        ``R = i_c·k²`` rows (im2col) and ``P`` output pixels.
      b0, b1, b2, alpha: border parameters, each shape ``(R,)``.
      k2: kernel-size² — the length of each input-channel segment of R.
      border_en: scalar 0/1 — 0 degrades to nearest rounding (B = 0.5).
      fuse_en: scalar 0/1 — border fusion (Eq. 9): per-input-channel
        weighted mean of the element-wise borders.
      b2_en: scalar 0/1 — quadratic (1) vs linear (0) border (Table 4).

    Returns:
      Border tensor broadcastable against ``xs``: ``(N, R, P)``.
    """
    n, r, p = xs.shape
    u = (b2_en * b2)[None, :, None] * xs * xs + b1[None, :, None] * xs + b0[None, :, None]
    be = 0.5 + border_en * border_offset(u)
    # Border fusion: average α_j·B^E_j over each input channel's k² taps and
    # share the fused value within the channel (Eq. 9).
    seg = (alpha[None, :, None] * be).reshape(n, r // k2, k2, p)
    fused = jnp.broadcast_to(jnp.mean(seg, axis=2, keepdims=True), seg.shape)
    fused = fused.reshape(n, r, p)
    return fuse_en * fused + (1.0 - fuse_en) * be


# ---------------------------------------------------------------------------
# Activation quantization
# ---------------------------------------------------------------------------


def act_quant_hard(x, s, b0, b1, b2, alpha, k2, qmin, qmax, border_en, fuse_en, b2_en, aq_en):
    """Hard (inference) activation fake-quant with adaptive border.

    ``x`` is the im2col'd patch tensor ``(N, R, P)``. Returns the
    dequantized tensor of the same shape. ``aq_en = 0`` bypasses
    quantization entirely (W-only settings like W2A32).
    """
    xs = x / s
    border = border_value(xs, b0, b1, b2, alpha, k2, border_en, fuse_en, b2_en)
    q = jnp.clip(jnp.ceil(xs - border), qmin, qmax)
    return aq_en * (s * q) + (1.0 - aq_en) * x


def act_quant_ste(
    x, s, b0, b1, b2, alpha, k2, qmin, qmax, border_en, fuse_en, b2_en, aq_en, alpha_round
):
    """Trainable activation fake-quant (STE) with the rounding schedule.

    Appendix B's rounding schedule: the rounding error is introduced
    gradually, ``x̂ = x + α_round·(quant(x) − x)`` with α_round 0 → 1 over
    finetuning, to stop border-induced rounding flips from destabilizing
    the optimization.
    """
    xs = x / s
    border = border_value(xs, b0, b1, b2, alpha, k2, border_en, fuse_en, b2_en)
    q = jnp.clip(ceil_ste(xs - border), qmin, qmax)
    xq = s * q
    xq = x + alpha_round * (xq - x)
    return aq_en * xq + (1.0 - aq_en) * x


# ---------------------------------------------------------------------------
# Weight quantization (AdaRound-style soft rounding)
# ---------------------------------------------------------------------------


def rect_sigmoid(v):
    """AdaRound's rectified sigmoid h(V) ∈ [0, 1]."""
    return jnp.clip(jax.nn.sigmoid(v) * 1.2 - 0.1, 0.0, 1.0)


def rect_sigmoid_inv(h):
    """Inverse of :func:`rect_sigmoid` on (0, 1) — used for V init."""
    h = jnp.clip(h, 1e-4, 1.0 - 1e-4)
    p = (h + 0.1) / 1.2
    return jnp.log(p / (1.0 - p))


def weight_quant_soft(w, s_w, v, qmin, qmax, wq_en):
    """Soft-quantized weights: ``s·clip(floor(w/s) + h(V), qmin, qmax)``."""
    wq = s_w * jnp.clip(jnp.floor(w / s_w) + rect_sigmoid(v), qmin, qmax)
    return wq_en * wq + (1.0 - wq_en) * w


def weight_quant_hard(w, s_w, v, qmin, qmax, wq_en):
    """Hard weights: the binary solution h(V) ≥ 0.5 → round up."""
    up = (rect_sigmoid(v) >= 0.5).astype(w.dtype)
    wq = s_w * jnp.clip(jnp.floor(w / s_w) + up, qmin, qmax)
    return wq_en * wq + (1.0 - wq_en) * w


def freg(v, beta):
    """AdaRound's rounding regularizer ``Σ 1 − |2h(V) − 1|^β`` (Eq. 4 app)."""
    return jnp.sum(1.0 - jnp.abs(2.0 * rect_sigmoid(v) - 1.0) ** beta)


# ---------------------------------------------------------------------------
# Scale initialization (build-time, weights only — activation scales are
# searched by the Rust coordinator at calibration time)
# ---------------------------------------------------------------------------


def weight_scale_mse(w2d, bits: int, grid: int = 60):
    """Per-output-channel symmetric scale minimizing quantization MSE.

    Args:
      w2d: weights ``(o_c, r)``.
      bits: signed bit-width M; levels in [−2^{M−1}, 2^{M−1} − 1].

    Returns:
      scales ``(o_c, 1)``.
    """
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(w2d), axis=1, keepdims=True) + 1e-12
    best_s = absmax / qmax
    best_err = jnp.full_like(absmax, jnp.inf)
    for i in range(grid):
        frac = 1.0 - 0.8 * i / grid
        s = absmax * frac / qmax
        q = jnp.clip(jnp.round(w2d / s), qmin, qmax)
        err = jnp.sum((s * q - w2d) ** 2, axis=1, keepdims=True)
        best_s = jnp.where(err < best_err, s, best_s)
        best_err = jnp.minimum(err, best_err)
    return best_s


def v_init(w2d, s_w):
    """AdaRound V init: soft quantization reproduces w exactly at start."""
    frac = w2d / s_w - jnp.floor(w2d / s_w)
    return rect_sigmoid_inv(frac)
