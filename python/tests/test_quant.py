"""L2 quantization math: STE forwards, soft/hard weight rounding, scale
search, border properties.

`hypothesis` is optional: environments without it skip this module at
collection instead of erroring (see test_kernel.py)."""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import quant


def test_nearest_border_at_zero_params():
    x = jnp.asarray(np.random.RandomState(0).randn(2, 9, 5), jnp.float32)
    b = quant.border_value(
        x, jnp.zeros(9), jnp.zeros(9), jnp.zeros(9), jnp.ones(9), 9, 1.0, 1.0, 1.0
    )
    np.testing.assert_allclose(np.asarray(b), 0.5, atol=1e-7)


@given(seed=st.integers(0, 1000))
@settings(deadline=None, max_examples=20)
def test_hard_equals_ste_at_alpha1(seed):
    """At α_round = 1 the STE forward equals the hard forward."""
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(2, 12, 7) * 2, jnp.float32)
    args = dict(
        s=0.21,
        b0=jnp.asarray(rng.randn(12) * 0.3, jnp.float32),
        b1=jnp.asarray(rng.randn(12) * 0.3, jnp.float32),
        b2=jnp.asarray(rng.randn(12) * 0.3, jnp.float32),
        alpha=jnp.ones(12),
        k2=4,
        qmin=0.0,
        qmax=15.0,
        border_en=1.0,
        fuse_en=1.0,
        b2_en=1.0,
        aq_en=1.0,
    )
    hard = quant.act_quant_hard(x, **{k: v for k, v in args.items()})
    ste = quant.act_quant_ste(x, **args, alpha_round=1.0)
    np.testing.assert_allclose(np.asarray(hard), np.asarray(ste), atol=1e-6)


def test_rounding_schedule_blends():
    x = jnp.asarray(np.random.RandomState(1).randn(1, 4, 3), jnp.float32)
    kw = dict(
        s=0.3, b0=jnp.zeros(4), b1=jnp.zeros(4), b2=jnp.zeros(4),
        alpha=jnp.ones(4), k2=2, qmin=0.0, qmax=3.0,
        border_en=0.0, fuse_en=0.0, b2_en=0.0, aq_en=1.0,
    )
    at0 = quant.act_quant_ste(x, **kw, alpha_round=0.0)
    at1 = quant.act_quant_ste(x, **kw, alpha_round=1.0)
    athalf = quant.act_quant_ste(x, **kw, alpha_round=0.5)
    np.testing.assert_allclose(np.asarray(at0), np.asarray(x), atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(athalf), 0.5 * np.asarray(x) + 0.5 * np.asarray(at1), atol=1e-6
    )


def test_border_params_receive_gradients():
    """The refactored quantization position must backprop into b: and s."""
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.rand(1, 6, 4) * 3, jnp.float32)
    w = jnp.asarray(rng.randn(5, 6), jnp.float32)

    def loss(b0, b1, s):
        q = quant.act_quant_ste(
            x, s, b0, b1, jnp.zeros(6), jnp.ones(6), 3, 0.0, 15.0,
            1.0, 1.0, 1.0, 1.0, 1.0,
        )
        y = jnp.einsum("or,nrp->nop", w, q)
        return jnp.sum(y * y)

    g0, g1, gs = jax.grad(loss, argnums=(0, 1, 2))(
        jnp.zeros(6), jnp.zeros(6), jnp.asarray(0.2)
    )
    assert float(jnp.abs(g0).sum()) > 0
    assert float(jnp.abs(g1).sum()) > 0
    assert float(jnp.abs(gs)) > 0


@given(seed=st.integers(0, 500), bits=st.sampled_from([2, 3, 4, 8]))
@settings(deadline=None, max_examples=20)
def test_v_init_reproduces_weights(seed, bits):
    """Soft quantization at V init must reproduce the FP weights exactly
    (AdaRound's starting point)."""
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(4, 10) * 0.5, jnp.float32)
    s = quant.weight_scale_mse(w, bits)
    v = quant.v_init(w, s)
    qmin, qmax = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    soft = quant.weight_quant_soft(w, s, v, qmin, qmax, 1.0)
    # exact only when the weight is representable in range; allow the
    # clipped tail to deviate
    clipped = np.abs(np.asarray(w / s)) > (qmax - 1)
    diff = np.abs(np.asarray(soft - w))
    if (~clipped).any():
        assert diff[~clipped].max() < 2e-3


def test_hard_weights_on_grid():
    rng = np.random.RandomState(3)
    w = jnp.asarray(rng.randn(3, 8), jnp.float32)
    s = quant.weight_scale_mse(w, 2)
    v = quant.v_init(w, s)
    hard = quant.weight_quant_hard(w, s, v, -2.0, 1.0, 1.0)
    codes = np.asarray(hard / s)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert codes.min() >= -2.0 - 1e-5 and codes.max() <= 1.0 + 1e-5


def test_weight_scale_beats_absmax():
    rng = np.random.RandomState(4)
    w = jnp.asarray(
        np.concatenate([rng.randn(1, 63) * 0.1, [[4.0]]], axis=1), jnp.float32
    )
    s_opt = quant.weight_scale_mse(w, 4)
    qmin, qmax = -8, 7
    s_naive = jnp.max(jnp.abs(w), axis=1, keepdims=True) / qmax

    def mse(s):
        q = jnp.clip(jnp.round(w / s), qmin, qmax)
        return float(jnp.sum((s * q - w) ** 2))

    assert mse(s_opt) <= mse(s_naive) + 1e-7


def test_freg_converges_to_zero_at_binary():
    v = jnp.asarray([[-20.0, 20.0]])
    assert float(quant.freg(v, 2.0)) < 1e-6
    v_mid = jnp.asarray([[0.0]])
    assert float(quant.freg(v_mid, 2.0)) > 0.9
