"""Producer-side contract checks on artifacts/manifest.json (skipped when
artifacts have not been built). The Rust consumer trusts exactly these
invariants."""

import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_every_program_file_exists(manifest):
    for name, p in manifest["programs"].items():
        path = os.path.join(ART, p["path"])
        assert os.path.exists(path), f"{name}: missing {p['path']}"
        assert os.path.getsize(path) > 100


def test_arg_roles_are_known(manifest):
    roles = {"w", "state", "adam", "batch", "hyper"}
    for name, p in manifest["programs"].items():
        for a in p["args"]:
            role = a["name"].split(":")[0]
            assert role in roles, f"{name}: bad arg {a['name']}"
            assert isinstance(a["shape"], list)
            assert a["dtype"] == "f32"


def test_step_programs_return_their_state(manifest):
    """Every step program's state/adam results must be a subset of its
    args with identical shapes (the coordinator writes back by name)."""
    for name, p in manifest["programs"].items():
        if not name.startswith("step_"):
            continue
        args = {a["name"]: a["shape"] for a in p["args"]}
        losses = 0
        for r in p["results"]:
            if r["name"] == "out:loss":
                losses += 1
                assert r["shape"] == []
                continue
            assert r["name"] in args, f"{name}: result {r['name']} not an arg"
            assert r["shape"] == args[r["name"]], f"{name}: shape drift {r['name']}"
        assert losses == 1


def test_knobs_convention_matches_ptq(manifest):
    from compile import ptq

    assert manifest["meta"]["knobs"] == ptq.KNOBS
    for name, p in manifest["programs"].items():
        for a in p["args"]:
            if a["name"] == "hyper:knobs":
                assert a["shape"] == [len(ptq.KNOBS)], name


def test_models_meta_consistent_with_zoo(manifest):
    from compile.models import MODELS

    meta = manifest["meta"]["models"]
    assert set(meta) <= set(MODELS)
    for name, m in meta.items():
        model = MODELS[name]
        flat = [l for b in m["blocks"] for l in b["layers"]]
        assert len(flat) == len(model.all_layers())
        for lm, l in zip(flat, model.all_layers()):
            assert lm["name"] == l.name
            assert lm["rows"] == l.rows
            assert tuple(lm["in_chw"])[0] == l.ic


def test_weight_files_have_exact_sizes(manifest):
    for model, layers in manifest["meta"]["weights"].items():
        for lname, m in layers.items():
            w = os.path.join(ART, m["w"])
            n = 1
            for d in m["w_shape"]:
                n *= d
            assert os.path.getsize(w) == 4 * n, f"{model}/{lname}"
