"""Model math: im2col-matmul forward == lax.conv, BN folding, block/step
builders, and the data generator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from compile import data as data_mod
from compile import ptq
from compile.models import MODELS
from compile.models.defs import BlockSpec, LayerSpec
from compile.models.forward import (
    extract_patches,
    fold_bn,
    init_params,
    layer_forward,
    model_forward,
    train_forward,
)


@pytest.mark.parametrize("groups,k,stride", [(1, 3, 1), (1, 3, 2), (4, 3, 1), (8, 3, 2), (1, 1, 1)])
def test_patches_matmul_matches_lax_conv(groups, k, stride):
    rng = np.random.RandomState(0)
    ic, oc, h = 8, 8, 10
    l = LayerSpec(
        name="t", kind="conv", ic=ic, oc=oc, k=k, stride=stride,
        pad=k // 2, groups=groups, relu=False,
    )
    x = jnp.asarray(rng.randn(2, ic, h, h), jnp.float32)
    w4 = jnp.asarray(rng.randn(oc, ic // groups, k, k), jnp.float32)
    b = jnp.asarray(rng.randn(oc), jnp.float32)
    ref = lax.conv_general_dilated(
        x, w4, (stride, stride), [(k // 2, k // 2)] * 2, feature_group_count=groups
    ) + b[None, :, None, None]
    got = layer_forward(l, w4.reshape(oc, -1), b, x, apply_relu=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", sorted(MODELS))
def test_folded_forward_matches_eval_bn(name):
    model = MODELS[name]
    params = init_params(model, 3)
    # make running stats non-trivial
    for l in model.all_layers():
        rng = np.random.RandomState(hash(l.name) % 1000)
        params[l.name]["rmean"] = jnp.asarray(rng.randn(l.oc) * 0.1, jnp.float32)
        params[l.name]["rvar"] = jnp.asarray(1.0 + rng.rand(l.oc), jnp.float32)
    x = jnp.asarray(np.random.RandomState(0).randn(2, model.in_c, *model.in_hw), jnp.float32)
    ref, _ = train_forward(model, params, x, train=False)
    folded = fold_bn(model, params)
    got = model_forward(model, folded, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_patches_shape_and_rows_order():
    l = LayerSpec(name="t", kind="conv", ic=2, oc=2, k=3, stride=1, pad=1)
    x = jnp.arange(2 * 4 * 4, dtype=jnp.float32).reshape(1, 2, 4, 4)
    pm = extract_patches(l, x)
    assert pm.shape == (1, 18, 16)
    # channel-major rows: row c*9+4 (center tap) at pixel p equals x[c, p]
    for c in range(2):
        np.testing.assert_allclose(
            np.asarray(pm[0, c * 9 + 4, :]), np.asarray(x[0, c].reshape(-1))
        )


def test_block_step_improves_loss():
    """A few optimizer steps on a single-layer block must reduce the
    reconstruction MSE (end-to-end sanity of the step builder)."""
    model = MODELS["mobiles"]
    blk = model.blocks[0]  # stem
    fn, args, res_names = ptq.make_block_step(model, blk)
    jfn = jax.jit(fn)
    rng = np.random.RandomState(0)

    vals = {}
    l = blk.layers[0]
    w = rng.randn(*l.weight_shape).astype(np.float32) * 0.3
    vals[f"w:{l.name}.w"] = w
    vals[f"w:{l.name}.b"] = np.zeros(l.oc, np.float32)
    from compile import quant

    s_w = np.asarray(quant.weight_scale_mse(jnp.asarray(w), 4))
    vals[f"state:{l.name}.V"] = np.asarray(quant.v_init(jnp.asarray(w), jnp.asarray(s_w)))
    vals[f"state:{l.name}.s_w"] = s_w
    vals[f"state:{l.name}.s_a"] = np.float32(0.05)
    bp = np.zeros((l.rows, 4), np.float32)
    bp[:, 3] = 1.0
    vals[f"state:{l.name}.bp"] = bp
    for leaf in ("V", "s_a", "bp"):
        shp = ptq.layer_state_shapes(l)[leaf]
        vals[f"adam:{l.name}.{leaf}.m"] = np.zeros(shp, np.float32)
        vals[f"adam:{l.name}.{leaf}.v"] = np.zeros(shp, np.float32)
    vals["adam:t"] = np.float32(0)
    b = ptq.BATCH_CALIB
    x = (rng.rand(b, l.ic, 24, 24) * 2).astype(np.float32)
    vals["batch:x_in"] = x
    vals["batch:x_fp"] = x
    # FP target
    y = layer_forward(
        l, jnp.asarray(w), jnp.zeros(l.oc), jnp.asarray(x), apply_relu=True
    )
    vals["batch:y_fp"] = np.asarray(y)
    vals["batch:mask"] = np.zeros_like(x)
    vals["hyper:bits"] = np.asarray([[-8.0, 7.0, -8.0, 7.0]], np.float32)
    knobs = np.zeros(len(ptq.KNOBS), np.float32)
    knobs[ptq.K["lr_v"]] = 3e-3
    knobs[ptq.K["lr_s"]] = 4e-5
    knobs[ptq.K["lr_b"]] = 1e-3
    knobs[ptq.K["alpha_round"]] = 1.0
    knobs[ptq.K["beta"]] = 20.0
    knobs[ptq.K["lam"]] = 0.0
    for k in ("wq_en", "aq_en", "border_en", "fuse_en", "b2_en"):
        knobs[ptq.K[k]] = 1.0
    vals["hyper:knobs"] = knobs

    flat = [jnp.asarray(vals[a.name]) for a in args]
    losses = []
    for _ in range(60):
        outs = jfn(*flat)
        by_name = dict(zip(res_names, outs))
        losses.append(float(by_name["out:loss"]))
        for i, a in enumerate(args):
            if a.name in by_name:
                flat[i] = by_name[a.name]
    assert losses[-1] < losses[0] * 0.98, f"loss did not improve: {losses[0]} -> {losses[-1]}"


def test_data_deterministic_and_balanced():
    a = data_mod.generate(64, 42)
    b = data_mod.generate(64, 42)
    np.testing.assert_array_equal(a.images, b.images)
    np.testing.assert_array_equal(a.labels, b.labels)
    c = data_mod.generate(64, 43)
    assert not np.array_equal(a.images, c.images)
    big = data_mod.generate(2000, 7)
    counts = np.bincount(big.labels, minlength=data_mod.N_CLASSES)
    assert counts.min() > 0.4 * counts.mean()


def test_model_shapes_consistent():
    for model in MODELS.values():
        shapes = model.shapes()
        for blk in model.blocks:
            for l in blk.all_layers():
                c, h, w = shapes[l.name]
                assert c == l.ic, f"{model.name}/{l.name}"
                ho, wo = l.out_hw(h, w)
                assert ho > 0 and wo > 0
