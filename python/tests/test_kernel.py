"""L1 correctness: the Pallas fused border-quantization kernel against the
pure-jnp oracle, swept over shapes and parameter regimes with hypothesis.
This is the CORE correctness signal for the inference path.

`hypothesis` is optional: environments without it (some containers)
skip this module at collection instead of erroring, so the rest of the
suite still runs."""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.border_quant import border_quant_pallas, make_scalars
from compile.kernels.ref import border_quant_ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def run_both(x, params, scalars, k2, tile_p=64):
    got = border_quant_pallas(
        jnp.asarray(x), jnp.asarray(params), jnp.asarray(scalars), k2, tile_p=tile_p
    )
    want = border_quant_ref(
        jnp.asarray(x), jnp.asarray(params), jnp.asarray(scalars), k2
    )
    return np.asarray(got), np.asarray(want)


@given(
    n=st.integers(1, 3),
    ic=st.integers(1, 6),
    k=st.sampled_from([1, 3]),
    p=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
    border_en=st.booleans(),
    fuse_en=st.booleans(),
    b2_en=st.booleans(),
)
def test_kernel_matches_ref(n, ic, k, p, seed, border_en, fuse_en, b2_en):
    rng = np.random.RandomState(seed % 100000)
    k2 = k * k
    r = ic * k2
    x = rng.randn(n, r, p).astype(np.float32) * 2.0
    params = (rng.randn(r, 4) * 0.5).astype(np.float32)
    scalars = make_scalars(
        s=0.17, qmin=0.0, qmax=15.0,
        border_en=float(border_en), fuse_en=float(fuse_en),
        b2_en=float(b2_en), aq_en=1.0,
    )
    got, want = run_both(x, params, scalars, k2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    p=st.integers(1, 200),
    tile=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 10_000),
)
def test_kernel_tile_invariance(p, tile, seed):
    """Result must not depend on the tile size (padding is masked off)."""
    rng = np.random.RandomState(seed)
    r, k2 = 18, 9
    x = rng.randn(2, r, p).astype(np.float32)
    params = (rng.randn(r, 4) * 0.3).astype(np.float32)
    scalars = make_scalars(0.1, 0.0, 7.0)
    a, _ = run_both(x, params, scalars, k2, tile_p=tile)
    b, _ = run_both(x, params, scalars, k2, tile_p=257)
    np.testing.assert_array_equal(a, b)


def test_zero_params_is_nearest_rounding():
    """All-zero border params + border_en must equal nearest rounding."""
    rng = np.random.RandomState(0)
    x = rng.randn(2, 9, 33).astype(np.float32)
    params = np.zeros((9, 4), np.float32)
    params[:, 3] = 1.0  # alpha
    s = 0.2
    for flags in [(1.0, 1.0, 1.0), (0.0, 0.0, 0.0), (1.0, 0.0, 1.0)]:
        scalars = make_scalars(s, 0.0, 15.0, *flags)
        got, _ = run_both(x, params, scalars, 9)
        want = s * np.clip(np.ceil(x / s - 0.5), 0.0, 15.0)
        np.testing.assert_allclose(got, want, atol=1e-6)


def test_aq_disabled_is_identity():
    rng = np.random.RandomState(1)
    x = rng.randn(1, 12, 10).astype(np.float32)
    params = (rng.randn(12, 4) * 0.5).astype(np.float32)
    scalars = make_scalars(0.3, 0.0, 3.0, aq_en=0.0)
    got, _ = run_both(x, params, scalars, 4)
    np.testing.assert_allclose(got, x, atol=1e-7)


def test_output_on_quant_grid():
    """Quantized outputs must be multiples of s within [qmin, qmax]·s."""
    rng = np.random.RandomState(2)
    x = (rng.randn(2, 27, 21) * 3).astype(np.float32)
    params = (rng.randn(27, 4) * 0.4).astype(np.float32)
    s = 0.25
    scalars = make_scalars(s, 0.0, 15.0)
    got, _ = run_both(x, params, scalars, 9)
    codes = got / s
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)
    assert codes.min() >= -1e-4 and codes.max() <= 15.0 + 1e-4


def test_fusion_shares_border_within_channel():
    """With fusion, all k² taps of an input channel share one border, so
    equal inputs in a channel quantize identically."""
    rng = np.random.RandomState(3)
    ic, k2, p = 3, 9, 5
    r = ic * k2
    # same value within each channel segment
    base = rng.rand(1, ic, 1, p).astype(np.float32) * 2
    x = np.broadcast_to(base, (1, ic, k2, p)).reshape(1, r, p).copy()
    params = (rng.randn(r, 4) * 0.5).astype(np.float32)
    scalars = make_scalars(0.11, 0.0, 15.0, fuse_en=1.0)
    got, _ = run_both(x, params, scalars, k2)
    got = got.reshape(1, ic, k2, p)
    for c in range(ic):
        for j in range(1, k2):
            np.testing.assert_array_equal(got[0, c, 0], got[0, c, j])


def test_rejects_bad_segments():
    x = jnp.zeros((1, 10, 4))
    params = jnp.zeros((10, 4))
    with pytest.raises(ValueError):
        border_quant_pallas(x, params, make_scalars(1.0, 0.0, 3.0), k2=3)
