//! Reload conformance: control-plane registry swaps under live
//! traffic. (Shared scaffolding in `common.rs`.)
//!
//! The acceptance invariants (ISSUE 10):
//!   * an `add` landing under a 256-connection mixed v1/v2 load drops
//!     ZERO connections, and every answer stays bit-identical to the
//!     named model's sequential engine — before, during, and after
//!     the swap;
//!   * a request queued before `remove` is still answered from the
//!     OLD engine (tombstone drain), while fresh requests for the
//!     removed id get the unknown-model close; re-adding the name
//!     assigns a fresh id;
//!   * malformed admin lines are rejected with `err ...` replies and
//!     change nothing; an overlong line closes only the admin
//!     connection, never the serving plane.
//!
//! This suite deliberately re-declares the admin wire constants
//! instead of importing them, so it speaks the raw protocol a human
//! operator would type over `nc`. `scripts/static_triage.py` (check 8)
//! cross-checks these mirrors against `rust/src/server/mod.rs` — a
//! drifted rename fails triage instead of silently hanging this suite
//! against the wrong protocol.

mod common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use aquant::config::ServeConfig;
use aquant::nn::registry::ModelRegistry;
use aquant::nn::synth;
use aquant::server::metrics::Snapshot;
use aquant::server::{classify_on, classify_on_v2, encode_header_v2};
use aquant::util::rng::Rng;

use common::{expect_closed, expected, random_images, start_with_admin, Watchdog};

// Wire-protocol mirrors (see module doc; triage check 8 pins these to
// rust/src/server/mod.rs).
const ADMIN_CMD_ADD: &str = "add";
const ADMIN_CMD_REMOVE: &str = "remove";
const ADMIN_CMD_POLICY: &str = "policy";
const ADMIN_CMD_RELOAD: &str = "reload";
const ADMIN_OK: &str = "ok";
const ADMIN_ERR: &str = "err";
const MAX_ADMIN_LINE: usize = 4096;

/// Read one reply line (without the trailing `\n`) off an admin
/// connection. Panics if the server closes mid-line.
fn read_line(s: &mut TcpStream) -> String {
    let mut out = Vec::new();
    let mut b = [0u8; 1];
    loop {
        match s.read(&mut b).unwrap() {
            1 if b[0] == b'\n' => break,
            1 => out.push(b[0]),
            _ => panic!("admin connection closed mid-line (got {out:?})"),
        }
    }
    String::from_utf8(out).expect("admin replies are utf-8")
}

/// Send one admin command line and return its reply line.
fn admin_cmd(s: &mut TcpStream, line: &str) -> String {
    s.write_all(line.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
    read_line(s)
}

fn two_model_cfg(max_accepts: usize) -> ServeConfig {
    ServeConfig {
        workers: 4,
        max_batch: 8,
        batch_wait_us: 200,
        max_accepts: Some(max_accepts),
        admin_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    }
}

/// Tentpole invariant: hot-`add` under a 256-connection mixed v1/v2
/// load. Every connection runs to completion (read_response panics on
/// a dropped one), every answer is bit-identical to its model's
/// sequential engine, and afterwards the added model serves correctly
/// on a fresh slot while the survivors are byte-for-byte unchanged.
#[test]
fn add_under_mixed_load_is_dropless_and_bit_identical() {
    let _wd = Watchdog::arm("add_under_mixed_load_is_dropless_and_bit_identical", Duration::from_secs(120));
    let a = Arc::new(synth::engine_from_spec("tiny", 11).unwrap());
    let b = Arc::new(synth::engine_from_spec("bench", 22).unwrap());
    let engines = vec![a.clone(), b.clone()];
    let registry =
        Arc::new(ModelRegistry::new(vec![("a".into(), a), ("b".into(), b)]).unwrap());

    let (n_clients, rounds, batch) = (256usize, 6usize, 2usize);
    // exact accounting: 256 load connections + 2 post-swap verify
    // connections; the admin connection does NOT count toward accepts
    let (addr, admin_addr, stats, server) = start_with_admin(registry, two_model_cfg(n_clients + 2));

    let mut clients = Vec::new();
    for c in 0..n_clients {
        let engines = engines.clone();
        clients.push(std::thread::spawn(move || {
            // stagger connects so 256 SYNs don't slam the backlog at once
            std::thread::sleep(Duration::from_millis((c % 32) as u64));
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(9_000 + c as u64);
            let id = (c % 2) as u16;
            let eng = &engines[id as usize];
            for r in 0..rounds {
                let images = random_images(&mut rng, batch, eng.img_elems());
                // even clients exercise the v1 framing (default model 0)
                let got = if id == 0 && r % 2 == 0 {
                    classify_on(&mut stream, &images, batch).unwrap()
                } else {
                    classify_on_v2(&mut stream, id, &images, batch).unwrap()
                };
                assert_eq!(got, expected(eng, &images, batch), "client {c} req {r}");
                std::thread::sleep(Duration::from_millis(3));
            }
        }));
    }

    // Land the swap mid-load: the staggered connects + per-round
    // sleeps keep traffic in flight well past this point.
    std::thread::sleep(Duration::from_millis(15));
    let mut admin = TcpStream::connect(admin_addr).unwrap();
    let reply = admin_cmd(&mut admin, &format!("{ADMIN_CMD_ADD} c=synth:tiny:7"));
    assert_eq!(reply, format!("{ADMIN_OK} epoch=1 models=3"));

    for c in clients {
        c.join().unwrap(); // any dropped/short-read connection panics here
    }

    // The added model serves on the fresh slot (id 2), bit-identical
    // to a locally built engine from the same spec...
    let added = synth::engine_from_spec("tiny", 7).unwrap();
    let mut rng = Rng::new(77);
    let images = random_images(&mut rng, 3, added.img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    let got = classify_on_v2(&mut s, 2, &images, 3).unwrap();
    assert_eq!(got, expected(&added, &images, 3), "hot-added model");
    drop(s);
    // ...and a surviving model is byte-for-byte unchanged post-swap.
    let images = random_images(&mut rng, 3, engines[0].img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    let got = classify_on_v2(&mut s, 0, &images, 3).unwrap();
    assert_eq!(got, expected(&engines[0], &images, 3), "surviving model");
    drop(s);
    drop(admin);
    server.join().unwrap().unwrap();

    // zero-drop, in numbers: nothing rejected, nothing refused
    assert_eq!(stats.total_rejected(), 0);
    assert_eq!(stats.conns_rejected.load(Ordering::Relaxed), 0);
    assert_eq!(stats.registry_epoch.load(Ordering::Relaxed), 1);
    assert_eq!(stats.reloads.load(Ordering::Relaxed), 1);
    assert_eq!(stats.model(2).unwrap().requests.load(Ordering::Relaxed), 1);
}

/// Retune, remove, drain, re-add: a request queued before `remove`
/// is answered from the OLD engine; fresh requests for the removed id
/// are rejected while it drains; re-adding the name gets a NEW id;
/// the swap history is visible in the stats snapshot.
#[test]
fn remove_drains_from_old_engine_and_rejects_new_requests() {
    let _wd = Watchdog::arm("remove_drains_from_old_engine_and_rejects_new_requests", Duration::from_secs(60));
    let a = Arc::new(synth::engine_from_spec("tiny", 11).unwrap());
    let b = Arc::new(synth::engine_from_spec("bench", 22).unwrap());
    let registry = Arc::new(
        ModelRegistry::new(vec![("a".into(), a.clone()), ("b".into(), b.clone())]).unwrap(),
    );
    // exactly 4 client connections: drain, rejected, re-added verify,
    // survivor verify
    let (addr, admin_addr, stats, server) = start_with_admin(registry, two_model_cfg(4));
    let mut admin = TcpStream::connect(admin_addr).unwrap();

    // live policy retune lands on the gauges immediately
    let reply = admin_cmd(&mut admin, &format!("{ADMIN_CMD_POLICY} b weight=5"));
    assert_eq!(reply, format!("{ADMIN_OK} epoch=1 models=2"));
    assert_eq!(stats.model(1).unwrap().weight.load(Ordering::Relaxed), 5);

    // park the next b request on the straggler deadline so it is
    // still queued when the remove lands
    let reply = admin_cmd(&mut admin, &format!("{ADMIN_CMD_POLICY} b batch_wait_us=300000"));
    assert_eq!(reply, format!("{ADMIN_OK} epoch=2 models=2"));

    let b_drain = {
        let b = b.clone();
        std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(41);
            let images = random_images(&mut rng, 2, b.img_elems());
            // enqueued now; admitted only after the 300ms straggler
            // wait — i.e. strictly after the remove below
            let got = classify_on_v2(&mut s, 1, &images, 2).unwrap();
            assert_eq!(got, expected(&b, &images, 2), "drained from old engine");
        })
    };
    std::thread::sleep(Duration::from_millis(80));

    let reply = admin_cmd(&mut admin, &format!("{ADMIN_CMD_REMOVE} b"));
    assert_eq!(reply, format!("{ADMIN_OK} epoch=3 models=1"));

    // a FRESH request for the tombstoned id gets the unknown-model
    // close, even while its queue is still draining
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&encode_header_v2(1, 1)).unwrap();
    expect_closed(s);

    b_drain.join().unwrap(); // the queued request was answered, bit-identical

    // re-adding the name assigns a fresh slot: id 2, not a reuse of 1
    let reply = admin_cmd(&mut admin, &format!("{ADMIN_CMD_ADD} b=synth:tiny:33"));
    assert_eq!(reply, format!("{ADMIN_OK} epoch=4 models=2"));
    let readded = synth::engine_from_spec("tiny", 33).unwrap();
    let mut rng = Rng::new(43);
    let images = random_images(&mut rng, 2, readded.img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    let got = classify_on_v2(&mut s, 2, &images, 2).unwrap();
    assert_eq!(got, expected(&readded, &images, 2), "re-added model, new id");
    drop(s);

    // the untouched model is byte-for-byte unchanged through all four swaps
    let images = random_images(&mut rng, 2, a.img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    let got = classify_on_v2(&mut s, 0, &images, 2).unwrap();
    assert_eq!(got, expected(&a, &images, 2), "survivor after 4 swaps");
    drop(s);
    drop(admin);
    server.join().unwrap().unwrap();

    assert_eq!(stats.unknown_model.load(Ordering::Relaxed), 1);
    let snap = Snapshot::collect(&stats);
    assert_eq!(snap.registry_epoch, 4);
    assert_eq!(snap.reloads, 4);
    assert_eq!(snap.models.len(), 3, "rows are append-only across remove/re-add");
    assert_eq!(snap.models[0].added_at_epoch, 0);
    assert_eq!(snap.models[1].name, "b"); // the tombstoned slot stays visible
    assert_eq!(snap.models[2].name, "b");
    assert_eq!(snap.models[2].added_at_epoch, 4);
}

/// Malformed admin input: every bad line gets an `err ...` reply and
/// changes nothing; an overlong line closes only that admin
/// connection; blank lines are keep-alives; serving stays bit-identical
/// throughout.
#[test]
fn malformed_admin_lines_are_rejected_without_side_effects() {
    let _wd = Watchdog::arm("malformed_admin_lines_are_rejected_without_side_effects", Duration::from_secs(60));
    let a = Arc::new(synth::engine_from_spec("tiny", 11).unwrap());
    let registry = Arc::new(ModelRegistry::new(vec![("a".into(), a.clone())]).unwrap());
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(1),
        admin_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    };
    let (addr, admin_addr, stats, server) = start_with_admin(registry, cfg);

    let mut admin = TcpStream::connect(admin_addr).unwrap();
    for bad in [
        "frobnicate".to_string(),
        ADMIN_CMD_ADD.to_string(),                  // no spec
        format!("{ADMIN_CMD_ADD} a=synth:tiny"),    // duplicate live name
        format!("{ADMIN_CMD_REMOVE} a b"),          // two names
        format!("{ADMIN_CMD_REMOVE} nope"),         // unknown name
        format!("{ADMIN_CMD_POLICY} a"),            // no key=value pairs
        format!("{ADMIN_CMD_RELOAD} now"),          // reload takes no args
    ] {
        let reply = admin_cmd(&mut admin, &bad);
        assert!(
            reply.starts_with(ADMIN_ERR) && reply.len() > ADMIN_ERR.len(),
            "{bad:?} -> {reply:?} (want `{ADMIN_ERR} <reason>`)"
        );
    }
    // non-utf-8 bytes on the wire get a protocol-level err, not a close
    admin.write_all(&[0xff, 0xfe, b'\n']).unwrap();
    assert_eq!(read_line(&mut admin), format!("{ADMIN_ERR} command is not valid utf-8"));
    // none of the rejected commands moved the epoch
    let reply = admin_cmd(&mut admin, ADMIN_CMD_RELOAD);
    assert_eq!(reply, format!("{ADMIN_OK} epoch=1 models=1"));
    drop(admin);

    // an overlong line (no newline within the cap) gets one final err
    // and a close — on THIS connection only
    let mut admin = TcpStream::connect(admin_addr).unwrap();
    admin.write_all(&vec![b'x'; MAX_ADMIN_LINE + 1000]).unwrap();
    assert_eq!(
        read_line(&mut admin),
        format!("{ADMIN_ERR} line exceeds {MAX_ADMIN_LINE} bytes")
    );
    let mut one = [0u8; 1];
    assert!(
        matches!(admin.read(&mut one), Ok(0) | Err(_)),
        "overlong-line connection must be closed"
    );

    // blank lines are keep-alives: no reply, next command still answered
    let mut admin = TcpStream::connect(admin_addr).unwrap();
    admin.write_all(b"\n").unwrap();
    let reply = admin_cmd(&mut admin, ADMIN_CMD_RELOAD);
    assert_eq!(reply, format!("{ADMIN_OK} epoch=2 models=1"));

    // the serving plane never noticed any of it
    let mut rng = Rng::new(5);
    let images = random_images(&mut rng, 2, a.img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    let got = classify_on(&mut s, &images, 2).unwrap();
    assert_eq!(got, expected(&a, &images, 2));
    drop(s);
    drop(admin);
    server.join().unwrap().unwrap();

    assert_eq!(stats.reloads.load(Ordering::Relaxed), 2);
    assert_eq!(stats.registry_epoch.load(Ordering::Relaxed), 2);
    assert_eq!(stats.total_rejected(), 0);
}
