//! Multi-model serving integration tests: ≥2 synthetic models of
//! different shapes behind one TCP server and ONE shared worker pool.
//! (Shared scaffolding in `common.rs`.)
//!
//! The acceptance invariant: for concurrent mixed-model traffic, every
//! served prediction is bit-identical to the named model's sequential
//! `Engine::classify_batch`, v1 clients keep being served by the
//! default model, and per-model stats/queues stay independent.

mod common;

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use aquant::config::{PolicyOverrides, ServeConfig};
use aquant::nn::engine::Engine;
use aquant::nn::registry::ModelRegistry;
use aquant::nn::synth;
use aquant::server::{
    classify_on, classify_on_v2, classify_remote, classify_remote_v2, encode_header_v2,
    RequestHeader,
};
use aquant::util::rng::Rng;

use common::{expect_closed, expected, random_images, start};

/// Two models with different input dims and class counts: tiny
/// (3x8x8 -> 5 classes) and bench (3x16x16 -> 10 classes), both with
/// learned borders so the full quantized hot path is served.
fn two_model_registry() -> (Arc<ModelRegistry>, Vec<Arc<Engine>>) {
    let a = Arc::new(synth::engine_from_spec("tiny", 11).unwrap());
    let b = Arc::new(synth::engine_from_spec("bench", 22).unwrap());
    assert_ne!(a.img_elems(), b.img_elems(), "test needs heterogeneous dims");
    let engines = vec![a.clone(), b.clone()];
    let reg = ModelRegistry::new(vec![("tiny".into(), a), ("bench".into(), b)]).unwrap();
    (Arc::new(reg), engines)
}

#[test]
fn interleaved_mixed_model_traffic_is_bit_identical() {
    let (registry, engines) = two_model_registry();
    let (n_clients, reqs_per_client, batch) = (6usize, 4usize, 3usize);
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 8,
        batch_wait_us: 300,
        max_accepts: Some(n_clients),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(registry, cfg);

    // Even clients exercise v1 (default model), odd clients v2 model 1;
    // every client also interleaves a v2 request to the *other* model on
    // the same connection, so one stream mixes models and framings.
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let engines = engines.clone();
        clients.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(7000 + c as u64);
            let primary = (c % 2) as u16;
            let other = 1 - primary;
            for r in 0..reqs_per_client {
                let eng = &engines[primary as usize];
                let images = random_images(&mut rng, batch, eng.img_elems());
                let got = if primary == 0 && r % 2 == 0 {
                    classify_on(&mut stream, &images, batch).unwrap() // v1 path
                } else {
                    classify_on_v2(&mut stream, primary, &images, batch).unwrap()
                };
                assert_eq!(got, expected(eng, &images, batch), "client {c} req {r}");

                let eng = &engines[other as usize];
                let images = random_images(&mut rng, 2, eng.img_elems());
                let got = classify_on_v2(&mut stream, other, &images, 2).unwrap();
                assert_eq!(got, expected(eng, &images, 2), "client {c} other-model req {r}");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    server.join().unwrap().unwrap();

    // Per-model accounting: each model saw every client once per round.
    let per_client_imgs = reqs_per_client * batch + reqs_per_client * 2;
    let total: u64 = (n_clients * per_client_imgs) as u64;
    let m0 = stats.model(0).unwrap();
    let m1 = stats.model(1).unwrap();
    assert_eq!(stats.total_images(), total);
    assert!(m0.images.load(Ordering::Relaxed) > 0);
    assert!(m1.images.load(Ordering::Relaxed) > 0);
    assert_eq!(
        stats.total_requests(),
        (n_clients * reqs_per_client * 2) as u64
    );
    assert_eq!(stats.total_rejected(), 0);
    let report = stats.report();
    assert!(report.contains("model 0 tiny:"), "{report}");
    assert!(report.contains("model 1 bench:"), "{report}");
}

#[test]
fn v1_clients_get_the_default_model() {
    let (registry, engines) = two_model_registry();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(2),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(registry, cfg);
    let a = addr.to_string();

    let mut rng = Rng::new(31);
    // bare v1 header -> model 0 (tiny), even though model 1 exists
    let images = random_images(&mut rng, 3, engines[0].img_elems());
    let got = classify_remote(&a, &images, 3).unwrap();
    assert_eq!(got, expected(&engines[0], &images, 3));
    // explicit v2 to model 0 gives the same answers as v1
    let got2 = classify_remote_v2(&a, 0, &images, 3).unwrap();
    assert_eq!(got, got2);

    server.join().unwrap().unwrap();
    let m0 = stats.model(0).unwrap();
    assert_eq!(m0.requests.load(Ordering::Relaxed), 2);
    assert_eq!(stats.model(1).unwrap().requests.load(Ordering::Relaxed), 0);
}

#[test]
fn unknown_model_and_bad_version_close_only_that_connection() {
    let (registry, engines) = two_model_registry();
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(5),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(registry, cfg);
    let a = addr.to_string();

    // unknown model id (registry has ids 0 and 1)
    let mut s = TcpStream::connect(&a).unwrap();
    s.write_all(&encode_header_v2(9, 1)).unwrap();
    expect_closed(s);

    // unsupported version: a well-formed v2 frame claiming version 1
    let mut s = TcpStream::connect(&a).unwrap();
    let hdr = RequestHeader::V2 {
        version: 1,
        model_id: 0,
        n: 1,
    }
    .encode();
    s.write_all(&hdr).unwrap();
    expect_closed(s);

    // v2 header truncated mid-frame
    let mut s = TcpStream::connect(&a).unwrap();
    s.write_all(&encode_header_v2(1, 2)[..7]).unwrap();
    drop(s);

    // the server still answers both models on fresh connections
    let mut rng = Rng::new(5);
    for id in [0u16, 1] {
        let eng = &engines[id as usize];
        let images = random_images(&mut rng, 2, eng.img_elems());
        let got = classify_remote_v2(&a, id, &images, 2).unwrap();
        assert_eq!(got, expected(eng, &images, 2), "model {id} after bad conns");
    }

    server.join().unwrap().unwrap();
    assert_eq!(stats.unknown_model.load(Ordering::Relaxed), 1);
    assert_eq!(stats.bad_version.load(Ordering::Relaxed), 1);
    assert_eq!(stats.total_rejected(), 2);
    assert_eq!(stats.total_requests(), 2);
}

#[test]
fn many_models_shared_pool_round_robin() {
    // Four models (two shapes x two seeds): same-shape models must
    // still route to *their own* weights — distinguishable predictions
    // come from distinct seeds, and identity is checked per model.
    let mut entries = Vec::new();
    let mut engines = Vec::new();
    for (i, (kind, seed)) in [("tiny", 1u64), ("tiny", 2), ("bench", 3), ("rand", 4)]
        .iter()
        .enumerate()
    {
        let e = Arc::new(synth::engine_from_spec(kind, *seed).unwrap());
        engines.push(e.clone());
        entries.push((format!("m{i}"), e));
    }
    let registry = Arc::new(ModelRegistry::new(entries).unwrap());
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 16,
        batch_wait_us: 100,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(registry, cfg);

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut rng = Rng::new(88);
    for round in 0..3 {
        for id in 0..4u16 {
            let eng = &engines[id as usize];
            let images = random_images(&mut rng, 2, eng.img_elems());
            let got = classify_on_v2(&mut stream, id, &images, 2).unwrap();
            assert_eq!(got, expected(eng, &images, 2), "round {round} model {id}");
        }
    }
    drop(stream);
    server.join().unwrap().unwrap();
    for id in 0..4u16 {
        assert_eq!(
            stats.model(id).unwrap().requests.load(Ordering::Relaxed),
            3,
            "model {id}"
        );
    }
}

#[test]
fn trickle_model_is_not_starved_by_saturating_model() {
    // Starvation regression for the fair scheduler: model 0 ("hog",
    // weight 3) saturates the pool from several pipelined clients while
    // model 1 ("trickle", weight 1, zero straggler wait) sends one
    // image at a time. Every trickle request must complete within a
    // bounded number of scheduler rounds and stay bit-identical to its
    // sequential engine — under FCFS admission it would instead sit
    // behind the hog's entire backlog.
    let hog = Arc::new(synth::engine_from_spec("tiny", 11).unwrap());
    let trickle = Arc::new(synth::engine_from_spec("bench", 22).unwrap());
    let registry = ModelRegistry::with_policies(vec![
        (
            "hog".into(),
            hog.clone(),
            PolicyOverrides {
                weight: Some(3),
                ..PolicyOverrides::default()
            },
        ),
        (
            "trickle".into(),
            trickle.clone(),
            PolicyOverrides {
                weight: Some(1),
                max_batch: Some(4),
                batch_wait_us: Some(0),
                ..PolicyOverrides::default()
            },
        ),
    ])
    .unwrap();
    let (hog_clients, hog_reqs, hog_batch) = (3usize, 60usize, 8usize);
    let trickle_reqs = 8usize;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 200,
        // 16-image bound < the hogs' 3x8 peak queued images, so
        // per-model queue backpressure genuinely engages during the run
        // and the fairness assertions hold with pushes blocking too
        queue_images: 16,
        max_accepts: Some(hog_clients + 1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(Arc::new(registry), cfg);

    let mut hogs = Vec::new();
    for c in 0..hog_clients {
        let engine = hog.clone();
        hogs.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(4000 + c as u64);
            for r in 0..hog_reqs {
                let images = random_images(&mut rng, hog_batch, engine.img_elems());
                let got = classify_on_v2(&mut stream, 0, &images, hog_batch).unwrap();
                assert_eq!(got, expected(&engine, &images, hog_batch), "hog {c} req {r}");
            }
        }));
    }
    // Let the hogs build a backlog before the trickle starts.
    std::thread::sleep(std::time::Duration::from_millis(20));

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut rng = Rng::new(4100);
    for r in 0..trickle_reqs {
        let images = random_images(&mut rng, 1, trickle.img_elems());
        let rounds_before = stats.rounds.load(Ordering::Relaxed);
        let got = classify_on_v2(&mut stream, 1, &images, 1).unwrap();
        let delta = stats.rounds.load(Ordering::Relaxed) - rounds_before;
        assert_eq!(got, expected(&trickle, &images, 1), "trickle req {r}");
        // Bounded starvation: the weighted scheduler admits a ready
        // model every round, and the in-flight cap keeps rounds tied to
        // pool completions, so a trickle request never waits more than
        // a handful of rounds. 64 is a very generous ceiling — FCFS
        // behind the hog backlog would blow far past it or time out.
        assert!(delta <= 64, "trickle req {r} took {delta} scheduler rounds");
    }
    drop(stream);
    for h in hogs {
        h.join().unwrap();
    }
    server.join().unwrap().unwrap();

    let m0 = stats.model(0).unwrap();
    let m1 = stats.model(1).unwrap();
    assert_eq!(
        m0.requests.load(Ordering::Relaxed),
        (hog_clients * hog_reqs) as u64
    );
    assert_eq!(m1.requests.load(Ordering::Relaxed), trickle_reqs as u64);
    assert_eq!(m1.images.load(Ordering::Relaxed), trickle_reqs as u64);
    // the trickle model was admitted on its own (sequential 1-image
    // requests cannot coalesce)
    assert_eq!(m1.admitted.load(Ordering::Relaxed), trickle_reqs as u64);
    assert!(m0.admitted.load(Ordering::Relaxed) > 0);
    assert_eq!(stats.total_rejected(), 0);
    assert!(stats.rounds.load(Ordering::Relaxed) > 0);
}

#[test]
fn policy_tails_thread_from_cli_specs_to_bound_server() {
    use aquant::config::ModelSpec;
    use aquant::server::Server;

    // spec tail -> ModelSpec -> registry entry -> resolved Policy on a
    // bound server, with server-level defaults filling the gaps
    let specs = vec![
        ModelSpec::parse("a=synth:tiny;weight=3;max_batch=4", None, None).unwrap(),
        ModelSpec::parse("b=synth:bench:7;batch_wait_us=0", None, None).unwrap(),
    ];
    let registry = Arc::new(ModelRegistry::from_specs(&specs, |_| unreachable!()).unwrap());
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 16,
        batch_wait_us: 300,
        queue_images: 128,
        max_accepts: Some(0),
        ..ServeConfig::default()
    };
    let srv = Server::bind(registry.clone(), "127.0.0.1:0", cfg.clone()).unwrap();
    let p = srv.policies();
    assert_eq!(p.len(), 2);
    assert_eq!((p[0].weight, p[0].max_batch), (3, 4));
    assert_eq!(p[0].batch_wait_us, 300, "unset key inherits the global knob");
    assert_eq!(p[0].queue_images, 128);
    assert_eq!((p[1].weight, p[1].max_batch), (1, 16));
    assert_eq!(p[1].batch_wait_us, 0);
    srv.run().unwrap(); // max_accepts 0: binds, drains, exits cleanly

    // a per-model policy that violates the bounds fails at bind
    let bad = ModelRegistry::with_policies(vec![(
        "a".into(),
        Arc::new(synth::engine_from_spec("tiny", 1).unwrap()),
        aquant::config::PolicyOverrides {
            queue_images: Some(4),
            max_batch: Some(8),
            ..Default::default()
        },
    )])
    .unwrap();
    let err = Server::bind(Arc::new(bad), "127.0.0.1:0", cfg).unwrap_err();
    assert!(format!("{err:#}").contains("queue_images"), "{err:#}");
}
