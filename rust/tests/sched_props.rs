//! Property tests for the fair scheduler's deficit-round-robin core
//! (`server::sched::FairScheduler`): weighted-share accounting, bounded
//! per-round deviation, starvation freedom, and the single-model
//! degenerate case — all driven deterministically through the
//! `ready`/`admit` callbacks (no threads, sockets, or clocks).

use aquant::server::{FairScheduler, Grant, Policy};
use aquant::util::prop;
use aquant::util::rng::Rng;

fn policy(max_batch: usize, weight: u32) -> Policy {
    Policy {
        max_batch,
        batch_wait_us: 0,
        queue_images: 1 << 20,
        weight,
    }
}

/// Random scheduler shape: 2..=5 models with random weights and
/// max_batches, plus a per-model request size (all ≤ max_batch, so no
/// oversize debt — that case has its own unit test in sched.rs).
fn random_setup(rng: &mut Rng) -> (Vec<Policy>, Vec<usize>) {
    let n = 2 + (rng.next_u64() % 4) as usize;
    let mut policies = Vec::new();
    let mut req_sizes = Vec::new();
    for _ in 0..n {
        let max_batch = 1 + (rng.next_u64() % 32) as usize;
        let weight = 1 + (rng.next_u64() % 8) as u32;
        policies.push(policy(max_batch, weight));
        req_sizes.push(1 + (rng.next_u64() % max_batch as u64) as usize);
    }
    (policies, req_sizes)
}

/// One unblocked DRR pass (== one classic round) over simulated
/// per-model backlogs: each `admit` pops whole `req_sizes[id]`-image
/// requests up to the `max_images` bound (always at least one request,
/// mirroring BatchQueue::try_pop).
fn sim_round(
    fs: &mut FairScheduler,
    backlog: &mut [u64],
    req_sizes: &[usize],
) -> Vec<u64> {
    let mut admitted = vec![0u64; backlog.len()];
    // readiness snapshot at pass start, exactly like the real
    // scheduler loop's queue polls (ready and admit cannot alias)
    let ready: Vec<bool> = backlog.iter().map(|b| *b > 0).collect();
    fs.service(
        &mut |id| ready[id],
        &mut |id, max_images| {
            if backlog[id] == 0 {
                return Grant::Skip;
            }
            let r = req_sizes[id] as u64;
            let per = ((max_images / req_sizes[id]).max(1) as u64) * r;
            let take = per.min(backlog[id]);
            backlog[id] -= take;
            admitted[id] += take;
            Grant::Admitted(take as usize)
        },
    );
    admitted
}

#[test]
fn prop_backlogged_admission_tracks_weights() {
    prop::check_default("admission-tracks-weights", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum();
        let rounds = 20 + (rng.next_u64() % 60);
        // effectively infinite backlogs: nobody drains within `rounds`
        let mut backlog = vec![u64::MAX / 2; n];
        let mut tot = vec![0u64; n];
        for _ in 0..rounds {
            let adm = sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                tot[id] += adm[id];
                // per-round overshoot past the weighted share is less
                // than one batch (= at most one quantum)
                let share = q * policies[id].weight as u64;
                assert!(
                    adm[id] < share + q,
                    "model {id} admitted {} in one round (share {share}, quantum {q})",
                    adm[id]
                );
            }
        }
        // cumulative service per weight unit agrees across models to
        // within one quantum + one request (the unspent deficit)
        let max_req = *req_sizes.iter().max().unwrap() as i64;
        for i in 0..n {
            for j in 0..n {
                let per_w_i = tot[i] as i64 / policies[i].weight as i64;
                let per_w_j = tot[j] as i64 / policies[j].weight as i64;
                assert!(
                    (per_w_i - per_w_j).abs() <= 2 * (q as i64 + max_req),
                    "models {i},{j}: per-weight service {per_w_i} vs {per_w_j} \
                     (q {q}, tot {tot:?}, weights {:?})",
                    policies.iter().map(|p| p.weight).collect::<Vec<_>>(),
                );
            }
        }
    });
}

#[test]
fn prop_every_ready_model_is_served_every_round() {
    // Starvation freedom: while requests are no larger than max_batch,
    // a backlogged model admits at least one request in EVERY round,
    // whatever the other models' weights are.
    prop::check_default("no-round-starvation", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let mut backlog = vec![u64::MAX / 2; n];
        for round in 0..50 {
            let adm = sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                assert!(
                    adm[id] > 0,
                    "round {round}: backlogged model {id} starved ({adm:?})"
                );
            }
        }
    });
}

#[test]
fn prop_deficits_stay_bounded() {
    // |deficit| never exceeds one round's credit (positive side) or one
    // batch (negative side): the accounting cannot drift over time.
    prop::check_default("deficit-bounded", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum() as i64;
        let mut backlog: Vec<u64> = (0..n).map(|_| rng.next_u64() % 10_000).collect();
        for _ in 0..100 {
            // intermittent traffic: occasionally refill a random model
            if rng.next_u64() % 4 == 0 {
                let id = (rng.next_u64() % n as u64) as usize;
                backlog[id] += rng.next_u64() % 1000;
            }
            sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                let d = fs.deficit(id);
                let hi = q * policies[id].weight as i64;
                let lo = -(policies[id].max_batch as i64);
                assert!(
                    d <= hi && d >= lo,
                    "model {id} deficit {d} outside [{lo}, {hi}]"
                );
            }
        }
    });
}

#[test]
fn prop_backpressure_preserves_weighted_shares() {
    // Regression for the parked-cursor design: with a tight in-flight
    // cap, a scheduler that restarted at id 0 on every wakeup would let
    // model 0 refill the cap each time and starve high ids entirely
    // (verified: the restart variant serves [3208, 0] in the 3:1 unit
    // scenario). The persistent cursor must keep per-weight service
    // equal for ALL models under any cap.
    prop::check("backpressure-weighted-shares", 64, |rng| {
        let (policies, _req) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum();
        // 1..=3 quanta of in-flight headroom: tight enough that most
        // visits block mid-service
        let cap = q * (1 + rng.next_u64() % 3);
        let mut in_flight = 0u64;
        let mut fifo = std::collections::VecDeque::new();
        let mut served = vec![0u64; n];
        // event loop: each iteration is one wakeup; the oldest batch in
        // the pool FIFO completes between wakeups
        for _ in 0..600 {
            fs.service(
                &mut |_| true, // every model saturated throughout
                &mut |id, max_images| {
                    if in_flight >= cap {
                        return Grant::Blocked;
                    }
                    in_flight += max_images as u64;
                    fifo.push_back(max_images as u64);
                    served[id] += max_images as u64;
                    Grant::Admitted(max_images)
                },
            );
            if let Some(done) = fifo.pop_front() {
                in_flight -= done;
            }
        }
        for (id, s) in served.iter().enumerate() {
            assert!(*s > 0, "model {id} starved under backpressure: {served:?}");
        }
        let per_w: Vec<f64> = served
            .iter()
            .zip(&policies)
            .map(|(s, p)| *s as f64 / p.weight as f64)
            .collect();
        let mx = per_w.iter().cloned().fold(f64::MIN, f64::max);
        let mn = per_w.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            mx - mn <= 4.0 * q as f64,
            "weighted shares lost under backpressure: served {served:?}, \
             per-weight {per_w:?}, quantum {q}"
        );
    });
}

#[test]
fn prop_single_model_degenerates_to_continuous_batching() {
    // PR 2 equivalence: with one hosted model, weight is irrelevant and
    // every round admits at least one full batch (or the remainder), so
    // a backlog of B images drains in at most ceil(B / max_batch)
    // back-to-back rounds — the old single-batcher cadence.
    prop::check_default("single-model-degenerate", |rng| {
        let max_batch = 1 + (rng.next_u64() % 64) as usize;
        let weight = 1 + (rng.next_u64() % 8) as u32;
        let req = 1 + (rng.next_u64() % max_batch as u64) as usize;
        let mut fs = FairScheduler::new(&[policy(max_batch, weight)]).unwrap();
        let total = 1 + rng.next_u64() % 5_000;
        let mut backlog = vec![total];
        let per_batch = ((max_batch / req).max(1) * req) as u64;
        let max_rounds = (total + per_batch - 1) / per_batch;
        let mut rounds = 0u64;
        while backlog[0] > 0 {
            let before = backlog[0];
            let adm = sim_round(&mut fs, &mut backlog, &[req]);
            assert!(
                adm[0] >= before.min(per_batch),
                "round admitted {} of {before} (per-batch {per_batch})",
                adm[0]
            );
            rounds += 1;
            assert!(rounds <= max_rounds + 1, "drain exceeded the PR 2 round bound");
        }
    });
}
