//! Property tests for the fair scheduler's deficit-round-robin core
//! (`server::sched::FairScheduler`): weighted-share accounting, bounded
//! per-round deviation, starvation freedom, the single-model
//! degenerate case, and the SLO weight adapter (bounds, convergence,
//! starvation freedom under boosted weights) — all driven
//! deterministically through the `ready`/`admit` callbacks and
//! synthetic p99 streams (no threads, sockets, or clocks).

use aquant::server::{
    FairScheduler, Grant, Policy, SloAdapter, MAX_WEIGHT, SLO_FACTOR_MAX,
};
use aquant::util::prop;
use aquant::util::rng::Rng;

fn policy(max_batch: usize, weight: u32) -> Policy {
    Policy {
        max_batch,
        batch_wait_us: 0,
        queue_images: 1 << 20,
        weight,
        slo_us: None,
    }
}

/// Random scheduler shape: 2..=5 models with random weights and
/// max_batches, plus a per-model request size (all ≤ max_batch, so no
/// oversize debt — that case has its own unit test in sched.rs).
fn random_setup(rng: &mut Rng) -> (Vec<Policy>, Vec<usize>) {
    let n = 2 + (rng.next_u64() % 4) as usize;
    let mut policies = Vec::new();
    let mut req_sizes = Vec::new();
    for _ in 0..n {
        let max_batch = 1 + (rng.next_u64() % 32) as usize;
        let weight = 1 + (rng.next_u64() % 8) as u32;
        policies.push(policy(max_batch, weight));
        req_sizes.push(1 + (rng.next_u64() % max_batch as u64) as usize);
    }
    (policies, req_sizes)
}

/// One unblocked DRR pass (== one classic round) over simulated
/// per-model backlogs: each `admit` pops whole `req_sizes[id]`-image
/// requests up to the `max_images` bound (always at least one request,
/// mirroring BatchQueue::try_pop).
fn sim_round(
    fs: &mut FairScheduler,
    backlog: &mut [u64],
    req_sizes: &[usize],
) -> Vec<u64> {
    let mut admitted = vec![0u64; backlog.len()];
    // readiness snapshot at pass start, exactly like the real
    // scheduler loop's queue polls (ready and admit cannot alias)
    let ready: Vec<bool> = backlog.iter().map(|b| *b > 0).collect();
    fs.service(
        &mut |id| ready[id],
        &mut |id, max_images| {
            if backlog[id] == 0 {
                return Grant::Skip;
            }
            let r = req_sizes[id] as u64;
            let per = ((max_images / req_sizes[id]).max(1) as u64) * r;
            let take = per.min(backlog[id]);
            backlog[id] -= take;
            admitted[id] += take;
            Grant::Admitted(take as usize)
        },
    );
    admitted
}

#[test]
fn prop_backlogged_admission_tracks_weights() {
    prop::check_default("admission-tracks-weights", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum();
        let rounds = 20 + (rng.next_u64() % 60);
        // effectively infinite backlogs: nobody drains within `rounds`
        let mut backlog = vec![u64::MAX / 2; n];
        let mut tot = vec![0u64; n];
        for _ in 0..rounds {
            let adm = sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                tot[id] += adm[id];
                // per-round overshoot past the weighted share is less
                // than one batch (= at most one quantum)
                let share = q * policies[id].weight as u64;
                assert!(
                    adm[id] < share + q,
                    "model {id} admitted {} in one round (share {share}, quantum {q})",
                    adm[id]
                );
            }
        }
        // cumulative service per weight unit agrees across models to
        // within one quantum + one request (the unspent deficit)
        let max_req = *req_sizes.iter().max().unwrap() as i64;
        for i in 0..n {
            for j in 0..n {
                let per_w_i = tot[i] as i64 / policies[i].weight as i64;
                let per_w_j = tot[j] as i64 / policies[j].weight as i64;
                assert!(
                    (per_w_i - per_w_j).abs() <= 2 * (q as i64 + max_req),
                    "models {i},{j}: per-weight service {per_w_i} vs {per_w_j} \
                     (q {q}, tot {tot:?}, weights {:?})",
                    policies.iter().map(|p| p.weight).collect::<Vec<_>>(),
                );
            }
        }
    });
}

#[test]
fn prop_every_ready_model_is_served_every_round() {
    // Starvation freedom: while requests are no larger than max_batch,
    // a backlogged model admits at least one request in EVERY round,
    // whatever the other models' weights are.
    prop::check_default("no-round-starvation", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let mut backlog = vec![u64::MAX / 2; n];
        for round in 0..50 {
            let adm = sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                assert!(
                    adm[id] > 0,
                    "round {round}: backlogged model {id} starved ({adm:?})"
                );
            }
        }
    });
}

#[test]
fn prop_deficits_stay_bounded() {
    // |deficit| never exceeds one round's credit (positive side) or one
    // batch (negative side): the accounting cannot drift over time.
    prop::check_default("deficit-bounded", |rng| {
        let (policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum() as i64;
        let mut backlog: Vec<u64> = (0..n).map(|_| rng.next_u64() % 10_000).collect();
        for _ in 0..100 {
            // intermittent traffic: occasionally refill a random model
            if rng.next_u64() % 4 == 0 {
                let id = (rng.next_u64() % n as u64) as usize;
                backlog[id] += rng.next_u64() % 1000;
            }
            sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                let d = fs.deficit(id);
                let hi = q * policies[id].weight as i64;
                let lo = -(policies[id].max_batch as i64);
                assert!(
                    d <= hi && d >= lo,
                    "model {id} deficit {d} outside [{lo}, {hi}]"
                );
            }
        }
    });
}

#[test]
fn prop_backpressure_preserves_weighted_shares() {
    // Regression for the parked-cursor design: with a tight in-flight
    // cap, a scheduler that restarted at id 0 on every wakeup would let
    // model 0 refill the cap each time and starve high ids entirely
    // (verified: the restart variant serves [3208, 0] in the 3:1 unit
    // scenario). The persistent cursor must keep per-weight service
    // equal for ALL models under any cap.
    prop::check("backpressure-weighted-shares", 64, |rng| {
        let (policies, _req) = random_setup(rng);
        let n = policies.len();
        let mut fs = FairScheduler::new(&policies).unwrap();
        let q = fs.quantum();
        // 1..=3 quanta of in-flight headroom: tight enough that most
        // visits block mid-service
        let cap = q * (1 + rng.next_u64() % 3);
        let mut in_flight = 0u64;
        let mut fifo = std::collections::VecDeque::new();
        let mut served = vec![0u64; n];
        // event loop: each iteration is one wakeup; the oldest batch in
        // the pool FIFO completes between wakeups
        for _ in 0..600 {
            fs.service(
                &mut |_| true, // every model saturated throughout
                &mut |id, max_images| {
                    if in_flight >= cap {
                        return Grant::Blocked;
                    }
                    in_flight += max_images as u64;
                    fifo.push_back(max_images as u64);
                    served[id] += max_images as u64;
                    Grant::Admitted(max_images)
                },
            );
            if let Some(done) = fifo.pop_front() {
                in_flight -= done;
            }
        }
        for (id, s) in served.iter().enumerate() {
            assert!(*s > 0, "model {id} starved under backpressure: {served:?}");
        }
        let per_w: Vec<f64> = served
            .iter()
            .zip(&policies)
            .map(|(s, p)| *s as f64 / p.weight as f64)
            .collect();
        let mx = per_w.iter().cloned().fold(f64::MIN, f64::max);
        let mn = per_w.iter().cloned().fold(f64::MAX, f64::min);
        assert!(
            mx - mn <= 4.0 * q as f64,
            "weighted shares lost under backpressure: served {served:?}, \
             per-weight {per_w:?}, quantum {q}"
        );
    });
}

#[test]
fn prop_slo_weights_stay_within_bounds() {
    // Whatever p99 stream the adapter sees — misses, recoveries, noise,
    // missing intervals — every returned weight stays in
    // [static, min(round(static * SLO_FACTOR_MAX), MAX_WEIGHT)] and the
    // boost factor itself stays in [1, SLO_FACTOR_MAX]. Models without
    // an SLO always get exactly their static weight.
    prop::check_default("slo-weights-bounded", |rng| {
        let (mut policies, _req) = random_setup(rng);
        let n = policies.len();
        for p in policies.iter_mut() {
            // roughly half the models carry an SLO
            if rng.next_u64() % 2 == 0 {
                p.slo_us = Some(100 + rng.next_u64() % 10_000);
            }
        }
        let mut slo = SloAdapter::new(&policies);
        for _ in 0..400 {
            let p99s: Vec<Option<f64>> = (0..n)
                .map(|_| match rng.next_u64() % 4 {
                    // quiet interval: too few samples, no signal
                    0 => None,
                    // anything from "way under" to "way over" the SLO
                    _ => Some(rng.range_f32(1.0, 200_000.0) as f64),
                })
                .collect();
            let weights = slo.tick(&p99s);
            for (id, p) in policies.iter().enumerate() {
                let hi = ((p.weight as f64 * SLO_FACTOR_MAX).round() as u32).min(MAX_WEIGHT);
                assert!(
                    weights[id] >= p.weight && weights[id] <= hi,
                    "model {id}: weight {} outside [{}, {hi}]",
                    weights[id],
                    p.weight
                );
                let f = slo.factor(id);
                assert!(
                    (1.0..=SLO_FACTOR_MAX).contains(&f),
                    "model {id}: factor {f} escaped [1, {SLO_FACTOR_MAX}]"
                );
                if p.slo_us.is_none() {
                    assert_eq!(weights[id], p.weight, "SLO-free model {id} adapted");
                }
            }
        }
    });
}

#[test]
fn prop_slo_factor_converges_when_met() {
    // Convergence: sustained misses drive the factor up; once the
    // observed p99 sits at/inside the SLO (deadband included), the
    // factor decays geometrically back to 1 and the weight returns to
    // the static value — no permanent boost, no oscillation.
    prop::check_default("slo-converges", |rng| {
        let weight = 1 + (rng.next_u64() % 8) as u32;
        let slo_us = 1_000 + rng.next_u64() % 50_000;
        let mut pol = policy(1 + (rng.next_u64() % 32) as usize, weight);
        pol.slo_us = Some(slo_us);
        let mut slo = SloAdapter::new(&[pol]);
        // phase 1: miss hard (2-10x over target) until boosted
        let over = slo_us as f64 * (2.0 + (rng.next_u64() % 9) as f64);
        let mut boosted = false;
        for _ in 0..200 {
            slo.tick(&[Some(over)]);
            if slo.factor(0) > 1.5 {
                boosted = true;
                break;
            }
        }
        assert!(boosted, "factor never rose past 1.5 under sustained misses");
        // phase 2: p99 lands exactly on (or just under) the SLO — the
        // deadband means decay-only, so the factor must drift home
        let met = slo_us as f64 * (0.90 + 0.10 * (rng.next_u64() % 2) as f64);
        for _ in 0..600 {
            slo.tick(&[Some(met)]);
        }
        let f = slo.factor(0);
        assert!(f < 1.01, "factor {f} did not converge to 1 once the SLO was met");
        assert_eq!(slo.effective_weight(0), weight, "weight did not return to static");
    });
}

#[test]
fn prop_slo_boost_never_starves_other_models() {
    // Close the loop against the DRR core: run one model's weight all
    // the way to its SLO ceiling and feed the boosted weights into a
    // live FairScheduler via set_weight. Every OTHER backlogged model
    // must still be served every round (boost-only adaptation can
    // shrink their relative share but never their round guarantee).
    prop::check("slo-no-starvation", 64, |rng| {
        let (mut policies, req_sizes) = random_setup(rng);
        let n = policies.len();
        let victim = (rng.next_u64() % n as u64) as usize;
        policies[victim].slo_us = Some(100);
        let mut fs = FairScheduler::new(&policies).unwrap();
        let mut slo = SloAdapter::new(&policies);
        let mut backlog = vec![u64::MAX / 2; n];
        for round in 0..60 {
            // the SLO'd model misses by 100x every interval
            let p99s: Vec<Option<f64>> = (0..n)
                .map(|id| if id == victim { Some(10_000.0) } else { None })
                .collect();
            let weights = slo.tick(&p99s);
            for id in 0..n {
                fs.set_weight(id, weights[id]);
            }
            let adm = sim_round(&mut fs, &mut backlog, &req_sizes);
            for id in 0..n {
                assert!(
                    adm[id] > 0,
                    "round {round}: model {id} starved while {victim} was boosted \
                     (weights {weights:?}, admitted {adm:?})"
                );
            }
        }
        // sanity: the pressure actually drove the factor up
        assert!(slo.factor(victim) > 1.0, "victim never boosted");
    });
}

#[test]
fn prop_single_model_degenerates_to_continuous_batching() {
    // PR 2 equivalence: with one hosted model, weight is irrelevant and
    // every round admits at least one full batch (or the remainder), so
    // a backlog of B images drains in at most ceil(B / max_batch)
    // back-to-back rounds — the old single-batcher cadence.
    prop::check_default("single-model-degenerate", |rng| {
        let max_batch = 1 + (rng.next_u64() % 64) as usize;
        let weight = 1 + (rng.next_u64() % 8) as u32;
        let req = 1 + (rng.next_u64() % max_batch as u64) as usize;
        let mut fs = FairScheduler::new(&[policy(max_batch, weight)]).unwrap();
        let total = 1 + rng.next_u64() % 5_000;
        let mut backlog = vec![total];
        let per_batch = ((max_batch / req).max(1) * req) as u64;
        let max_rounds = (total + per_batch - 1) / per_batch;
        let mut rounds = 0u64;
        while backlog[0] > 0 {
            let before = backlog[0];
            let adm = sim_round(&mut fs, &mut backlog, &[req]);
            assert!(
                adm[0] >= before.min(per_batch),
                "round admitted {} of {before} (per-batch {per_batch})",
                adm[0]
            );
            rounds += 1;
            assert!(rounds <= max_rounds + 1, "drain exceeded the PR 2 round bound");
        }
    });
}
