//! Property tests over the pure-Rust engine + quant substrate that do not
//! require artifacts (run in a fresh clone).

use aquant::nn::engine::{ActQuant, Engine, FusionMode};
use aquant::nn::synth::tiny_model;
use aquant::quant::border::BorderFn;
use aquant::util::prop;
use aquant::util::rng::Rng;

#[test]
fn fused_and_unfused_border_agree_with_same_params() {
    let mut rng = Rng::new(77);
    let (topo, weights) = tiny_model(&mut rng);
    let image: Vec<f32> = (0..3 * 64).map(|_| rng.normal()).collect();
    // fixed params shared by both engines
    let mut params_by_layer = std::collections::HashMap::new();
    for l in topo.all_layers() {
        let params: Vec<f32> = (0..l.rows * 4).map(|_| rng.normal() * 0.2).collect();
        params_by_layer.insert(l.name.clone(), params);
    }
    let mut outs = Vec::new();
    for mode in [FusionMode::Fused, FusionMode::Unfused] {
        let mut eng = Engine::new(topo.clone(), weights.clone());
        eng.fusion = mode;
        for l in topo.all_layers() {
            eng.set_act_quant(
                &l.name,
                ActQuant::Border {
                    border: BorderFn::from_params(
                        params_by_layer[&l.name].clone(),
                        l.k2(),
                        true,
                        true,
                    )
                    .unwrap(),
                    s: 0.1,
                    qmin: 0.0,
                    qmax: 15.0,
                },
            );
        }
        outs.push(eng.forward(&image, None).unwrap());
    }
    assert_eq!(outs[0], outs[1], "fusion mode changed the numerics");
}

#[test]
fn quantized_forward_close_to_fp_at_8bit() {
    prop::check("8-bit quantization is near-lossless", 16, |rng| {
        let (topo, weights) = tiny_model(rng);
        let image: Vec<f32> = (0..3 * 64).map(|_| rng.normal().abs()).collect();
        let fp = Engine::new(topo.clone(), weights.clone())
            .forward(&image, None)
            .unwrap();
        let mut eng = Engine::new(topo.clone(), weights.clone());
        for l in topo.all_layers() {
            eng.set_act_quant(
                &l.name,
                ActQuant::Border {
                    border: BorderFn::nearest(l.rows, l.k2()),
                    s: 4.0 / 255.0,
                    qmin: -128.0,
                    qmax: 127.0,
                },
            );
        }
        let q = eng.forward(&image, None).unwrap();
        for (a, b) in fp.iter().zip(&q) {
            assert!((a - b).abs() < 0.35, "8-bit drift too large: {a} vs {b}");
        }
    });
}

#[test]
fn residual_block_identity_skip() {
    // With zero conv weights in a residual block, output == relu(input).
    let mut rng = Rng::new(5);
    let (topo, mut weights) = tiny_model(&mut rng);
    weights.get_mut("c2").unwrap().w.iter_mut().for_each(|v| *v = 0.0);
    weights.get_mut("c2").unwrap().b.iter_mut().for_each(|v| *v = 0.0);
    let eng = Engine::new(topo.clone(), weights.clone());
    let image: Vec<f32> = (0..3 * 64).map(|_| rng.normal()).collect();
    let mut taps = std::collections::HashMap::new();
    let _ = eng.forward(&image, Some(&mut taps)).unwrap();
    // the input of c2 is the block input; the block output equals
    // relu(0 + skip) = skip (inputs are post-relu, hence non-negative)
    let skip = &taps["c2"];
    let mut expect = skip.data.clone();
    expect.iter_mut().for_each(|v| {
        if *v < 0.0 {
            *v = 0.0
        }
    });
    // forward again capturing the fc input (= block output pooled later)
    let mut taps2 = std::collections::HashMap::new();
    let _ = eng.forward(&image, Some(&mut taps2)).unwrap();
    assert_eq!(taps2["fc"].data, expect);
}
