//! Integration tests for the serving path: real TCP on an ephemeral
//! port, a tiny synthetic model (no artifacts needed), concurrent
//! clients, and the protocol's failure modes. (Multi-model routing has
//! its own suite in `multi_model.rs`; shared scaffolding in `common.rs`.)
//!
//! The core invariant: dynamic batching + the worker pool must not
//! change results — every served prediction equals the sequential
//! `Engine::classify_batch` bit-for-bit.

mod common;

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::Ordering;

use aquant::config::ServeConfig;
use aquant::server::{classify_on, classify_remote};
use aquant::util::rng::Rng;

use common::{expect_closed, expected, random_images, start_single, synth_engine, v1_request_bytes};

#[test]
fn concurrent_clients_match_sequential_engine() {
    let engine = synth_engine(42);
    let (n_clients, reqs_per_client, batch) = (4usize, 3usize, 5usize);
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 8,
        batch_wait_us: 500,
        max_accepts: Some(n_clients + 1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);
    let img_elems = engine.img_elems();

    let mut clients = Vec::new();
    for c in 0..n_clients {
        let engine = engine.clone();
        clients.push(std::thread::spawn(move || {
            // one connection per client, pipelined requests over it
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(1000 + c as u64);
            for _ in 0..reqs_per_client {
                let images = random_images(&mut rng, batch, img_elems);
                let got = classify_on(&mut stream, &images, batch).unwrap();
                assert_eq!(got, expected(&engine, &images, batch), "client {c}");
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    // one more request through the fresh-connection helper
    let mut rng = Rng::new(9);
    let images = random_images(&mut rng, 2, img_elems);
    let got = classify_remote(&addr.to_string(), &images, 2).unwrap();
    assert_eq!(got, expected(&engine, &images, 2));

    server.join().unwrap().unwrap();
    let m = stats.default_model();
    let served = (n_clients * reqs_per_client * batch + 2) as u64;
    assert_eq!(m.images.load(Ordering::Relaxed), served);
    assert_eq!(
        m.requests.load(Ordering::Relaxed),
        (n_clients * reqs_per_client + 1) as u64
    );
    assert_eq!(stats.total_requests(), m.requests.load(Ordering::Relaxed));
    assert!(m.batches.load(Ordering::Relaxed) >= 1);
    // coalescing can only shrink the batch count, never lose images
    assert!(m.batches.load(Ordering::Relaxed) <= m.requests.load(Ordering::Relaxed));
}

#[test]
fn single_image_zero_wait_roundtrip() {
    let engine = synth_engine(5);
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 1,
        batch_wait_us: 0,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);
    let mut rng = Rng::new(6);
    let images = random_images(&mut rng, 1, engine.img_elems());
    let got = classify_remote(&addr.to_string(), &images, 1).unwrap();
    assert_eq!(got, expected(&engine, &images, 1));
    server.join().unwrap().unwrap();
    let m = stats.default_model();
    assert_eq!(m.batches.load(Ordering::Relaxed), 1);
    assert_eq!(m.batch_hist[0].load(Ordering::Relaxed), 1);
}

#[test]
fn oversized_pipelined_requests_never_wedge_the_scheduler() {
    // Every request is larger than max_batch, so each admission drives
    // the model's DRR deficit negative. With no other traffic and
    // nothing in flight, only the scheduler's work-conservation path
    // can admit the next one — without it, request 2 would hang
    // forever behind the debt.
    let engine = synth_engine(31);
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 2,
        batch_wait_us: 0,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut rng = Rng::new(32);
    for r in 0..5 {
        let images = random_images(&mut rng, 8, engine.img_elems());
        let got = classify_on(&mut stream, &images, 8).unwrap();
        assert_eq!(got, expected(&engine, &images, 8), "oversized req {r}");
    }
    drop(stream);
    server.join().unwrap().unwrap();
    let m = stats.default_model();
    assert_eq!(m.requests.load(Ordering::Relaxed), 5);
    assert_eq!(m.images.load(Ordering::Relaxed), 40);
    // oversized requests are admitted alone: one batch each
    assert_eq!(m.admitted.load(Ordering::Relaxed), 5);
}

#[test]
fn nan_payload_is_answered_and_does_not_kill_workers() {
    // A NaN pixel must not panic a pool worker (that would permanently
    // shrink the pool): the request gets *some* answer and the server
    // keeps serving clean requests with correct results afterwards.
    let engine = synth_engine(21);
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(3),
        ..ServeConfig::default()
    };
    let (addr, _stats, server) = start_single(engine.clone(), cfg);
    let a = addr.to_string();
    let img_elems = engine.img_elems();

    let mut rng = Rng::new(22);
    let mut evil = random_images(&mut rng, 2, img_elems);
    evil[7] = f32::NAN;
    evil[img_elems + 3] = f32::INFINITY;
    let got = classify_remote(&a, &evil, 2).unwrap();
    // same total-order argmax as the sequential engine
    assert_eq!(got, expected(&engine, &evil, 2));

    for seed in [23u64, 24] {
        let mut rng = Rng::new(seed);
        let images = random_images(&mut rng, 3, img_elems);
        let got = classify_remote(&a, &images, 3).unwrap();
        assert_eq!(got, expected(&engine, &images, 3));
    }
    server.join().unwrap().unwrap();
}

#[test]
fn malformed_requests_do_not_wedge_server() {
    let engine = synth_engine(7);
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(5),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);
    let a = addr.to_string();
    let img_elems = engine.img_elems();

    // n = 0
    let mut s = TcpStream::connect(&a).unwrap();
    s.write_all(&v1_request_bytes(&[], 0)).unwrap();
    expect_closed(s);

    // n > 4096
    let mut s = TcpStream::connect(&a).unwrap();
    s.write_all(&v1_request_bytes(&[], 5000)).unwrap();
    expect_closed(s);

    // mid-stream EOF: header promises 2 images, body cut short (1/8)
    let mut s = TcpStream::connect(&a).unwrap();
    s.write_all(&v1_request_bytes(&vec![0.0; img_elems / 4], 2))
        .unwrap();
    drop(s);

    // the server must still answer good requests on fresh connections
    for seed in [1u64, 2] {
        let mut rng = Rng::new(seed);
        let images = random_images(&mut rng, 3, img_elems);
        let got = classify_remote(&a, &images, 3).unwrap();
        assert_eq!(got, expected(&engine, &images, 3));
    }

    server.join().unwrap().unwrap();
    let m = stats.default_model();
    // bad-n rejections are attributed to the resolved (default) model
    assert_eq!(m.rejected.load(Ordering::Relaxed), 2);
    assert_eq!(stats.total_rejected(), 2);
    assert_eq!(m.requests.load(Ordering::Relaxed), 2);
}
