//! Connection-conformance harness for the event-loop server: hostile
//! and degenerate clients that a thread-per-connection design tolerates
//! by accident and a readiness loop must tolerate by construction.
//!
//! Scenarios: slow-loris dribble across ≥256 *concurrent* connections
//! (served without a thread per connection — asserted via the process
//! thread count), mid-payload disconnects, half-open (shutdown-write)
//! peers, pipelined bursts on one connection, >`--max-conns` admission
//! rejection, idle/read timeouts, EPIPE'd dead clients sharing a batch
//! with live ones, and pure garbage streams. Every scenario asserts
//! the server stays live and later/concurrent clients get answers
//! bit-identical to the sequential engine.
//!
//! Each test arms a [`common::Watchdog`] — a wedged loop aborts the
//! process rather than hanging CI (scripts/check.sh adds an outer
//! `timeout` belt on top).

mod common;

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::time::Duration;

use aquant::config::ServeConfig;
use aquant::server::{classify_on_v2, classify_remote};
use aquant::util::rng::Rng;

use common::{
    chunked_write, expect_closed, expected, random_images, read_response, start_single,
    synth_engine, v1_request_bytes, v2_request_bytes, Watchdog,
};

/// OS threads in this process (Linux; None elsewhere).
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
}

#[test]
fn slow_loris_256_connections_served_by_one_loop() {
    let _wd = Watchdog::arm("slow_loris_256", Duration::from_secs(120));
    const CONNS: usize = 256;
    let engine = synth_engine(71);
    let elems = engine.img_elems();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 64,
        batch_wait_us: 200,
        max_accepts: Some(CONNS),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    // One driver thread opens every connection, then dribbles each
    // request a few bytes per turn round-robin: all 256 requests are
    // partially received *simultaneously*, which is exactly the state
    // a thread-per-connection server would spend 256 blocked threads
    // on. Even connections speak v1, odd ones v2 — one loop, mixed
    // framings.
    let mut rng = Rng::new(72);
    let mut conns: Vec<(TcpStream, Vec<f32>, Vec<u8>)> = (0..CONNS)
        .map(|c| {
            let stream = TcpStream::connect(addr).expect("connect");
            let images = random_images(&mut rng, 1, elems);
            let bytes = if c % 2 == 0 {
                v1_request_bytes(&images, 1)
            } else {
                v2_request_bytes(0, &images, 1)
            };
            (stream, images, bytes)
        })
        .collect();

    let chunk = 7usize;
    let rounds = conns.iter().map(|(_, _, b)| b.len()).max().unwrap() / chunk + 1;
    for r in 0..rounds {
        for (stream, _, bytes) in conns.iter_mut() {
            let start = r * chunk;
            if start < bytes.len() {
                let end = (start + chunk).min(bytes.len());
                stream.write_all(&bytes[start..end]).expect("dribble");
            }
        }
        if r == rounds / 2 {
            // hold every request mid-flight for a beat, then check the
            // server is doing this with state, not threads
            std::thread::sleep(Duration::from_millis(50));
            if let Some(threads) = process_threads() {
                assert!(
                    threads < CONNS / 2,
                    "{threads} process threads while {CONNS} connections are \
                     mid-request — that smells like a thread per connection"
                );
            }
            assert_eq!(stats.conns_open.load(Ordering::Relaxed), CONNS as u64);
        }
    }

    for (c, (stream, images, _)) in conns.iter_mut().enumerate() {
        let got = read_response(stream).expect("response");
        assert_eq!(got, expected(&engine, images, 1), "conn {c}");
    }
    drop(conns);
    server.join().unwrap().unwrap();
    let m = stats.default_model();
    assert_eq!(m.requests.load(Ordering::Relaxed), CONNS as u64);
    assert_eq!(stats.conns_accepted.load(Ordering::Relaxed), CONNS as u64);
    assert_eq!(stats.conns_rejected.load(Ordering::Relaxed), 0);
    assert_eq!(stats.total_rejected(), 0);
}

#[test]
fn mid_payload_disconnects_leave_the_server_live() {
    let _wd = Watchdog::arm("mid_payload_disconnects", Duration::from_secs(60));
    let engine = synth_engine(73);
    let elems = engine.img_elems();
    let killers = 20usize;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 0,
        max_accepts: Some(killers + 2),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);
    let a = addr.to_string();

    let mut rng = Rng::new(74);
    for k in 0..killers {
        let images = random_images(&mut rng, 2, elems);
        let bytes = if k % 2 == 0 {
            v1_request_bytes(&images, 2)
        } else {
            v2_request_bytes(0, &images, 2)
        };
        let cut = 4 + 1 + (k * 97) % (bytes.len() - 6); // always mid-frame
        let mut s = TcpStream::connect(&a).unwrap();
        s.write_all(&bytes[..cut]).unwrap();
        drop(s); // vanish mid-payload (or mid-v2-header)
    }

    // bit-identical service continues on fresh connections
    for seed in [75u64, 76] {
        let mut rng = Rng::new(seed);
        let images = random_images(&mut rng, 3, elems);
        let got = classify_remote(&a, &images, 3).unwrap();
        assert_eq!(got, expected(&engine, &images, 3));
    }
    server.join().unwrap().unwrap();
    let m = stats.default_model();
    assert_eq!(m.requests.load(Ordering::Relaxed), 2);
    assert_eq!(stats.conns_open.load(Ordering::Relaxed), 0);
}

#[test]
fn half_open_client_still_gets_every_answer() {
    let _wd = Watchdog::arm("half_open_client", Duration::from_secs(60));
    let engine = synth_engine(77);
    let elems = engine.img_elems();
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 100,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    // two pipelined requests, then shutdown(WR): the read side of the
    // socket is gone from the server's perspective, but both answers
    // must still arrive (graceful half-close), in order.
    let mut rng = Rng::new(78);
    let img_a = random_images(&mut rng, 2, elems);
    let img_b = random_images(&mut rng, 1, elems);
    let mut s = TcpStream::connect(addr).unwrap();
    let mut burst = v1_request_bytes(&img_a, 2);
    burst.extend_from_slice(&v2_request_bytes(0, &img_b, 1));
    s.write_all(&burst).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    assert_eq!(read_response(&mut s).unwrap(), expected(&engine, &img_a, 2));
    assert_eq!(read_response(&mut s).unwrap(), expected(&engine, &img_b, 1));
    // and then the server closes cleanly
    expect_closed(s);
    server.join().unwrap().unwrap();
    assert_eq!(stats.default_model().requests.load(Ordering::Relaxed), 2);
}

#[test]
fn pipelined_burst_is_answered_in_request_order() {
    let _wd = Watchdog::arm("pipelined_burst", Duration::from_secs(60));
    let engine = synth_engine(79);
    let elems = engine.img_elems();
    let cfg = ServeConfig {
        workers: 3,
        max_batch: 4, // smaller than the burst: several engine batches in flight
        batch_wait_us: 0,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    // 16 requests written back-to-back with no reads in between: the
    // event loop reads ahead while earlier requests are still in the
    // pool (the blocking server never had requests from one connection
    // in flight concurrently). Responses must come back in request
    // order and bit-identical despite out-of-order completion being
    // possible.
    let mut rng = Rng::new(80);
    let reqs: Vec<(Vec<f32>, usize)> = (0..16)
        .map(|i| {
            let n = 1 + i % 3;
            (random_images(&mut rng, n, elems), n)
        })
        .collect();
    let mut burst = Vec::new();
    for (i, (images, n)) in reqs.iter().enumerate() {
        if i % 2 == 0 {
            burst.extend_from_slice(&v1_request_bytes(images, *n as u32));
        } else {
            burst.extend_from_slice(&v2_request_bytes(0, images, *n as u32));
        }
    }
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&burst).unwrap();
    for (i, (images, n)) in reqs.iter().enumerate() {
        let got = read_response(&mut s).unwrap();
        assert_eq!(got, expected(&engine, images, *n), "pipelined request {i}");
    }
    drop(s);
    server.join().unwrap().unwrap();
    assert_eq!(stats.default_model().requests.load(Ordering::Relaxed), 16);
}

#[test]
fn connections_over_max_conns_are_rejected_until_capacity_frees() {
    let _wd = Watchdog::arm("max_conns_rejection", Duration::from_secs(60));
    let engine = synth_engine(81);
    let elems = engine.img_elems();
    let cap = 4usize;
    let rejected = 4usize;
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_conns: Some(cap),
        max_accepts: Some(cap + rejected + 1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    // fill the cap with idle holders and wait until they're installed
    let mut holders: Vec<TcpStream> =
        (0..cap).map(|_| TcpStream::connect(addr).unwrap()).collect();
    while stats.conns_open.load(Ordering::Relaxed) < cap as u64 {
        std::thread::sleep(Duration::from_millis(5));
    }
    // everything beyond the cap is accepted and closed straight back
    for _ in 0..rejected {
        let s = TcpStream::connect(addr).unwrap();
        expect_closed(s);
    }
    assert_eq!(stats.conns_rejected.load(Ordering::Relaxed), rejected as u64);
    // freeing one slot lets the next client in — and it gets a
    // bit-identical answer, so rejection never corrupted the loop
    drop(holders.remove(0));
    while stats.conns_open.load(Ordering::Relaxed) >= cap as u64 {
        std::thread::sleep(Duration::from_millis(5));
    }
    let mut rng = Rng::new(82);
    let images = random_images(&mut rng, 2, elems);
    let got = classify_remote(&addr.to_string(), &images, 2).unwrap();
    assert_eq!(got, expected(&engine, &images, 2));

    drop(holders); // let the bounded run drain
    server.join().unwrap().unwrap();
    assert_eq!(
        stats.conns_accepted.load(Ordering::Relaxed),
        (cap + rejected + 1) as u64
    );
    assert_eq!(stats.default_model().requests.load(Ordering::Relaxed), 1);
}

#[test]
fn idle_and_loris_connections_time_out_on_both_backends() {
    let _wd = Watchdog::arm("conn_timeouts", Duration::from_secs(120));
    for poll_fallback in [false, true] {
        let engine = synth_engine(83);
        let elems = engine.img_elems();
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            batch_wait_us: 0,
            conn_timeout_ms: 200,
            max_accepts: Some(3),
            poll_fallback,
            ..ServeConfig::default()
        };
        let (addr, stats, server) = start_single(engine.clone(), cfg);

        // a fully idle connection and an abandoned mid-header loris:
        // both are reclaimed by the deadline, not held forever
        let idle = TcpStream::connect(addr).unwrap();
        let mut loris = TcpStream::connect(addr).unwrap();
        loris.write_all(&[0x01]).unwrap(); // 1 of 4 header bytes, then silence
        expect_closed(idle);
        expect_closed(loris);
        assert_eq!(stats.conns_timed_out.load(Ordering::Relaxed), 2);

        // an active client is never timed out while the server owes it
        // a response, and still gets the right answer
        let mut rng = Rng::new(84);
        let images = random_images(&mut rng, 2, elems);
        let mut s = TcpStream::connect(addr).unwrap();
        let got = classify_on_v2(&mut s, 0, &images, 2).unwrap();
        assert_eq!(got, expected(&engine, &images, 2));
        drop(s);
        server.join().unwrap().unwrap();
        assert_eq!(
            stats.conns_timed_out.load(Ordering::Relaxed),
            2,
            "poll_fallback={poll_fallback}: the live client must not time out"
        );
    }
}

#[test]
fn dead_client_in_a_shared_batch_does_not_poison_the_living() {
    let _wd = Watchdog::arm("epipe_shared_batch", Duration::from_secs(60));
    let engine = synth_engine(85);
    let elems = engine.img_elems();
    let rounds = 5usize;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        // a straggler window so the dead and living clients' requests
        // genuinely coalesce into one engine batch
        batch_wait_us: 50_000,
        max_accepts: Some(rounds * 2),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    let mut rng = Rng::new(86);
    for round in 0..rounds {
        // the doomed client: full request, then gone before any reply
        // can be written — the response write hits EPIPE/reset
        let dead_images = random_images(&mut rng, 2, elems);
        let mut dead = TcpStream::connect(addr).unwrap();
        dead.write_all(&v1_request_bytes(&dead_images, 2)).unwrap();
        drop(dead);
        // the living client shares the batch and must be untouched
        let images = random_images(&mut rng, 3, elems);
        let got = classify_remote(&addr.to_string(), &images, 3).unwrap();
        assert_eq!(got, expected(&engine, &images, 3), "round {round}");
    }
    server.join().unwrap().unwrap();
    // every image executed, including the dead clients' (their requests
    // were already admitted; only the response delivery failed)
    assert_eq!(
        stats.default_model().images.load(Ordering::Relaxed),
        (rounds * 5) as u64
    );
    assert_eq!(stats.total_rejected(), 0);
}

#[test]
fn garbage_streams_close_cleanly_and_never_wedge() {
    let _wd = Watchdog::arm("garbage_streams", Duration::from_secs(60));
    let engine = synth_engine(87);
    let elems = engine.img_elems();
    let garbage_conns = 24usize;
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(garbage_conns + 1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    let mut rng = Rng::new(88);
    for g in 0..garbage_conns {
        let len = 1 + rng.below(512);
        let junk: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let mut s = TcpStream::connect(addr).unwrap();
        // dribble some of them to mix loris with garbage
        if g % 3 == 0 {
            chunked_write(&mut s, &junk, 11, Duration::from_millis(1)).unwrap();
        } else {
            s.write_all(&junk).unwrap();
        }
        s.shutdown(Shutdown::Write).ok();
        // server must terminate the connection (a random u32 ≤ 4096
        // would start a payload wait, but our write side is shut, so
        // EOF lands mid-payload and closes it)
        s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        expect_closed(s);
    }

    let mut rng2 = Rng::new(89);
    let images = random_images(&mut rng2, 2, elems);
    let got = classify_remote(&addr.to_string(), &images, 2).unwrap();
    assert_eq!(got, expected(&engine, &images, 2));
    server.join().unwrap().unwrap();
    assert_eq!(stats.default_model().requests.load(Ordering::Relaxed), 1);
    assert_eq!(stats.conns_open.load(Ordering::Relaxed), 0);
}

#[cfg(target_os = "linux")]
extern "C" {
    fn setsockopt(
        fd: std::os::raw::c_int,
        level: std::os::raw::c_int,
        name: std::os::raw::c_int,
        value: *const std::os::raw::c_void,
        len: u32,
    ) -> std::os::raw::c_int;
}

/// Shrink a socket's receive buffer (Linux; no-op elsewhere) so the
/// server hits genuine short writes while this client reads slowly.
fn shrink_rcvbuf(s: &TcpStream, bytes: i32) {
    #[cfg(target_os = "linux")]
    {
        use std::os::unix::io::AsRawFd;
        const SOL_SOCKET: std::os::raw::c_int = 1;
        const SO_RCVBUF: std::os::raw::c_int = 8;
        // SAFETY: plain setsockopt on a live fd with a stack i32.
        unsafe {
            setsockopt(
                s.as_raw_fd(),
                SOL_SOCKET,
                SO_RCVBUF,
                &bytes as *const _ as *const std::os::raw::c_void,
                std::mem::size_of::<i32>() as u32,
            );
        }
    }
    #[cfg(not(target_os = "linux"))]
    let _ = (s, bytes);
}

#[test]
fn partial_response_writes_reassemble_for_a_slow_reader() {
    let _wd = Watchdog::arm("partial_writes", Duration::from_secs(120));
    let engine = synth_engine(90);
    let elems = engine.img_elems();
    // protocol-max responses (4 + 4*4096 bytes each), pipelined past
    // any socket buffer while the client reads nothing: the server's
    // write path must block, park the remainder, and resume cleanly —
    // byte-exact — once the client drains.
    let reqs = 16usize;
    let n = 4096usize;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 4096,
        batch_wait_us: 0,
        queue_images: 2 * 4096,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start_single(engine.clone(), cfg);

    let mut s = TcpStream::connect(addr).unwrap();
    shrink_rcvbuf(&s, 4096);
    let mut rng = Rng::new(91);
    let mut wants: Vec<Vec<u32>> = Vec::new();
    for _ in 0..reqs {
        let images = random_images(&mut rng, n, elems);
        s.write_all(&v1_request_bytes(&images, n as u32)).unwrap();
        wants.push(expected(&engine, &images, n));
    }
    // let responses pile up against the tiny receive window
    std::thread::sleep(Duration::from_millis(300));
    for (i, want) in wants.iter().enumerate() {
        let got = read_response(&mut s).unwrap();
        assert_eq!(&got, want, "response {i} after partial writes");
    }
    drop(s);
    server.join().unwrap().unwrap();
    assert_eq!(
        stats.default_model().requests.load(Ordering::Relaxed),
        reqs as u64
    );
}
