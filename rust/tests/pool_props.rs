//! Properties of the parallel inference substrate: for random
//! topologies, border parameters, batch sizes, and worker counts,
//! pooled execution is bit-identical to the sequential engine — in both
//! `FusionMode`s — and the scratch-buffer forward path is bit-identical
//! to the allocating one, including when one scratch (or one pool) is
//! shared across models of different shapes.

use std::sync::Arc;

use aquant::nn::engine::{EngineScratch, FusionMode};
use aquant::nn::pool::{InferencePool, IntraCfg};
use aquant::nn::synth;
use aquant::util::prop;

#[test]
fn pool_matches_sequential_for_random_topologies() {
    prop::check_default("pool == sequential engine", |rng| {
        let (topo, weights) = synth::random_model(rng);
        let fuse_en = rng.bernoulli(0.5);
        let b2_en = rng.bernoulli(0.5);
        let mut engine = synth::engine_with_random_borders(&topo, &weights, rng, fuse_en, b2_en);
        engine.fusion = if rng.bernoulli(0.5) {
            FusionMode::Fused
        } else {
            FusionMode::Unfused
        };
        let engine = Arc::new(engine);
        let img_elems = engine.img_elems();
        let n = 1 + rng.below(9);
        let images = prop::vec_f32(rng, n * img_elems, -1.0, 3.0);
        let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for workers in [1usize, 2, 7] {
            let pool = InferencePool::new(workers);
            let got = pool.classify_batch(&engine, &refs).unwrap();
            assert_eq!(
                got, want,
                "workers={workers} n={n} fuse={fuse_en} b2={b2_en} fusion={:?}",
                engine.fusion
            );
        }
    });
}

#[test]
fn scratch_forward_is_bit_identical_to_allocating_forward() {
    prop::check_default("forward_scratch == forward", |rng| {
        let (topo, weights) = synth::random_model(rng);
        let mut engine = synth::engine_with_random_borders(
            &topo,
            &weights,
            rng,
            rng.bernoulli(0.5),
            rng.bernoulli(0.5),
        );
        if rng.bernoulli(0.5) {
            engine.fusion = FusionMode::Unfused;
        }
        let img_elems = engine.img_elems();
        let mut scratch = EngineScratch::new();
        // several images through ONE scratch: buffer reuse must not leak
        // state between forwards
        for _ in 0..3 {
            let image = prop::vec_f32(rng, img_elems, -1.0, 3.0);
            let want = engine.forward(&image, None).unwrap();
            let got = engine.forward_scratch(&image, &mut scratch).unwrap();
            assert_eq!(got, want.as_slice());
        }
    });
}

#[test]
fn one_scratch_serves_alternating_random_models() {
    // The multi-model serving invariant at its core: a single
    // EngineScratch alternates between two independently random models
    // (different dims, borders, block structures) and every forward is
    // bit-identical to a fresh-scratch run. Catches any exact-size or
    // stale-state assumption in the reusable buffers.
    prop::check("shared scratch across models", 96, |rng| {
        let (t1, w1) = synth::random_model(rng);
        let (t2, w2) = synth::random_model(rng);
        let e1 = synth::engine_with_random_borders(&t1, &w1, rng, true, true);
        let e2 = synth::engine_with_random_borders(&t2, &w2, rng, rng.bernoulli(0.5), true);
        let mut shared = EngineScratch::new();
        for _ in 0..2 {
            for e in [&e1, &e2] {
                let image = prop::vec_f32(rng, e.img_elems(), -1.0, 3.0);
                let want = e.forward(&image, None).unwrap();
                let got = e.forward_scratch(&image, &mut shared).unwrap();
                assert_eq!(got, want.as_slice());
            }
        }
    });
}

#[test]
fn pool_shard_split_never_changes_results() {
    // Same batch, every worker count from 1 to n+2: shard boundaries
    // move across all positions, results must not.
    prop::check("shard splits are invisible", 64, |rng| {
        let (topo, weights) = synth::random_model(rng);
        let engine = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, rng, true, true,
        ));
        let img_elems = engine.img_elems();
        let n = 3 + rng.below(6);
        let images = prop::vec_f32(rng, n * img_elems, -1.0, 3.0);
        let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for workers in 1..=n + 2 {
            let pool = InferencePool::new(workers);
            assert_eq!(
                pool.classify_batch(&engine, &refs).unwrap(),
                want,
                "workers={workers}"
            );
        }
    });
}

#[test]
fn intra_image_sharding_is_bit_identical() {
    // Intra-image parallelism forced ON for every conv layer
    // (min_elems 0): chunked gather/GEMM with helper stealing must be
    // bit-identical to the sequential engine for every split count —
    // including the single-image batch it exists to accelerate, where
    // the whole forward runs through the chunk protocol.
    prop::check("intra-image sharding invisible", 48, |rng| {
        let (topo, weights) = synth::random_model(rng);
        let mut engine = synth::engine_with_random_borders(
            &topo,
            &weights,
            rng,
            rng.bernoulli(0.5),
            rng.bernoulli(0.5),
        );
        if rng.bernoulli(0.5) {
            engine.fusion = FusionMode::Unfused;
        }
        let engine = Arc::new(engine);
        let img_elems = engine.img_elems();
        let n = 1 + rng.below(4);
        let images = prop::vec_f32(rng, n * img_elems, -1.0, 3.0);
        let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for (workers, split) in [(2usize, 2usize), (3, 7), (4, 0)] {
            let pool = InferencePool::with_intra(
                workers,
                engine.scratch_dims(),
                1,
                Some(IntraCfg { split, min_elems: 0 }),
            );
            // run the same batch twice through one pool: chunk claim
            // interleavings differ per run, results must not
            for rep in 0..2 {
                assert_eq!(
                    pool.classify_batch(&engine, &refs).unwrap(),
                    want,
                    "workers={workers} split={split} n={n} rep={rep}"
                );
            }
        }
    });
}

#[test]
fn gemm_panel_strip_sharding_is_bit_identical() {
    // The GEMM intra chunks are now whole B-panel tile strips, not raw
    // output rows. Three shapes must stay invisible in the bits: odd
    // chunk counts (panel ranges of uneven width), far more chunks
    // requested than any layer has panels (chunk_range hands trailing
    // executors empty ranges), and a min_elems threshold no layer
    // reaches (every GEMM takes the serial fallback inside the intra
    // path instead of spawning chunks).
    prop::check("panel-strip sharding invisible", 48, |rng| {
        let (topo, weights) = synth::random_model(rng);
        let mut engine = synth::engine_with_random_borders(
            &topo,
            &weights,
            rng,
            rng.bernoulli(0.5),
            rng.bernoulli(0.5),
        );
        if rng.bernoulli(0.5) {
            engine.fusion = FusionMode::Unfused;
        }
        let engine = Arc::new(engine);
        let img_elems = engine.img_elems();
        let n = 1 + rng.below(3);
        let images = prop::vec_f32(rng, n * img_elems, -1.0, 3.0);
        let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        for (workers, split, min_elems) in
            [(3usize, 5usize, 0usize), (2, 63, 0), (4, 0, 1 << 40)]
        {
            let pool = InferencePool::with_intra(
                workers,
                engine.scratch_dims(),
                1,
                Some(IntraCfg { split, min_elems }),
            );
            for rep in 0..2 {
                assert_eq!(
                    pool.classify_batch(&engine, &refs).unwrap(),
                    want,
                    "workers={workers} split={split} min_elems={min_elems} n={n} rep={rep}"
                );
            }
        }
    });
}

#[test]
fn intra_disabled_pool_matches_sequential() {
    // `intra = None` must behave exactly like the pre-intra pool.
    prop::check("intra off == sequential", 32, |rng| {
        let (topo, weights) = synth::random_model(rng);
        let engine = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, rng, true, true,
        ));
        let img_elems = engine.img_elems();
        let n = 1 + rng.below(5);
        let images = prop::vec_f32(rng, n * img_elems, -1.0, 3.0);
        let refs: Vec<&[f32]> = images.chunks_exact(img_elems).collect();
        let want = engine.classify_batch(&refs).unwrap();
        let pool = InferencePool::with_intra(3, engine.scratch_dims(), 1, None);
        assert_eq!(pool.classify_batch(&engine, &refs).unwrap(), want);
    });
}

#[test]
fn one_pool_interleaves_random_models_bit_identically() {
    // Two random models through one pool, interleaved: per-worker
    // scratch reshapes between models mid-stream and results must stay
    // bit-identical to each model's sequential engine.
    prop::check("pool shared across models", 48, |rng| {
        let (t1, w1) = synth::random_model(rng);
        let (t2, w2) = synth::random_model(rng);
        let e1 = Arc::new(synth::engine_with_random_borders(&t1, &w1, rng, true, true));
        let e2 = Arc::new(synth::engine_with_random_borders(&t2, &w2, rng, true, true));
        let dims = e1.scratch_dims().union(e2.scratch_dims());
        let pool = InferencePool::with_scratch_dims(1 + rng.below(4), dims);
        for _ in 0..2 {
            for e in [&e1, &e2] {
                let n = 1 + rng.below(5);
                let images = prop::vec_f32(rng, n * e.img_elems(), -1.0, 3.0);
                let refs: Vec<&[f32]> = images.chunks_exact(e.img_elems()).collect();
                let want = e.classify_batch(&refs).unwrap();
                assert_eq!(pool.classify_batch(e, &refs).unwrap(), want);
            }
        }
    });
}
