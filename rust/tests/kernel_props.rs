//! Differential properties of the SIMD microkernels: every available
//! backend (AVX2 / NEON) is **bit-identical** to the always-compiled
//! scalar reference, over random shapes including ragged tails that
//! exercise the scalar-tail delegation inside each vector loop. This is
//! the enforcement half of the bit-identity contract documented in
//! `rust/src/nn/kernels.rs` — serving results must not depend on which
//! ISA the host happens to have.

use aquant::nn::kernels::{self, Backend, FastMode, KC, LANES, MR, NR};
use aquant::util::prop;
use aquant::util::rng::Rng;

/// Backends the host CPU can actually run (scalar always; AVX2/NEON
/// when detected). Differential assertions loop over these.
fn available() -> Vec<Backend> {
    Backend::all().into_iter().filter(|b| b.available()).collect()
}

/// Random column length biased toward interesting shapes: lane-exact,
/// ragged by 1..LANES, shorter than one lane block, and empty.
fn random_len(rng: &mut Rng) -> usize {
    match rng.below(4) {
        0 => LANES * (1 + rng.below(8)),              // exact blocks
        1 => LANES * (1 + rng.below(8)) + 1 + rng.below(LANES - 1), // ragged
        2 => rng.below(LANES),                        // tail-only (incl. 0)
        _ => 1 + rng.below(257),                      // arbitrary
    }
}

fn assert_cols_eq(b: Backend, got: &[f32], want: &[f32], what: &str) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: backend {} differs from scalar at [{i}]: {g:?} ({:#010x}) vs {w:?} ({:#010x}) (len {})",
            b.name(),
            g.to_bits(),
            w.to_bits(),
            got.len()
        );
    }
}

#[test]
fn quantize_kernels_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("quant col kernels == scalar", |rng| {
        let n = random_len(rng);
        let col = prop::vec_f32(rng, n, -6.0, 6.0);
        let b0 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b1 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b2 = prop::vec_f32(rng, n, -2.0, 2.0);
        let s = rng.range_f32(0.01, 0.5);
        let inv_s = 1.0 / s;
        let (qmin, qmax) = (0.0f32, 15.0f32);

        let mut want = col.clone();
        kernels::nearest_col_on(Backend::Scalar, &mut want, s, inv_s, qmin, qmax);
        for &b in &backends {
            let mut got = col.clone();
            kernels::nearest_col_on(b, &mut got, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "nearest_col");
        }

        let mut want = col.clone();
        kernels::quant_col_lin_on(Backend::Scalar, &mut want, &b0, &b1, s, inv_s, qmin, qmax);
        for &b in &backends {
            let mut got = col.clone();
            kernels::quant_col_lin_on(b, &mut got, &b0, &b1, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "quant_col_lin");
        }

        let mut want = col.clone();
        kernels::quant_col_quad_on(
            Backend::Scalar,
            &mut want,
            &b0,
            &b1,
            &b2,
            s,
            inv_s,
            qmin,
            qmax,
        );
        for &b in &backends {
            let mut got = col.clone();
            kernels::quant_col_quad_on(b, &mut got, &b0, &b1, &b2, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "quant_col_quad");
        }
    });
}

#[test]
fn border_table_kernels_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("border/scale/round kernels == scalar", |rng| {
        let n = random_len(rng);
        let xs = prop::vec_f32(rng, n, -8.0, 8.0);
        let b0 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b1 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b2 = prop::vec_f32(rng, n, -2.0, 2.0);
        let s = rng.range_f32(0.01, 0.5);
        let (qmin, qmax) = (-8.0f32, 7.0f32);

        let mut want = vec![0.0; n];
        kernels::borders_col_lin_on(Backend::Scalar, &xs, &b0, &b1, &mut want);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::borders_col_lin_on(b, &xs, &b0, &b1, &mut got);
            assert_cols_eq(b, &got, &want, "borders_col_lin");
        }

        let mut want = vec![0.0; n];
        kernels::borders_col_quad_on(Backend::Scalar, &xs, &b0, &b1, &b2, &mut want);
        let borders = want.clone();
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::borders_col_quad_on(b, &xs, &b0, &b1, &b2, &mut got);
            assert_cols_eq(b, &got, &want, "borders_col_quad");
        }

        let src = prop::vec_f32(rng, n, -5.0, 5.0);
        let mut want = vec![0.0; n];
        kernels::scale_col_on(Backend::Scalar, &src, 1.0 / s, &mut want);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::scale_col_on(b, &src, 1.0 / s, &mut got);
            assert_cols_eq(b, &got, &want, "scale_col");
        }

        let mut want = vec![0.0; n];
        kernels::round_col_on(Backend::Scalar, &mut want, &xs, &borders, s, qmin, qmax);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::round_col_on(b, &mut got, &xs, &borders, s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "round_col");
        }
    });
}

#[test]
fn dot_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("dot == scalar dot", |rng| {
        let n = random_len(rng);
        let w = prop::vec_f32(rng, n, -2.0, 2.0);
        let x = prop::vec_f32(rng, n, -2.0, 2.0);
        let want = kernels::dot_on(Backend::Scalar, &w, &x);
        for &b in &backends {
            let got = kernels::dot_on(b, &w, &x);
            assert!(
                got.to_bits() == want.to_bits(),
                "dot: backend {} {got:?} vs scalar {want:?} (len {n})",
                b.name()
            );
        }
    });
}

#[test]
fn active_dispatch_matches_explicit_backend() {
    // The plain entry points must route to exactly the active() backend
    // (the env-forced path is covered operationally: AQUANT_KERNELS is a
    // process-wide OnceLock, so within one test process we check the
    // resolved backend agrees with its explicit `_on` twin).
    let active = kernels::active();
    assert!(active.available());
    let mut rng = Rng::new(7);
    let n = LANES * 5 + 3;
    let col = prop::vec_f32(&mut rng, n, -4.0, 4.0);
    let b0 = prop::vec_f32(&mut rng, n, -1.0, 1.0);
    let b1 = prop::vec_f32(&mut rng, n, -1.0, 1.0);
    let (s, inv_s) = (0.1f32, 10.0f32);
    let mut via_plain = col.clone();
    kernels::quant_col_lin(&mut via_plain, &b0, &b1, s, inv_s, 0.0, 15.0);
    let mut via_on = col.clone();
    kernels::quant_col_lin_on(active, &mut via_on, &b0, &b1, s, inv_s, 0.0, 15.0);
    assert_eq!(via_plain, via_on);
}

/// Pack a row-major `(rows, k)` matrix into the KC-strip layout
/// `gemm_tile_on` consumes: per strip, each row's `ls`-element slice
/// contiguous, rows in order — the layout `im2col::pack_weights` /
/// `pack_patches` produce for one panel / one group block.
fn pack_strips(src: &[f32], rows: usize, k: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * k);
    let mut kbase = 0;
    while kbase < k {
        let ls = (k - kbase).min(KC);
        for r in 0..rows {
            out.extend_from_slice(&src[r * k + kbase..r * k + kbase + ls]);
        }
        kbase += ls;
    }
    out
}

/// Random K biased toward strip boundaries (the only place the tiled
/// reduction's bookkeeping differs from a flat dot) on top of the usual
/// lane-boundary mix.
fn random_k(rng: &mut Rng) -> usize {
    match rng.below(4) {
        0 => KC * (1 + rng.below(2)),           // strip-exact
        1 => KC * (1 + rng.below(2)) + 1 + rng.below(7), // just past a strip
        2 => KC * (1 + rng.below(2)) - 1 - rng.below(7), // just short of one
        _ => random_len(rng),                   // lane-level shapes (incl. 0)
    }
}

#[test]
fn gemm_tile_bit_identical_to_scalar_dot() {
    // The tentpole contract: the packed register-tile GEMM in exact
    // mode reduces in EXACTLY scalar `dot`'s order, on every backend,
    // for every ragged tile/strip shape — so swapping dot-per-row for
    // the tiled kernel cannot move a single output bit.
    let backends = available();
    prop::check_default("gemm_tile exact == scalar dot", |rng| {
        let k = random_k(rng);
        let mc = 1 + rng.below(2 * MR + 1);
        let nr = 1 + rng.below(NR);
        let a = prop::vec_f32(rng, mc * k, -2.0, 2.0);
        let b = prop::vec_f32(rng, nr * k, -2.0, 2.0);
        let ap = pack_strips(&a, mc, k);
        let bp = pack_strips(&b, nr, k);
        let m0 = rng.below(mc);
        let mr = (mc - m0).min(1 + rng.below(MR));
        let mut want = vec![0.0f32; mr * nr];
        for mi in 0..mr {
            for ni in 0..nr {
                want[mi * nr + ni] = kernels::dot_on(
                    Backend::Scalar,
                    &b[ni * k..(ni + 1) * k],
                    &a[(m0 + mi) * k..(m0 + mi + 1) * k],
                );
            }
        }
        for &bk in &backends {
            let mut sums = [0.0f32; MR * NR];
            kernels::gemm_tile_on(bk, FastMode::Exact, &ap, mc, m0, mr, &bp, nr, k, &mut sums);
            for mi in 0..mr {
                for ni in 0..nr {
                    let (g, w) = (sums[mi * nr + ni], want[mi * nr + ni]);
                    assert!(
                        g.to_bits() == w.to_bits(),
                        "gemm_tile: backend {} [{mi},{ni}] {g:?} ({:#010x}) vs dot {w:?} \
                         ({:#010x}) (k={k} mc={mc} m0={m0} mr={mr} nr={nr})",
                        bk.name(),
                        g.to_bits(),
                        w.to_bits()
                    );
                }
            }
        }
    });
}

#[test]
fn gemm_tile_covers_strip_and_tile_boundaries() {
    // Deterministic sweep of the exact edge shapes: K below one lane,
    // lane-exact, one off a strip boundary either way, multi-strip; a
    // ragged trailing M tile; every sub-width panel.
    let backends = available();
    let mut rng = Rng::new(11);
    for k in [1, 3, LANES, LANES + 1, KC - 1, KC, KC + 1, 2 * KC + 5] {
        let mc = MR + 1; // forces a 1-row ragged tile at m0 = MR
        for nr in 1..=NR {
            let a = prop::vec_f32(&mut rng, mc * k, -2.0, 2.0);
            let b = prop::vec_f32(&mut rng, nr * k, -2.0, 2.0);
            let ap = pack_strips(&a, mc, k);
            let bp = pack_strips(&b, nr, k);
            for m0 in [0, MR] {
                let mr = (mc - m0).min(MR);
                for &bk in &backends {
                    let mut sums = [0.0f32; MR * NR];
                    kernels::gemm_tile_on(
                        bk,
                        FastMode::Exact,
                        &ap,
                        mc,
                        m0,
                        mr,
                        &bp,
                        nr,
                        k,
                        &mut sums,
                    );
                    for mi in 0..mr {
                        for ni in 0..nr {
                            let w = kernels::dot_on(
                                Backend::Scalar,
                                &b[ni * k..(ni + 1) * k],
                                &a[(m0 + mi) * k..(m0 + mi + 1) * k],
                            );
                            assert_eq!(
                                sums[mi * nr + ni].to_bits(),
                                w.to_bits(),
                                "k={k} nr={nr} m0={m0} mi={mi} ni={ni} backend {}",
                                bk.name()
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn gemm_tile_fma_allclose_to_exact() {
    // The opt-in relaxed mode is validated by CLOSENESS, not bit
    // identity: FMA contracts the multiply-add rounding, so its bits
    // may legitimately differ from the exact contract — the property is
    // that every element stays within a few accumulation ulps.
    let backends = available();
    prop::check_default("gemm_tile fma allclose to exact", |rng| {
        let k = random_k(rng);
        let mc = 1 + rng.below(MR);
        let nr = 1 + rng.below(NR);
        let a = prop::vec_f32(rng, mc * k, -2.0, 2.0);
        let b = prop::vec_f32(rng, nr * k, -2.0, 2.0);
        let ap = pack_strips(&a, mc, k);
        let bp = pack_strips(&b, nr, k);
        for &bk in &backends {
            let mut exact = [0.0f32; MR * NR];
            let mut fma = [0.0f32; MR * NR];
            kernels::gemm_tile_on(bk, FastMode::Exact, &ap, mc, 0, mc, &bp, nr, k, &mut exact);
            kernels::gemm_tile_on(bk, FastMode::Fma, &ap, mc, 0, mc, &bp, nr, k, &mut fma);
            for mi in 0..mc {
                for ni in 0..nr {
                    // |fma - exact| is bounded by a small multiple of
                    // eps times the sum of |a·b| magnitudes
                    let mag: f32 = (0..k)
                        .map(|t| (a[mi * k + t] * b[ni * k + t]).abs())
                        .sum();
                    let tol = 1e-3 * (1.0 + mag);
                    let (e, f) = (exact[mi * nr + ni], fma[mi * nr + ni]);
                    assert!(
                        (e - f).abs() <= tol,
                        "fma drifted past allclose: backend {} [{mi},{ni}] exact {e} fma {f} \
                         tol {tol} (k={k})",
                        bk.name()
                    );
                }
            }
        }
    });
}

#[test]
fn fast_mode_defaults_to_exact() {
    // Without AQUANT_FAST (or with it explicitly off) and without a
    // --fast-kernels request in this process, the resolved mode must be
    // the exact bit-identity contract.
    let env_exact = std::env::var("AQUANT_FAST")
        .map(|v| {
            let v = v.trim().to_ascii_lowercase();
            v.is_empty() || v == "exact" || v == "off"
        })
        .unwrap_or(true);
    if env_exact {
        assert_eq!(kernels::fast_mode(), FastMode::Exact);
        assert_eq!(kernels::fast_mode().name(), "exact");
    }
}

#[test]
fn fast_offset_within_2e3_of_exact_sigmoid() {
    // The paper's fast border approximation: B(x) = sigmoid(2.5u) with
    // the 0.5 offset folded out. The rational approximation must stay
    // within 2e-3 of the exact transcendental over a wide input range —
    // the bound the border-flip analysis in quant/border.rs relies on.
    for i in 0..=4000 {
        let u = (i as f32 - 2000.0) * 0.01; // [-20, 20]
        let exact = 1.0 / (1.0 + (-2.5f64 * u as f64).exp()) - 0.5;
        let fast = kernels::fast_offset(u) as f64;
        assert!(
            (fast - exact).abs() < 2e-3,
            "fast_offset({u}) = {fast}, exact {exact}, err {}",
            (fast - exact).abs()
        );
    }
}
