//! Differential properties of the SIMD microkernels: every available
//! backend (AVX2 / NEON) is **bit-identical** to the always-compiled
//! scalar reference, over random shapes including ragged tails that
//! exercise the scalar-tail delegation inside each vector loop. This is
//! the enforcement half of the bit-identity contract documented in
//! `rust/src/nn/kernels.rs` — serving results must not depend on which
//! ISA the host happens to have.

use aquant::nn::kernels::{self, Backend, LANES};
use aquant::util::prop;
use aquant::util::rng::Rng;

/// Backends the host CPU can actually run (scalar always; AVX2/NEON
/// when detected). Differential assertions loop over these.
fn available() -> Vec<Backend> {
    Backend::all().into_iter().filter(|b| b.available()).collect()
}

/// Random column length biased toward interesting shapes: lane-exact,
/// ragged by 1..LANES, shorter than one lane block, and empty.
fn random_len(rng: &mut Rng) -> usize {
    match rng.below(4) {
        0 => LANES * (1 + rng.below(8)),              // exact blocks
        1 => LANES * (1 + rng.below(8)) + 1 + rng.below(LANES - 1), // ragged
        2 => rng.below(LANES),                        // tail-only (incl. 0)
        _ => 1 + rng.below(257),                      // arbitrary
    }
}

fn assert_cols_eq(b: Backend, got: &[f32], want: &[f32], what: &str) {
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: backend {} differs from scalar at [{i}]: {g:?} ({:#010x}) vs {w:?} ({:#010x}) (len {})",
            b.name(),
            g.to_bits(),
            w.to_bits(),
            got.len()
        );
    }
}

#[test]
fn quantize_kernels_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("quant col kernels == scalar", |rng| {
        let n = random_len(rng);
        let col = prop::vec_f32(rng, n, -6.0, 6.0);
        let b0 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b1 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b2 = prop::vec_f32(rng, n, -2.0, 2.0);
        let s = rng.range_f32(0.01, 0.5);
        let inv_s = 1.0 / s;
        let (qmin, qmax) = (0.0f32, 15.0f32);

        let mut want = col.clone();
        kernels::nearest_col_on(Backend::Scalar, &mut want, s, inv_s, qmin, qmax);
        for &b in &backends {
            let mut got = col.clone();
            kernels::nearest_col_on(b, &mut got, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "nearest_col");
        }

        let mut want = col.clone();
        kernels::quant_col_lin_on(Backend::Scalar, &mut want, &b0, &b1, s, inv_s, qmin, qmax);
        for &b in &backends {
            let mut got = col.clone();
            kernels::quant_col_lin_on(b, &mut got, &b0, &b1, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "quant_col_lin");
        }

        let mut want = col.clone();
        kernels::quant_col_quad_on(
            Backend::Scalar,
            &mut want,
            &b0,
            &b1,
            &b2,
            s,
            inv_s,
            qmin,
            qmax,
        );
        for &b in &backends {
            let mut got = col.clone();
            kernels::quant_col_quad_on(b, &mut got, &b0, &b1, &b2, s, inv_s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "quant_col_quad");
        }
    });
}

#[test]
fn border_table_kernels_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("border/scale/round kernels == scalar", |rng| {
        let n = random_len(rng);
        let xs = prop::vec_f32(rng, n, -8.0, 8.0);
        let b0 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b1 = prop::vec_f32(rng, n, -2.0, 2.0);
        let b2 = prop::vec_f32(rng, n, -2.0, 2.0);
        let s = rng.range_f32(0.01, 0.5);
        let (qmin, qmax) = (-8.0f32, 7.0f32);

        let mut want = vec![0.0; n];
        kernels::borders_col_lin_on(Backend::Scalar, &xs, &b0, &b1, &mut want);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::borders_col_lin_on(b, &xs, &b0, &b1, &mut got);
            assert_cols_eq(b, &got, &want, "borders_col_lin");
        }

        let mut want = vec![0.0; n];
        kernels::borders_col_quad_on(Backend::Scalar, &xs, &b0, &b1, &b2, &mut want);
        let borders = want.clone();
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::borders_col_quad_on(b, &xs, &b0, &b1, &b2, &mut got);
            assert_cols_eq(b, &got, &want, "borders_col_quad");
        }

        let src = prop::vec_f32(rng, n, -5.0, 5.0);
        let mut want = vec![0.0; n];
        kernels::scale_col_on(Backend::Scalar, &src, 1.0 / s, &mut want);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::scale_col_on(b, &src, 1.0 / s, &mut got);
            assert_cols_eq(b, &got, &want, "scale_col");
        }

        let mut want = vec![0.0; n];
        kernels::round_col_on(Backend::Scalar, &mut want, &xs, &borders, s, qmin, qmax);
        for &b in &backends {
            let mut got = vec![0.0; n];
            kernels::round_col_on(b, &mut got, &xs, &borders, s, qmin, qmax);
            assert_cols_eq(b, &got, &want, "round_col");
        }
    });
}

#[test]
fn dot_bit_identical_across_backends() {
    let backends = available();
    prop::check_default("dot == scalar dot", |rng| {
        let n = random_len(rng);
        let w = prop::vec_f32(rng, n, -2.0, 2.0);
        let x = prop::vec_f32(rng, n, -2.0, 2.0);
        let want = kernels::dot_on(Backend::Scalar, &w, &x);
        for &b in &backends {
            let got = kernels::dot_on(b, &w, &x);
            assert!(
                got.to_bits() == want.to_bits(),
                "dot: backend {} {got:?} vs scalar {want:?} (len {n})",
                b.name()
            );
        }
    });
}

#[test]
fn active_dispatch_matches_explicit_backend() {
    // The plain entry points must route to exactly the active() backend
    // (the env-forced path is covered operationally: AQUANT_KERNELS is a
    // process-wide OnceLock, so within one test process we check the
    // resolved backend agrees with its explicit `_on` twin).
    let active = kernels::active();
    assert!(active.available());
    let mut rng = Rng::new(7);
    let n = LANES * 5 + 3;
    let col = prop::vec_f32(&mut rng, n, -4.0, 4.0);
    let b0 = prop::vec_f32(&mut rng, n, -1.0, 1.0);
    let b1 = prop::vec_f32(&mut rng, n, -1.0, 1.0);
    let (s, inv_s) = (0.1f32, 10.0f32);
    let mut via_plain = col.clone();
    kernels::quant_col_lin(&mut via_plain, &b0, &b1, s, inv_s, 0.0, 15.0);
    let mut via_on = col.clone();
    kernels::quant_col_lin_on(active, &mut via_on, &b0, &b1, s, inv_s, 0.0, 15.0);
    assert_eq!(via_plain, via_on);
}

#[test]
fn fast_offset_within_2e3_of_exact_sigmoid() {
    // The paper's fast border approximation: B(x) = sigmoid(2.5u) with
    // the 0.5 offset folded out. The rational approximation must stay
    // within 2e-3 of the exact transcendental over a wide input range —
    // the bound the border-flip analysis in quant/border.rs relies on.
    for i in 0..=4000 {
        let u = (i as f32 - 2000.0) * 0.01; // [-20, 20]
        let exact = 1.0 / (1.0 + (-2.5f64 * u as f64).exp()) - 0.5;
        let fast = kernels::fast_offset(u) as f64;
        assert!(
            (fast - exact).abs() < 2e-3,
            "fast_offset({u}) = {fast}, exact {exact}, err {}",
            (fast - exact).abs()
        );
    }
}
