//! Router-tier conformance: front-end processes running the same
//! readiness loop as serving mode, forwarding framed requests verbatim
//! to backend serving processes over pooled, pipelined connections.
//!
//! Scenarios: mixed v1/v2 traffic through N routers and M backends is
//! bit-identical to direct single-process serving (sequential clients,
//! a pipelined cross-backend burst answered in request order, and the
//! describe handshake); a killed backend fails only its own in-flight
//! window while other models keep answering (and the router keeps
//! retrying the dead address); slow-loris clients dribbling through
//! the router; a saturated in-flight window parking and retrying
//! without reordering; and the router's GET /stats surfacing
//! per-backend counters.
//!
//! Every test arms a [`common::Watchdog`] — a wedged loop aborts the
//! process rather than hanging CI (scripts/check.sh adds an outer
//! `timeout` belt on top).

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use aquant::config::{RouteSpec, ServeConfig};
use aquant::nn::engine::Engine;
use aquant::nn::registry::ModelRegistry;
use aquant::server::{
    classify_remote, classify_remote_v2, describe_remote, encode_describe_response, RouterServer,
    ServerStats,
};
use aquant::util::rng::Rng;

use common::{
    chunked_write, expect_closed, expected, random_images, read_response, start, synth_engine,
    v1_request_bytes, v2_request_bytes, Watchdog,
};

/// Two distinct engines registered at the SAME ids ("a" = 0, "b" = 1)
/// on every backend — frames forward verbatim (model ids are not
/// rewritten), so routed ids must line up across the tier. Traffic is
/// partitioned by the route table: id 0 goes to one backend, id 1 to
/// the other.
fn two_model_registry(a: &Arc<Engine>, b: &Arc<Engine>) -> Arc<ModelRegistry> {
    Arc::new(
        ModelRegistry::new(vec![("a".into(), a.clone()), ("b".into(), b.clone())])
            .expect("valid registry"),
    )
}

fn backend_cfg(max_accepts: usize) -> ServeConfig {
    ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 0,
        max_accepts: Some(max_accepts),
        ..ServeConfig::default()
    }
}

/// Bind an ephemeral-port router over `routes` and run it on its own
/// thread (the router-mode mirror of [`common::start`]).
fn start_router(
    routes: Vec<RouteSpec>,
    cfg: ServeConfig,
) -> (
    SocketAddr,
    Arc<ServerStats>,
    Arc<aquant::server::route::RouterStats>,
    JoinHandle<anyhow::Result<()>>,
) {
    let srv = RouterServer::bind(routes, "127.0.0.1:0", cfg).expect("bind router");
    let addr = srv.local_addr().expect("router addr");
    let stats = srv.stats();
    let rstats = srv.router_stats();
    let handle = std::thread::spawn(move || srv.run());
    (addr, stats, rstats, handle)
}

fn route(name: &str, addr: SocketAddr) -> RouteSpec {
    RouteSpec {
        name: name.into(),
        addr: addr.to_string(),
    }
}

#[test]
fn mixed_v1_v2_through_router_matches_direct_serving() {
    let _wd = Watchdog::arm("router_mixed_v1_v2", Duration::from_secs(120));
    let ea = synth_engine(201);
    let eb = synth_engine(202);
    let elems = ea.img_elems();
    let pool = 2usize;
    // both backends host both models at matching ids; the route table
    // sends "a" (id 0, the v1 default) to A and "b" (id 1) to B
    let (addr_a, _sa, backend_a) = start(two_model_registry(&ea, &eb), backend_cfg(pool));
    let (addr_b, _sb, backend_b) = start(two_model_registry(&ea, &eb), backend_cfg(pool));

    let sequential = 6usize;
    let cfg = ServeConfig {
        route_pool: pool,
        route_inflight: 32,
        max_accepts: Some(sequential + 2), // + pipelined burst + describe
        ..ServeConfig::default()
    };
    let (raddr, stats, rstats, router) =
        start_router(vec![route("a", addr_a), route("b", addr_b)], cfg);
    let ra = raddr.to_string();

    // sequential clients, alternating framings and models: every answer
    // bit-identical to the sequential engines (= direct serving)
    let mut rng = Rng::new(203);
    for k in 0..sequential {
        let n = 1 + k % 3;
        let images = random_images(&mut rng, n, elems);
        let got = match k % 3 {
            0 => classify_remote(&ra, &images, n).expect("v1 via router"),
            1 => classify_remote_v2(&ra, 0, &images, n).expect("v2 id0 via router"),
            _ => classify_remote_v2(&ra, 1, &images, n).expect("v2 id1 via router"),
        };
        let engine = if k % 3 == 2 { &eb } else { &ea };
        assert_eq!(got, expected(engine, &images, n), "sequential client {k}");
    }

    // one connection pipelines a mixed burst across BOTH backends:
    // replies may complete out of order across backends, but the
    // client must see them in request order, bit-identical
    let reqs: Vec<(u16, Vec<f32>, usize)> = (0..16)
        .map(|i| {
            let n = 1 + i % 2;
            (
                (i % 2) as u16,
                random_images(&mut rng, n, elems),
                n,
            )
        })
        .collect();
    let mut burst = Vec::new();
    for (i, (id, images, n)) in reqs.iter().enumerate() {
        if i % 4 == 0 {
            burst.extend_from_slice(&v1_request_bytes(images, *n as u32)); // routes to id 0
        } else {
            burst.extend_from_slice(&v2_request_bytes(*id, images, *n as u32));
        }
    }
    let mut s = TcpStream::connect(raddr).unwrap();
    s.write_all(&burst).unwrap();
    for (i, (id, images, n)) in reqs.iter().enumerate() {
        let engine = if i % 4 != 0 && *id == 1 { &eb } else { &ea };
        let got = read_response(&mut s).unwrap();
        assert_eq!(got, expected(engine, images, *n), "pipelined request {i}");
    }
    drop(s);

    // the router answers the describe handshake itself, from the dims
    // its backend handshakes learned (both completed: both models have
    // answered requests by now)
    assert_eq!(
        describe_remote(&ra).expect("describe via router"),
        vec![elems as u32, eb.img_elems() as u32]
    );

    router.join().unwrap().unwrap();
    backend_a.join().unwrap().unwrap();
    backend_b.join().unwrap().unwrap();

    // per-route request counters on the router match what was served
    let total = sequential + reqs.len();
    assert_eq!(stats.total_requests(), total as u64);
    // per-backend router counters: everything forwarded was answered,
    // nothing failed, no reconnects, the in-flight gauge drained
    let mut forwarded = 0u64;
    for b in &rstats.backends {
        forwarded += b.forwarded.load(Ordering::Relaxed);
        assert_eq!(
            b.forwarded.load(Ordering::Relaxed),
            b.answered.load(Ordering::Relaxed),
            "backend {}",
            b.addr
        );
        assert_eq!(b.failed.load(Ordering::Relaxed), 0);
        assert_eq!(b.inflight.load(Ordering::Relaxed), 0);
        assert_eq!(b.reconnects.load(Ordering::Relaxed), 0);
        assert_eq!(b.rtt.count(), b.answered.load(Ordering::Relaxed));
    }
    assert_eq!(forwarded, total as u64);
}

#[test]
fn two_routers_share_backends_bit_identically() {
    let _wd = Watchdog::arm("two_routers", Duration::from_secs(120));
    let ea = synth_engine(211);
    let eb = synth_engine(212);
    let elems = ea.img_elems();
    let routers = 2usize;
    let pool = 2usize;
    // each backend accepts one pool per router
    let (addr_a, _sa, backend_a) = start(two_model_registry(&ea, &eb), backend_cfg(routers * pool));
    let (addr_b, _sb, backend_b) = start(two_model_registry(&ea, &eb), backend_cfg(routers * pool));

    let cfg = ServeConfig {
        route_pool: pool,
        route_inflight: 8,
        max_accepts: Some(4),
        ..ServeConfig::default()
    };
    let handles: Vec<_> = (0..routers)
        .map(|_| start_router(vec![route("a", addr_a), route("b", addr_b)], cfg.clone()))
        .collect();

    let mut rng = Rng::new(213);
    for (r, (raddr, ..)) in handles.iter().enumerate() {
        let ra = raddr.to_string();
        for k in 0..4 {
            let n = 1 + (r + k) % 3;
            let images = random_images(&mut rng, n, elems);
            let (got, engine) = match k {
                0 => (classify_remote(&ra, &images, n).unwrap(), &ea),
                1 => (classify_remote_v2(&ra, 0, &images, n).unwrap(), &ea),
                _ => (classify_remote_v2(&ra, 1, &images, n).unwrap(), &eb),
            };
            assert_eq!(got, expected(engine, &images, n), "router {r} client {k}");
        }
    }

    for (_, stats, rstats, router) in handles {
        router.join().unwrap().unwrap();
        assert_eq!(stats.total_requests(), 4);
        for b in &rstats.backends {
            assert_eq!(b.failed.load(Ordering::Relaxed), 0);
            assert_eq!(b.inflight.load(Ordering::Relaxed), 0);
        }
    }
    backend_a.join().unwrap().unwrap();
    backend_b.join().unwrap().unwrap();
}

/// A hand-rolled "backend" that completes the describe handshake and
/// then drops any connection as soon as a forwarded frame starts to
/// arrive — a backend dying mid-flight, deterministically.
fn start_dying_backend(dims: Vec<u32>, pool: usize) -> (SocketAddr, JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind fake backend");
    let addr = listener.local_addr().unwrap();
    let acceptor = std::thread::spawn(move || {
        let handlers: Vec<JoinHandle<()>> = (0..pool)
            .map(|_| {
                let (mut s, _) = listener.accept().expect("router pool connect");
                let desc = encode_describe_response(&dims);
                std::thread::spawn(move || {
                    let mut hdr = [0u8; 8];
                    if s.read_exact(&mut hdr).is_err() {
                        return; // router gone before the handshake
                    }
                    s.write_all(&desc).ok();
                    // wait for the first forwarded byte (or router
                    // shutdown EOF), then drop: dead mid-request
                    let mut b = [0u8; 256];
                    let _ = s.read(&mut b);
                })
            })
            .collect();
        // listener drops here: reconnects get ECONNREFUSED
        drop(listener);
        for h in handlers {
            h.join().unwrap();
        }
    });
    (addr, acceptor)
}

#[test]
fn killed_backend_fails_only_its_inflight_window() {
    let _wd = Watchdog::arm("killed_backend", Duration::from_secs(120));
    let ea = synth_engine(221);
    let elems = ea.img_elems();
    let pool = 2usize;
    // model "a" on a real backend; model "b" on a backend that dies the
    // moment a request reaches it. Its describe table must still host
    // id 1 (id 0 lives elsewhere, so its entry may be 0).
    let reg_a = Arc::new(ModelRegistry::new(vec![("a".into(), ea.clone())]).unwrap());
    let (addr_a, _sa, backend_a) = start(reg_a, backend_cfg(pool));
    let (addr_b, dying) = start_dying_backend(vec![0, elems as u32], pool);

    let cfg = ServeConfig {
        route_pool: pool,
        route_inflight: 8,
        max_accepts: Some(4),
        ..ServeConfig::default()
    };
    let (raddr, _stats, rstats, router) =
        start_router(vec![route("a", addr_a), route("b", addr_b)], cfg);
    let ra = raddr.to_string();

    // model "a" serves before the failure...
    let mut rng = Rng::new(222);
    let images = random_images(&mut rng, 2, elems);
    assert_eq!(
        classify_remote(&ra, &images, 2).unwrap(),
        expected(&ea, &images, 2)
    );

    // ...a request for "b" reaches the dying backend: exactly that
    // connection's in-flight window fails, and the client whose request
    // it was is closed without an answer
    let doomed_images = random_images(&mut rng, 1, elems);
    let mut doomed = TcpStream::connect(raddr).unwrap();
    doomed
        .write_all(&v2_request_bytes(1, &doomed_images, 1))
        .unwrap();
    expect_closed(doomed);

    // ...and model "a" keeps answering, bit-identical, afterwards
    let images = random_images(&mut rng, 3, elems);
    assert_eq!(
        classify_remote(&ra, &images, 3).unwrap(),
        expected(&ea, &images, 3)
    );

    // hold a connection open so the router outlives the reconnect
    // backoff (50 ms), then check the isolation ledger while it's live
    let holder = TcpStream::connect(raddr).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    let b_stats = rstats
        .backends
        .iter()
        .find(|b| b.addr == addr_b.to_string())
        .expect("dying backend entry");
    assert!(b_stats.failed.load(Ordering::Relaxed) >= 1, "doomed request failed");
    assert_eq!(b_stats.inflight.load(Ordering::Relaxed), 0);
    assert!(
        b_stats.reconnects.load(Ordering::Relaxed) >= 1,
        "router keeps retrying the dead backend"
    );
    let a_stats = rstats
        .backends
        .iter()
        .find(|b| b.addr == addr_a.to_string())
        .unwrap();
    assert_eq!(a_stats.failed.load(Ordering::Relaxed), 0, "healthy backend untouched");
    assert_eq!(a_stats.answered.load(Ordering::Relaxed), 2);

    drop(holder);
    router.join().unwrap().unwrap();
    backend_a.join().unwrap().unwrap();
    dying.join().unwrap();
}

#[test]
fn slow_loris_through_the_router_is_served_not_buffered_to_death() {
    let _wd = Watchdog::arm("router_slow_loris", Duration::from_secs(120));
    let ea = synth_engine(231);
    let eb = synth_engine(232);
    let elems = ea.img_elems();
    let pool = 2usize;
    let (addr_a, _sa, backend_a) = start(two_model_registry(&ea, &eb), backend_cfg(pool));
    let (addr_b, _sb, backend_b) = start(two_model_registry(&ea, &eb), backend_cfg(pool));

    let lorises = 4usize;
    let cfg = ServeConfig {
        route_pool: pool,
        route_inflight: 8,
        max_accepts: Some(lorises),
        ..ServeConfig::default()
    };
    let (raddr, _stats, rstats, router) =
        start_router(vec![route("a", addr_a), route("b", addr_b)], cfg);

    // dribble whole requests a few bytes at a time — the first one
    // starts before the backend handshakes can possibly be done, so the
    // gate-park (header decoded, no capacity knowledge yet) is on the
    // path too
    let mut rng = Rng::new(233);
    for k in 0..lorises {
        let n = 1 + k % 2;
        let images = random_images(&mut rng, n, elems);
        let (bytes, engine) = if k % 2 == 0 {
            (v1_request_bytes(&images, n as u32), &ea)
        } else {
            (v2_request_bytes(1, &images, n as u32), &eb)
        };
        let mut s = TcpStream::connect(raddr).unwrap();
        chunked_write(&mut s, &bytes, 7, Duration::from_millis(2)).unwrap();
        let got = read_response(&mut s).unwrap();
        assert_eq!(got, expected(engine, &images, n), "loris {k}");
    }

    router.join().unwrap().unwrap();
    backend_a.join().unwrap().unwrap();
    backend_b.join().unwrap().unwrap();
    for b in &rstats.backends {
        assert_eq!(b.failed.load(Ordering::Relaxed), 0);
        assert_eq!(b.inflight.load(Ordering::Relaxed), 0);
    }
}

#[test]
fn saturated_inflight_window_parks_and_answers_in_order() {
    let _wd = Watchdog::arm("router_saturation", Duration::from_secs(120));
    let ea = synth_engine(241);
    let elems = ea.img_elems();
    // one backend connection with a one-request window: a pipelined
    // burst must park at the gate, retry as replies free the window,
    // and still come back in request order
    let reg = Arc::new(ModelRegistry::new(vec![("a".into(), ea.clone())]).unwrap());
    let (addr_a, _sa, backend_a) = start(reg, backend_cfg(1));

    let cfg = ServeConfig {
        route_pool: 1,
        route_inflight: 1,
        max_accepts: Some(1),
        ..ServeConfig::default()
    };
    let (raddr, _stats, rstats, router) = start_router(vec![route("a", addr_a)], cfg);

    let mut rng = Rng::new(242);
    let reqs: Vec<(Vec<f32>, usize)> = (0..8)
        .map(|i| {
            let n = 1 + i % 3;
            (random_images(&mut rng, n, elems), n)
        })
        .collect();
    let mut burst = Vec::new();
    for (i, (images, n)) in reqs.iter().enumerate() {
        if i % 2 == 0 {
            burst.extend_from_slice(&v1_request_bytes(images, *n as u32));
        } else {
            burst.extend_from_slice(&v2_request_bytes(0, images, *n as u32));
        }
    }
    let mut s = TcpStream::connect(raddr).unwrap();
    s.write_all(&burst).unwrap();
    for (i, (images, n)) in reqs.iter().enumerate() {
        let got = read_response(&mut s).unwrap();
        assert_eq!(got, expected(&ea, images, *n), "burst request {i}");
    }
    drop(s);
    router.join().unwrap().unwrap();
    backend_a.join().unwrap().unwrap();

    let b = &rstats.backends[0];
    assert_eq!(b.forwarded.load(Ordering::Relaxed), reqs.len() as u64);
    assert_eq!(b.answered.load(Ordering::Relaxed), reqs.len() as u64);
    assert_eq!(b.inflight.load(Ordering::Relaxed), 0);
}

/// Minimal HTTP GET against the router's stats endpoint.
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect stats");
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut body = String::new();
    s.read_to_string(&mut body).expect("read stats response");
    body
}

#[test]
fn router_stats_endpoint_reports_per_backend_counters() {
    let _wd = Watchdog::arm("router_stats_endpoint", Duration::from_secs(120));
    let ea = synth_engine(251);
    let elems = ea.img_elems();
    let reg = Arc::new(ModelRegistry::new(vec![("a".into(), ea.clone())]).unwrap());
    let (addr_a, _sa, backend_a) = start(reg, backend_cfg(2));

    let cfg = ServeConfig {
        route_pool: 2,
        route_inflight: 8,
        max_accepts: Some(2),
        stats_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    };
    let srv = RouterServer::bind(vec![route("a", addr_a)], "127.0.0.1:0", cfg).unwrap();
    let raddr = srv.local_addr().unwrap();
    let stats_addr = srv.stats_local_addr().expect("stats endpoint bound");
    let router = std::thread::spawn(move || srv.run());

    let mut rng = Rng::new(252);
    let images = random_images(&mut rng, 2, elems);
    assert_eq!(
        classify_remote(&raddr.to_string(), &images, 2).unwrap(),
        expected(&ea, &images, 2)
    );

    // hold the loop open while scraping (accepts are exhausted once the
    // holder connects; the stats listener is independent of that)
    let holder = TcpStream::connect(raddr).unwrap();
    let json = http_get(stats_addr, "/stats");
    assert!(json.contains("\"router\""), "JSON router section: {json}");
    assert!(json.contains("\"backends\""));
    assert!(
        json.contains(&format!("\"{addr_a}\"")),
        "backend addr in JSON: {json}"
    );
    assert!(json.contains("\"forwarded\""));
    let text = http_get(stats_addr, "/stats?fmt=text");
    assert!(
        text.contains(&format!("backend {addr_a}")),
        "backend line in text: {text}"
    );
    drop(holder);
    router.join().unwrap().unwrap();
    backend_a.join().unwrap().unwrap();
}
