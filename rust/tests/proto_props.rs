//! Protocol framing properties: v1/v2 request headers survive an
//! encode → decode round trip for arbitrary model ids, versions, and
//! image counts; truncation at every byte boundary behaves as specified
//! (clean EOF inside the 4-byte sniff window, `UnexpectedEof` inside a
//! started v2 frame); and byte-sniffing can never misroute a valid v1
//! request.

use std::io::ErrorKind;

use aquant::server::{
    encode_header_v2, read_request_header, RequestHeader, MAGIC, MAX_REQ_IMAGES, PROTO_VERSION,
    V2_HEADER_LEN,
};
use aquant::util::prop;

#[test]
fn v1_header_roundtrips_for_any_n() {
    prop::check_default("v1 encode/decode", |rng| {
        let n = rng.next_u64() as u32;
        let h = RequestHeader::V1 { n };
        let bytes = h.encode();
        assert_eq!(bytes.len(), 4);
        // 1 in 2^32 random n values spells MAGIC and legitimately reads
        // as the start of a v2 frame — such an n can never pass the
        // <= MAX_REQ_IMAGES range check, so the server rejects it under
        // either reading. Round-trip only the unambiguous majority.
        if bytes == MAGIC {
            return;
        }
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 0, "v1 always routes to the default model");
        assert!(r.is_empty(), "decode must consume exactly the header");
    });
}

#[test]
fn v2_header_roundtrips_for_any_fields() {
    prop::check_default("v2 encode/decode", |rng| {
        let version = rng.next_u64() as u16;
        let model_id = rng.next_u64() as u16;
        let n = rng.next_u64() as u32;
        let h = RequestHeader::V2 {
            version,
            model_id,
            n,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len(), V2_HEADER_LEN);
        assert_eq!(&bytes[..4], &MAGIC);
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), model_id);
        assert_eq!(got.n(), n);
        assert!(r.is_empty());
        // the convenience encoder agrees with RequestHeader::encode at
        // the current protocol version
        if version == PROTO_VERSION {
            assert_eq!(bytes, encode_header_v2(model_id, n).to_vec());
        }
    });
}

#[test]
fn decode_leaves_reader_at_payload_start() {
    // Streamed decoding depends on the header reader consuming exactly
    // the header bytes: whatever follows must still be readable.
    prop::check_default("header consumes exactly itself", |rng| {
        let payload: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        let h = if rng.bernoulli(0.5) {
            RequestHeader::V1 {
                n: 1 + rng.below(MAX_REQ_IMAGES) as u32,
            }
        } else {
            RequestHeader::V2 {
                version: PROTO_VERSION,
                model_id: rng.next_u64() as u16,
                n: 1 + rng.below(MAX_REQ_IMAGES) as u32,
            }
        };
        let mut bytes = h.encode();
        bytes.extend_from_slice(&payload);
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(r, &payload[..]);
    });
}

#[test]
fn truncation_at_every_boundary_is_well_defined() {
    prop::check_default("truncated headers", |rng| {
        let h = RequestHeader::V2 {
            version: rng.next_u64() as u16,
            model_id: rng.next_u64() as u16,
            n: rng.next_u64() as u32,
        };
        let bytes = h.encode();
        for cut in 0..bytes.len() {
            let mut r = &bytes[..cut];
            match read_request_header(&mut r) {
                // EOF before the sniff window fills = clean end of a
                // pipelined connection
                Ok(None) => assert!(cut < 4, "cut={cut} misread as clean EOF"),
                // EOF after the magic word = truncated v2 frame
                Err(e) => {
                    assert!(cut >= 4, "cut={cut} errored inside the sniff window");
                    assert_eq!(e.kind(), ErrorKind::UnexpectedEof, "cut={cut}");
                }
                Ok(Some(got)) => panic!("cut={cut} decoded {got:?} from a truncated frame"),
            }
        }
    });
}

#[test]
fn valid_v1_requests_are_never_sniffed_as_v2() {
    // The whole backward-compat story rests on this: every n the v1
    // protocol accepts (1..=4096) produces a header whose first bytes
    // differ from MAGIC.
    for n in 1..=MAX_REQ_IMAGES as u32 {
        let bytes = RequestHeader::V1 { n }.encode();
        assert_ne!(bytes[..], MAGIC[..], "n={n} collides with the magic word");
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, RequestHeader::V1 { n });
    }
    // and the magic word itself, read as v1, is out of protocol range
    assert!(u32::from_le_bytes(MAGIC) as usize > MAX_REQ_IMAGES);
}
