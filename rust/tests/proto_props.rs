//! Protocol framing properties: v1/v2 request headers survive an
//! encode → decode round trip for arbitrary model ids, versions, and
//! image counts; truncation at every byte boundary behaves as specified
//! (clean EOF inside the 4-byte sniff window, `UnexpectedEof` inside a
//! started v2 frame); byte-sniffing can never misroute a valid v1
//! request; and the event loop's incremental [`RequestDecoder`] (a)
//! never panics on arbitrary byte streams — random prefixes of valid
//! frames, pure garbage, any chunking — (b) always terminates each
//! stream in a clean close decision or a complete request, and (c)
//! agrees byte-for-byte with the blocking reader on valid frames.
//!
//! Router-path properties: the decoder's RAW mode (forwarding without
//! recompute) rebuilds every frame byte-identically to what the client
//! sent, under any chunking, and never panics or over-consumes on
//! garbage; [`ReplyReader`] parses pipelined backend replies one frame
//! per feed without over-consuming; and per-backend FIFO
//! re-association delivers every reply to the request that owns it
//! under arbitrary cross-backend completion interleavings, with a
//! failed window erroring exactly its own members.

use std::io::ErrorKind;

use aquant::server::conn::{Decoded, RequestDecoder};
use aquant::server::route::{complete_front, fail_window, PendingReply, ReplyReader, RouterStats};
use aquant::server::{
    encode_header_v2, read_request_header, RequestHeader, DESC_HEADER_LEN, MAGIC, MAGIC_DESC,
    MAX_REQ_IMAGES, PROTO_VERSION, V2_HEADER_LEN,
};
use aquant::util::prop;
use aquant::util::rng::Rng;

#[test]
fn v1_header_roundtrips_for_any_n() {
    prop::check_default("v1 encode/decode", |rng| {
        let n = rng.next_u64() as u32;
        let h = RequestHeader::V1 { n };
        let bytes = h.encode();
        assert_eq!(bytes.len(), 4);
        // 1 in 2^32 random n values spells MAGIC and legitimately reads
        // as the start of a v2 frame — such an n can never pass the
        // <= MAX_REQ_IMAGES range check, so the server rejects it under
        // either reading. Round-trip only the unambiguous majority.
        if bytes == MAGIC {
            return;
        }
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 0, "v1 always routes to the default model");
        assert!(r.is_empty(), "decode must consume exactly the header");
    });
}

#[test]
fn v2_header_roundtrips_for_any_fields() {
    prop::check_default("v2 encode/decode", |rng| {
        let version = rng.next_u64() as u16;
        let model_id = rng.next_u64() as u16;
        let n = rng.next_u64() as u32;
        let h = RequestHeader::V2 {
            version,
            model_id,
            n,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len(), V2_HEADER_LEN);
        assert_eq!(&bytes[..4], &MAGIC);
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), model_id);
        assert_eq!(got.n(), n);
        assert!(r.is_empty());
        // the convenience encoder agrees with RequestHeader::encode at
        // the current protocol version
        if version == PROTO_VERSION {
            assert_eq!(bytes, encode_header_v2(model_id, n).to_vec());
        }
    });
}

#[test]
fn decode_leaves_reader_at_payload_start() {
    // Streamed decoding depends on the header reader consuming exactly
    // the header bytes: whatever follows must still be readable.
    prop::check_default("header consumes exactly itself", |rng| {
        let payload: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        let h = if rng.bernoulli(0.5) {
            RequestHeader::V1 {
                n: 1 + rng.below(MAX_REQ_IMAGES) as u32,
            }
        } else {
            RequestHeader::V2 {
                version: PROTO_VERSION,
                model_id: rng.next_u64() as u16,
                n: 1 + rng.below(MAX_REQ_IMAGES) as u32,
            }
        };
        let mut bytes = h.encode();
        bytes.extend_from_slice(&payload);
        let mut r = &bytes[..];
        let got = read_request_header(&mut r).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(r, &payload[..]);
    });
}

#[test]
fn truncation_at_every_boundary_is_well_defined() {
    prop::check_default("truncated headers", |rng| {
        let h = RequestHeader::V2 {
            version: rng.next_u64() as u16,
            model_id: rng.next_u64() as u16,
            n: rng.next_u64() as u32,
        };
        let bytes = h.encode();
        for cut in 0..bytes.len() {
            let mut r = &bytes[..cut];
            match read_request_header(&mut r) {
                // EOF before the sniff window fills = clean end of a
                // pipelined connection
                Ok(None) => assert!(cut < 4, "cut={cut} misread as clean EOF"),
                // EOF after the magic word = truncated v2 frame
                Err(e) => {
                    assert!(cut >= 4, "cut={cut} errored inside the sniff window");
                    assert_eq!(e.kind(), ErrorKind::UnexpectedEof, "cut={cut}");
                }
                Ok(Some(got)) => panic!("cut={cut} decoded {got:?} from a truncated frame"),
            }
        }
    });
}

/// Drive the incremental decoder over `stream` exactly the way the
/// event loop does (arbitrary chunk sizes, server-side n/version/model
/// validation at the header gate), collecting completed requests.
/// Returns `(requests, rejected)` where `rejected` means the emulated
/// server decided to drop the connection. Every call must terminate —
/// the loop is bounded by the stream length — and must never panic,
/// whatever the bytes are.
fn drive_decoder(
    stream: &[u8],
    rng: &mut Rng,
    img_elems: usize,
) -> (Vec<(RequestHeader, Vec<f32>)>, bool) {
    let mut dec = RequestDecoder::new();
    let mut requests = Vec::new();
    let mut off = 0usize;
    while off < stream.len() {
        if let Some(hdr) = dec.gated() {
            // the server's validation order: version, model id, n
            let bad_version = matches!(hdr, RequestHeader::V2 { version, .. }
                if version != PROTO_VERSION);
            let n = hdr.n() as usize;
            if bad_version || hdr.model_id() != 0 || n == 0 || n > MAX_REQ_IMAGES {
                return (requests, true);
            }
            dec.begin_payload(img_elems);
            continue;
        }
        // feed an arbitrary-sized slice; the decoder consumes at most
        // want() bytes and must report the consumption honestly
        let chunk = 1 + rng.below(16);
        let end = (off + chunk).min(stream.len());
        let want_before = dec.want();
        let (consumed, event) = dec.feed(&stream[off..end]);
        assert!(consumed <= end - off, "decoder over-consumed");
        assert!(consumed <= want_before, "decoder consumed past want()");
        assert!(
            consumed > 0 || want_before == 0,
            "decoder stalled with bytes available"
        );
        off += consumed;
        if let Decoded::Request { header, images } = event {
            assert_eq!(images.len(), header.n() as usize * img_elems);
            requests.push((header, images));
        }
    }
    (requests, false)
}

#[test]
fn decoder_never_panics_on_valid_frame_prefixes() {
    // Random prefixes of pipelined valid v1/v2 frames, fed in random
    // chunks: whatever survives the cut decodes to exactly the frames
    // that fit, and the tail is silently incomplete (the event loop's
    // EOF handling decides clean-vs-truncated; the decoder just must
    // not lie, loop, or panic).
    prop::check_default("decoder on valid prefixes", |rng| {
        let img_elems = 1 + rng.below(8);
        let mut stream = Vec::new();
        let mut frames = Vec::new();
        for _ in 0..1 + rng.below(4) {
            let n = 1 + rng.below(5) as u32;
            let images: Vec<f32> = (0..n as usize * img_elems)
                .map(|_| rng.normal())
                .collect();
            let header = if rng.bernoulli(0.5) {
                RequestHeader::V1 { n }
            } else {
                RequestHeader::V2 {
                    version: PROTO_VERSION,
                    model_id: 0,
                    n,
                }
            };
            stream.extend_from_slice(&header.encode());
            for v in &images {
                stream.extend_from_slice(&v.to_le_bytes());
            }
            frames.push((header, images, stream.len()));
        }
        let cut = rng.below(stream.len() + 1);
        let (requests, rejected) = drive_decoder(&stream[..cut], rng, img_elems);
        assert!(!rejected, "valid frames must not be rejected");
        let complete = frames.iter().take_while(|(_, _, end)| *end <= cut).count();
        assert_eq!(requests.len(), complete, "cut={cut}");
        for ((h, imgs, _), (gh, gimgs)) in frames.iter().zip(&requests) {
            assert_eq!(h, gh);
            assert_eq!(imgs, gimgs);
        }
    });
}

#[test]
fn decoder_never_panics_on_garbage_and_always_terminates() {
    // Pure garbage (and garbage spliced after a valid frame): the
    // decoder either parses a header the server rejects — terminating
    // the connection — or keeps waiting for bytes that will never make
    // a full frame. No panic, no infinite loop, no over-consumption,
    // and bounded allocation (payload space only ever follows an
    // accepted header).
    prop::check_default("decoder on garbage", |rng| {
        let img_elems = 1 + rng.below(8);
        let mut stream: Vec<u8> = Vec::new();
        if rng.bernoulli(0.3) {
            // valid frame first: garbage after a request must not
            // corrupt the requests decoded before it
            let n = 1 + rng.below(3) as u32;
            stream.extend_from_slice(&RequestHeader::V1 { n }.encode());
            for _ in 0..n as usize * img_elems {
                stream.extend_from_slice(&rng.normal().to_le_bytes());
            }
        }
        let valid_len = stream.len();
        let junk = 1 + rng.below(256);
        stream.extend((0..junk).map(|_| rng.next_u64() as u8));
        let (requests, _rejected) = drive_decoder(&stream, rng, img_elems);
        // every request decoded before the garbage is intact
        for (h, imgs) in &requests {
            assert_eq!(imgs.len(), h.n() as usize * img_elems);
        }
        if valid_len > 0 {
            assert!(!requests.is_empty(), "valid frame lost to trailing garbage");
        }
    });
}

#[test]
fn incremental_decoder_agrees_with_blocking_reader_on_headers() {
    prop::check_default("incremental vs blocking header decode", |rng| {
        let h = if rng.bernoulli(0.5) {
            RequestHeader::V1 {
                n: rng.next_u64() as u32,
            }
        } else {
            RequestHeader::V2 {
                version: rng.next_u64() as u16,
                model_id: rng.next_u64() as u16,
                n: rng.next_u64() as u32,
            }
        };
        let bytes = h.encode();
        if bytes[..4] == MAGIC && matches!(h, RequestHeader::V1 { .. }) {
            return; // the one ambiguous v1 value; rejected either way
        }
        let blocking = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        let mut dec = RequestDecoder::new();
        let mut gated = None;
        let mut off = 0;
        while off < bytes.len() && gated.is_none() {
            let (c, ev) = dec.feed(&bytes[off..off + 1]);
            off += c;
            if let Decoded::Header(g) = ev {
                gated = Some(g);
            }
        }
        assert_eq!(gated, Some(blocking));
        assert_eq!(dec.gated(), Some(blocking));
    });
}

#[test]
fn valid_v1_requests_are_never_sniffed_as_v2() {
    // The whole backward-compat story rests on this: every n the v1
    // protocol accepts (1..=4096) produces a header whose first bytes
    // differ from MAGIC.
    for n in 1..=MAX_REQ_IMAGES as u32 {
        let bytes = RequestHeader::V1 { n }.encode();
        assert_ne!(bytes[..], MAGIC[..], "n={n} collides with the magic word");
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, RequestHeader::V1 { n });
    }
    // and the magic word itself, read as v1, is out of protocol range
    assert!(u32::from_le_bytes(MAGIC) as usize > MAX_REQ_IMAGES);
}

#[test]
fn describe_magic_is_sniff_disjoint_and_roundtrips() {
    // the describe handshake word must collide with neither a valid v1
    // count nor the v2 magic — the 4-byte sniff stays unambiguous
    assert!(u32::from_le_bytes(MAGIC_DESC) as usize > MAX_REQ_IMAGES);
    assert_ne!(MAGIC_DESC, MAGIC);
    let h = RequestHeader::Describe {
        version: PROTO_VERSION,
    };
    let bytes = h.encode();
    assert_eq!(bytes.len(), DESC_HEADER_LEN);
    assert_eq!(&bytes[..4], &MAGIC_DESC);
    let mut r = &bytes[..];
    assert_eq!(read_request_header(&mut r).unwrap().unwrap(), h);
    assert!(r.is_empty());
}

/// Drive the decoder in RAW (router/forwarding) mode the way the
/// router's event loop does: gate headers, size payloads from a
/// per-model dimension table, collect rebuilt wire frames. Returns
/// `(frames, rejected)` with the same termination/consumption
/// assertions as [`drive_decoder`].
fn drive_raw(
    stream: &[u8],
    rng: &mut Rng,
    elems_by_id: &[u32],
) -> (Vec<(RequestHeader, Vec<u8>)>, bool) {
    let mut dec = RequestDecoder::new();
    let mut frames = Vec::new();
    let mut off = 0usize;
    while off < stream.len() {
        if let Some(hdr) = dec.gated() {
            let bad_version = matches!(hdr,
                RequestHeader::V2 { version, .. } | RequestHeader::Describe { version }
                    if version != PROTO_VERSION);
            if bad_version {
                return (frames, true);
            }
            if matches!(hdr, RequestHeader::Describe { .. }) {
                // payload-less: the router answers it and re-arms
                dec.reset();
                continue;
            }
            let n = hdr.n() as usize;
            let Some(&elems) = elems_by_id.get(hdr.model_id() as usize) else {
                return (frames, true); // unroutable model id
            };
            if n == 0 || n > MAX_REQ_IMAGES {
                return (frames, true);
            }
            dec.begin_payload_raw(n * elems as usize * 4);
            continue;
        }
        let chunk = 1 + rng.below(16);
        let end = (off + chunk).min(stream.len());
        let want_before = dec.want();
        let (consumed, event) = dec.feed(&stream[off..end]);
        assert!(consumed <= end - off, "raw decoder over-consumed");
        assert!(consumed <= want_before, "raw decoder consumed past want()");
        assert!(
            consumed > 0 || want_before == 0,
            "raw decoder stalled with bytes available"
        );
        off += consumed;
        if let Decoded::RequestRaw { header, frame } = event {
            frames.push((header, frame));
        }
    }
    (frames, false)
}

#[test]
fn raw_decoder_rebuilds_every_forwarded_frame_byte_identically() {
    // The router's zero-recompute guarantee: whatever chunking the
    // client uses, the frame handed to the backend is byte-for-byte
    // the frame the client sent (describes interleaved freely — they
    // are answered locally, never forwarded).
    prop::check_default("raw mode is byte-identical", |rng| {
        let elems_by_id: Vec<u32> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(6) as u32).collect();
        let mut stream = Vec::new();
        let mut spans: Vec<(usize, usize)> = Vec::new(); // non-describe frames
        for _ in 0..1 + rng.below(4) {
            let start = stream.len();
            if rng.bernoulli(0.2) {
                stream.extend_from_slice(
                    &RequestHeader::Describe {
                        version: PROTO_VERSION,
                    }
                    .encode(),
                );
                continue;
            }
            let id = rng.below(elems_by_id.len()) as u16;
            let n = 1 + rng.below(4) as u32;
            let header = if id == 0 && rng.bernoulli(0.5) {
                RequestHeader::V1 { n }
            } else {
                RequestHeader::V2 {
                    version: PROTO_VERSION,
                    model_id: id,
                    n,
                }
            };
            stream.extend_from_slice(&header.encode());
            for _ in 0..n as usize * elems_by_id[id as usize] as usize {
                stream.extend_from_slice(&rng.normal().to_le_bytes());
            }
            spans.push((start, stream.len()));
        }
        let (frames, rejected) = drive_raw(&stream, rng, &elems_by_id);
        assert!(!rejected, "valid frames must not be rejected");
        assert_eq!(frames.len(), spans.len());
        for (i, ((start, end), (_, frame))) in spans.iter().zip(&frames).enumerate() {
            assert_eq!(frame, &stream[*start..*end], "frame {i} not byte-identical");
        }
    });
}

#[test]
fn raw_decoder_never_panics_on_garbage() {
    // Same hostile streams as the local-serving decoder fuzz, driven
    // through the raw gate: terminate (reject or starve), never panic,
    // never over-consume, and any frame completed before the garbage
    // is still byte-identical.
    prop::check_default("raw decoder on garbage", |rng| {
        let elems_by_id = [1 + rng.below(6) as u32, 1 + rng.below(6) as u32];
        let mut stream: Vec<u8> = Vec::new();
        let mut valid_spans: Vec<(usize, usize)> = Vec::new();
        if rng.bernoulli(0.3) {
            let n = 1 + rng.below(3) as u32;
            let start = stream.len();
            stream.extend_from_slice(&RequestHeader::V1 { n }.encode());
            for _ in 0..n as usize * elems_by_id[0] as usize {
                stream.extend_from_slice(&rng.normal().to_le_bytes());
            }
            valid_spans.push((start, stream.len()));
        }
        let junk = 1 + rng.below(256);
        stream.extend((0..junk).map(|_| rng.next_u64() as u8));
        let (frames, _rejected) = drive_raw(&stream, rng, &elems_by_id);
        for ((start, end), (_, frame)) in valid_spans.iter().zip(&frames) {
            assert_eq!(frame, &stream[*start..*end]);
        }
        assert!(
            frames.len() >= valid_spans.len(),
            "valid frame lost to trailing garbage"
        );
    });
}

#[test]
fn reply_reader_parses_pipelined_replies_and_survives_garbage() {
    prop::check_default("reply reader", |rng| {
        // valid pipelined reply frames, arbitrary chunking and cut
        let mut frames: Vec<Vec<u32>> = Vec::new();
        let mut stream = Vec::new();
        let mut ends = Vec::new();
        for _ in 0..1 + rng.below(5) {
            let n = 1 + rng.below(8);
            let words: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            stream.extend_from_slice(&(n as u32).to_le_bytes());
            for w in &words {
                stream.extend_from_slice(&w.to_le_bytes());
            }
            frames.push(words);
            ends.push(stream.len());
        }
        let cut = rng.below(stream.len() + 1);
        let mut rd = ReplyReader::new();
        let mut got: Vec<Vec<u32>> = Vec::new();
        let mut off = 0usize;
        while off < cut {
            let chunk = 1 + rng.below(7);
            let end = (off + chunk).min(cut);
            let (used, done) = rd.feed(&stream[off..end]).expect("valid replies");
            assert!(used > 0 && used <= end - off, "honest consumption");
            off += used;
            if let Some(f) = done {
                // one frame per feed: consumption stopped exactly at
                // this frame's boundary, pipelined bytes left alone
                assert_eq!(off, ends[got.len()], "over-consumed past a frame");
                got.push(f);
            }
        }
        let complete = ends.iter().take_while(|&&e| e <= cut).count();
        assert_eq!(got.len(), complete, "cut={cut}");
        assert_eq!(got[..], frames[..complete]);

        // garbage: every feed either errors (connection torn down) or
        // consumes honestly — no panic, no stall, no over-consumption
        let junk: Vec<u8> = (0..1 + rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        let mut rd = ReplyReader::new();
        let mut off = 0usize;
        while off < junk.len() {
            match rd.feed(&junk[off..]) {
                Ok((used, _)) => {
                    assert!(used > 0 && used <= junk.len() - off);
                    off += used;
                }
                Err(_) => break,
            }
        }
    });
}

#[test]
fn fifo_reassociation_survives_out_of_order_cross_backend_completion() {
    // The router's ordering contract in miniature: per-backend FIFOs
    // re-associate replies (TCP delivers per-connection in forward
    // order), while the interleaving ACROSS backends is arbitrary.
    // Every client receiver must end up with exactly its own reply,
    // and failing one backend's window errors exactly its members.
    prop::check_default("fifo reassociation", |rng| {
        use aquant::config::RouteSpec;
        use std::collections::VecDeque;
        use std::sync::atomic::Ordering;
        use std::sync::mpsc;
        use std::time::Instant;

        let n_backends = 2 + rng.below(3);
        let routes: Vec<RouteSpec> = (0..n_backends)
            .map(|b| RouteSpec {
                name: format!("m{b}"),
                addr: format!("backend-{b}:1"),
            })
            .collect();
        let stats = RouterStats::for_routes(&routes);
        let mut fifos: Vec<VecDeque<PendingReply>> =
            (0..n_backends).map(|_| VecDeque::new()).collect();
        // "forward" tagged requests to random backends; the tag is the
        // reply payload, so delivery to the wrong request is visible
        let total = 1 + rng.below(24);
        let mut rxs = Vec::new();
        let mut queued: Vec<VecDeque<u32>> = (0..n_backends).map(|_| VecDeque::new()).collect();
        for i in 0..total as u32 {
            let b = rng.below(n_backends);
            let n = 1 + rng.below(4) as u32;
            let (tx, rx) = mpsc::channel();
            fifos[b].push_back(PendingReply {
                tx,
                n,
                t0: Instant::now(),
            });
            stats.backends[b].inflight.fetch_add(1, Ordering::Relaxed);
            queued[b].push_back(i);
            rxs.push((i, b, n, rx));
        }
        // one backend may die mid-run; its not-yet-answered window
        // fails, everyone else is untouched
        let dying = rng.bernoulli(0.5).then(|| rng.below(n_backends));
        let mut failed_tags: Vec<u32> = Vec::new();
        let mut done: Vec<u32> = Vec::new();
        loop {
            let live: Vec<usize> = (0..n_backends).filter(|b| !fifos[*b].is_empty()).collect();
            let Some(&b) = live.get(rng.below(live.len().max(1))).or(live.first()) else {
                break;
            };
            if Some(b) == dying && rng.bernoulli(0.4) {
                failed_tags.extend(queued[b].drain(..));
                fail_window(&mut fifos[b], &stats.backends[b], "backend gone");
                continue;
            }
            let tag = queued[b].pop_front().unwrap();
            let n = fifos[b].front().unwrap().n;
            complete_front(&mut fifos[b], vec![tag; n as usize], &stats.backends[b])
                .expect("in-order completion");
            done.push(tag);
        }
        assert_eq!(done.len() + failed_tags.len(), total);
        for (i, b, n, rx) in rxs {
            let got = rx.try_recv().expect("every request resolved");
            if failed_tags.contains(&i) {
                let e = got.expect_err("failed window member must error");
                assert!(e.contains("backend gone"));
            } else {
                assert_eq!(got.unwrap(), vec![i; n as usize], "request {i}");
            }
            let _ = b;
        }
        for b in 0..n_backends {
            assert_eq!(stats.backends[b].inflight.load(Ordering::Relaxed), 0);
        }
    });
}
