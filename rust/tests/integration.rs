//! Integration tests over the AOT artifacts: the JAX-lowered PJRT
//! programs and the pure-Rust engine must agree bit-tightly — this is the
//! cross-layer parity signal (L1 pallas == L2 jnp is covered by pytest;
//! here L3-rust == lowered-L2).
//!
//! These tests need `make artifacts`; they skip (with a notice) when the
//! artifacts directory is absent so `cargo test` works in a fresh clone.

use std::path::Path;

use aquant::config::{Bits, Method, RunConfig};
use aquant::coordinator::chain::QuantCtx;
use aquant::coordinator::state::{bits_row_for, Knobs, StateStore};
use aquant::exp::cell::{build_quantized_engine, Ctx};
use aquant::nn::engine::{ActQuant, Engine};
use aquant::quant::border::BorderFn;
use aquant::quant::tensor::Tensor;

fn ctx() -> Option<Ctx> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping integration test: no artifacts/manifest.json");
        return None;
    }
    Some(Ctx::new("artifacts", Some(2)).expect("ctx"))
}

#[test]
fn manifest_lists_all_models_and_programs() {
    let Some(ctx) = ctx() else { return };
    let manifest = ctx.rt.manifest().unwrap();
    for model in ctx.models() {
        let topo = ctx.topo(&model).unwrap();
        for l in topo.all_layers() {
            assert!(manifest.program(&format!("fp_{model}_{}", l.name)).is_some());
            assert!(manifest.program(&format!("q_{model}_{}", l.name)).is_some());
            assert!(manifest
                .program(&format!("step_{model}_L_{}", l.name))
                .is_some());
        }
        for b in &topo.blocks {
            assert!(manifest
                .program(&format!("step_{model}_B_{}", b.name))
                .is_some());
        }
        assert!(manifest.program(&format!("fp_full_{model}")).is_some());
        assert!(manifest.program(&format!("q_full_{model}")).is_some());
    }
}

#[test]
fn rust_engine_matches_pjrt_fp_forward() {
    let Some(ctx) = ctx() else { return };
    let model = "mobiles";
    let chain = ctx.chain(model).unwrap();
    let b = chain.batch;
    let d = &ctx.dataset.test;
    let idx: Vec<usize> = (0..b).collect();
    let x = Tensor::new(vec![b, d.c, d.h, d.w], d.gather(&idx)).unwrap();
    let pjrt_logits = chain.full(&x, None).unwrap();

    let engine = Engine::new(
        ctx.topo(model).unwrap().clone(),
        ctx.weights(model).unwrap().clone(),
    );
    for i in 0..4 {
        let logits = engine.forward(d.image(i), None).unwrap();
        for (j, &v) in logits.iter().enumerate() {
            let want = pjrt_logits.data[i * logits.len() + j];
            assert!(
                (v - want).abs() < 1e-2,
                "img {i} logit {j}: rust {v} vs pjrt {want}"
            );
        }
    }
}

#[test]
fn rust_engine_matches_pjrt_quantized_forward() {
    let Some(ctx) = ctx() else { return };
    let model = "mobiles";
    let bits = Bits { w: 4, a: 4 };
    // Nearest (scale-search-only) state so both sides share exact params.
    let cfg = RunConfig::new(model, Method::Nearest, bits);
    let st = ctx.calibrated_state(&cfg).unwrap();
    let chain = ctx.chain(model).unwrap();
    let b = chain.batch;
    let d = &ctx.dataset.test;
    let idx: Vec<usize> = (0..b).collect();
    let x = Tensor::new(vec![b, d.c, d.h, d.w], d.gather(&idx)).unwrap();
    let q = QuantCtx {
        state: &st,
        bits,
        knobs: Knobs::inference(Method::Nearest, bits),
    };
    let pjrt_logits = chain.full(&x, Some(&q)).unwrap();

    let engine = build_quantized_engine(&ctx, model, Method::Nearest, bits).unwrap();
    let nc = ctx.topo(model).unwrap().n_classes;
    let mut agree = 0;
    for i in 0..8 {
        let logits = engine.forward(d.image(i), None).unwrap();
        let mut max_diff = 0.0f32;
        for (j, &v) in logits.iter().enumerate() {
            max_diff = max_diff.max((v - pjrt_logits.data[i * nc + j]).abs());
        }
        // f32 accumulation-order differences only
        if max_diff < 5e-2 {
            agree += 1;
        }
    }
    assert!(agree >= 7, "only {agree}/8 images matched PJRT quantized logits");
}

#[test]
fn q_layer_nearest_equals_border_zero() {
    // q_* programs with zero border params must equal the rust nearest
    // quantizer on the same patches (Definition 2.1 ⇒ B = 0.5).
    let Some(ctx) = ctx() else { return };
    let model = "mobiles";
    let bits = Bits { w: 32, a: 4 };
    let cfg = RunConfig::new(model, Method::Nearest, bits);
    let st = ctx.calibrated_state(&cfg).unwrap();
    let topo = ctx.topo(model).unwrap();
    let chain = ctx.chain(model).unwrap();
    let d = &ctx.dataset.test;
    let b = chain.batch;
    let idx: Vec<usize> = (0..b).collect();
    let x = Tensor::new(vec![b, d.c, d.h, d.w], d.gather(&idx)).unwrap();
    let q = QuantCtx {
        state: &st,
        bits,
        knobs: Knobs::inference(Method::Nearest, bits),
    };
    let rec = chain.walk(&x, Some(&q)).unwrap();

    // Rust engine with the same scales/borders (weights FP).
    let mut engine = Engine::new(topo.clone(), ctx.weights(model).unwrap().clone());
    for l in topo.all_layers() {
        let row = bits_row_for(topo, bits, &l.name);
        let s = st.get(&format!("state:{}.s_a", l.name)).unwrap().data[0];
        engine.set_act_quant(
            &l.name,
            ActQuant::Border {
                border: BorderFn::nearest(l.rows, l.k2()),
                s,
                qmin: row.qmin_a,
                qmax: row.qmax_a,
            },
        );
    }
    for i in 0..2 {
        let logits = engine.forward(d.image(i), None).unwrap();
        let nc = topo.n_classes;
        for (j, &v) in logits.iter().enumerate() {
            let want = rec.logits.data[i * nc + j];
            assert!(
                (v - want).abs() < 5e-2,
                "img {i} logit {j}: rust {v} vs pjrt {want}"
            );
        }
    }
}

#[test]
fn state_store_roundtrip_via_calibration_cache() {
    let Some(ctx) = ctx() else { return };
    let cfg = RunConfig::new("mobiles", Method::Nearest, Bits { w: 4, a: 4 });
    let st1 = ctx.calibrated_state(&cfg).unwrap();
    let st2 = ctx.calibrated_state(&cfg).unwrap(); // from cache
    for name in st1.names() {
        let a = st1.get(name).unwrap();
        let b = st2.get(name).unwrap();
        assert_eq!(a.shape, b.shape, "{name}");
        assert_eq!(a.data, b.data, "{name}");
    }
    let _ = StateStore::new(); // exercise Default path
}

#[test]
fn dataset_matches_manifest_counts() {
    let Some(ctx) = ctx() else { return };
    assert_eq!(ctx.dataset.calib.n % 32, 0);
    assert!(ctx.dataset.test.n >= 512);
    let max_label = *ctx.dataset.test.labels.iter().max().unwrap() as usize;
    assert!(max_label < ctx.dataset.n_classes);
}
