//! Shared scaffolding for the serving integration suites
//! (`serve_roundtrip.rs`, `multi_model.rs`): server startup on an
//! ephemeral port, random payloads, sequential-engine expectations, and
//! the closed-connection assertion. Included via `mod common;` from
//! each suite (not a test target itself — Cargo.toml declares targets
//! explicitly with autotests off).
#![allow(dead_code)] // each suite uses its own subset

use std::io::Read;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;

use aquant::config::ServeConfig;
use aquant::nn::engine::Engine;
use aquant::nn::registry::ModelRegistry;
use aquant::nn::synth;
use aquant::server::{Server, ServerStats};
use aquant::util::rng::Rng;

/// Tiny synthetic model with learned borders on every layer, so the
/// full quantized hot path is what's being served.
pub fn synth_engine(seed: u64) -> Arc<Engine> {
    let mut rng = Rng::new(seed);
    let (topo, weights) = synth::tiny_model(&mut rng);
    Arc::new(synth::engine_with_random_borders(
        &topo, &weights, &mut rng, true, true,
    ))
}

/// Bind an ephemeral-port server over `registry` and run it on its own
/// thread; returns the address, the live stats handle, and the join
/// handle (resolves once `cfg.max_conns` connections have completed).
pub fn start(
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
) -> (SocketAddr, Arc<ServerStats>, JoinHandle<anyhow::Result<()>>) {
    let srv = Server::bind(registry, "127.0.0.1:0", cfg).expect("bind ephemeral");
    let addr = srv.local_addr().expect("local addr");
    let stats = srv.stats();
    let handle = std::thread::spawn(move || srv.run());
    (addr, stats, handle)
}

/// [`start`] for the single-model (pre-v2) server shape.
pub fn start_single(
    engine: Arc<Engine>,
    cfg: ServeConfig,
) -> (SocketAddr, Arc<ServerStats>, JoinHandle<anyhow::Result<()>>) {
    start(
        Arc::new(ModelRegistry::single(engine).expect("valid engine")),
        cfg,
    )
}

pub fn random_images(rng: &mut Rng, n: usize, img_elems: usize) -> Vec<f32> {
    (0..n * img_elems).map(|_| rng.normal()).collect()
}

/// Sequential-engine predictions for a flat batch — the bit-identity
/// reference every served answer is checked against.
pub fn expected(engine: &Engine, images: &[f32], n: usize) -> Vec<u32> {
    let elems = engine.img_elems();
    let refs: Vec<&[f32]> = (0..n).map(|i| &images[i * elems..(i + 1) * elems]).collect();
    engine
        .classify_batch(&refs)
        .unwrap()
        .iter()
        .map(|&c| c as u32)
        .collect()
}

/// Assert the server closed this connection without answering (the
/// required reaction to a malformed/unroutable request).
pub fn expect_closed(mut s: TcpStream) {
    let mut b = [0u8; 1];
    match s.read(&mut b) {
        Ok(0) | Err(_) => {} // server closed the connection
        Ok(_) => panic!("server answered a bad request"),
    }
}
