//! Shared scaffolding for the serving integration suites
//! (`serve_roundtrip.rs`, `multi_model.rs`, `conn_conformance.rs`,
//! `reload_conformance.rs`):
//! server startup on an ephemeral port, random payloads,
//! sequential-engine expectations, raw v1/v2 request builders, a
//! chunked (slow-loris) writer, the response reader, the
//! closed-connection assertion, and a per-test watchdog. Included via
//! `mod common;` from each suite (not a test target itself —
//! Cargo.toml declares targets explicitly with autotests off).
#![allow(dead_code)] // each suite uses its own subset

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use aquant::config::ServeConfig;
use aquant::nn::engine::Engine;
use aquant::nn::registry::ModelRegistry;
use aquant::nn::synth;
use aquant::server::{encode_header_v2, Server, ServerStats};
use aquant::util::rng::Rng;

/// Tiny synthetic model with learned borders on every layer, so the
/// full quantized hot path is what's being served.
pub fn synth_engine(seed: u64) -> Arc<Engine> {
    let mut rng = Rng::new(seed);
    let (topo, weights) = synth::tiny_model(&mut rng);
    Arc::new(synth::engine_with_random_borders(
        &topo, &weights, &mut rng, true, true,
    ))
}

/// Bind an ephemeral-port server over `registry` and run it on its own
/// thread; returns the address, the live stats handle, and the join
/// handle (resolves once `cfg.max_accepts` connections have completed).
pub fn start(
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
) -> (SocketAddr, Arc<ServerStats>, JoinHandle<anyhow::Result<()>>) {
    let srv = Server::bind(registry, "127.0.0.1:0", cfg).expect("bind ephemeral");
    let addr = srv.local_addr().expect("local addr");
    let stats = srv.stats();
    let handle = std::thread::spawn(move || srv.run());
    (addr, stats, handle)
}

/// [`start`] with a stats endpoint on an ephemeral port: also returns
/// the bound stats address. `cfg.stats_addr` must be set (the caller
/// decides the address; tests use `127.0.0.1:0`).
pub fn start_with_stats(
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
) -> (
    SocketAddr,
    SocketAddr,
    Arc<ServerStats>,
    JoinHandle<anyhow::Result<()>>,
) {
    assert!(cfg.stats_addr.is_some(), "caller must set cfg.stats_addr");
    let srv = Server::bind(registry, "127.0.0.1:0", cfg).expect("bind ephemeral");
    let addr = srv.local_addr().expect("local addr");
    let stats_addr = srv.stats_local_addr().expect("stats addr");
    let stats = srv.stats();
    let handle = std::thread::spawn(move || srv.run());
    (addr, stats_addr, stats, handle)
}

/// [`start`] with a control-plane admin endpoint on an ephemeral
/// port: also returns the bound admin address. `cfg.admin_addr` must
/// be set (tests use `127.0.0.1:0`).
pub fn start_with_admin(
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
) -> (
    SocketAddr,
    SocketAddr,
    Arc<ServerStats>,
    JoinHandle<anyhow::Result<()>>,
) {
    assert!(cfg.admin_addr.is_some(), "caller must set cfg.admin_addr");
    let srv = Server::bind(registry, "127.0.0.1:0", cfg).expect("bind ephemeral");
    let addr = srv.local_addr().expect("local addr");
    let admin_addr = srv.admin_local_addr().expect("admin addr");
    let stats = srv.stats();
    let handle = std::thread::spawn(move || srv.run());
    (addr, admin_addr, stats, handle)
}

/// [`start`] for the single-model (pre-v2) server shape.
pub fn start_single(
    engine: Arc<Engine>,
    cfg: ServeConfig,
) -> (SocketAddr, Arc<ServerStats>, JoinHandle<anyhow::Result<()>>) {
    start(
        Arc::new(ModelRegistry::single(engine).expect("valid engine")),
        cfg,
    )
}

pub fn random_images(rng: &mut Rng, n: usize, img_elems: usize) -> Vec<f32> {
    (0..n * img_elems).map(|_| rng.normal()).collect()
}

/// Sequential-engine predictions for a flat batch — the bit-identity
/// reference every served answer is checked against.
pub fn expected(engine: &Engine, images: &[f32], n: usize) -> Vec<u32> {
    let elems = engine.img_elems();
    let refs: Vec<&[f32]> = (0..n).map(|i| &images[i * elems..(i + 1) * elems]).collect();
    engine
        .classify_batch(&refs)
        .unwrap()
        .iter()
        .map(|&c| c as u32)
        .collect()
}

/// Assert the server closed this connection without answering (the
/// required reaction to a malformed/unroutable request).
pub fn expect_closed(mut s: TcpStream) {
    let mut b = [0u8; 1];
    match s.read(&mut b) {
        Ok(0) | Err(_) => {} // server closed the connection
        Ok(_) => panic!("server answered a bad request"),
    }
}

/// Raw wire bytes of one v1 request (`u32 n` + payload).
pub fn v1_request_bytes(images: &[f32], n: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + images.len() * 4);
    out.extend_from_slice(&n.to_le_bytes());
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Raw wire bytes of one v2 request at the current protocol version.
pub fn v2_request_bytes(model_id: u16, images: &[f32], n: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + images.len() * 4);
    out.extend_from_slice(&encode_header_v2(model_id, n));
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Slow-loris writer: dribble `bytes` onto the stream `chunk` bytes at
/// a time, sleeping `pause` between writes.
pub fn chunked_write(
    s: &mut TcpStream,
    bytes: &[u8],
    chunk: usize,
    pause: Duration,
) -> std::io::Result<()> {
    for piece in bytes.chunks(chunk.max(1)) {
        s.write_all(piece)?;
        s.flush()?;
        if !pause.is_zero() {
            std::thread::sleep(pause);
        }
    }
    Ok(())
}

/// Read one response frame (`u32 n` + `n` class ids) off the stream.
pub fn read_response(s: &mut TcpStream) -> anyhow::Result<Vec<u32>> {
    let mut hdr = [0u8; 4];
    s.read_exact(&mut hdr)?;
    let m = u32::from_le_bytes(hdr) as usize;
    let mut buf = vec![0u8; m * 4];
    s.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Per-test timeout guard: aborts the whole process (with a message)
/// if the test hasn't finished within `limit` — a wedged event loop
/// must fail CI loudly, not hang it. Drop disarms.
pub struct Watchdog {
    armed: Arc<std::sync::atomic::AtomicBool>,
}

impl Watchdog {
    pub fn arm(name: &'static str, limit: Duration) -> Watchdog {
        let armed = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let flag = armed.clone();
        std::thread::spawn(move || {
            let step = Duration::from_millis(50);
            let mut left = limit;
            while flag.load(std::sync::atomic::Ordering::Relaxed) {
                if left.is_zero() {
                    eprintln!("watchdog: test {name} exceeded {limit:?}; aborting");
                    std::process::abort();
                }
                let s = step.min(left);
                std::thread::sleep(s);
                left -= s;
            }
        });
        Watchdog { armed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.armed
            .store(false, std::sync::atomic::Ordering::Relaxed);
    }
}
