//! Stats-endpoint integration suite: scrape `GET /stats` while mixed
//! v1/v2 traffic is in flight, then assert the frozen counters cohere
//! with what the clients actually sent; reject malformed/oversized
//! stats requests without perturbing serving; persist history lines
//! across a server restart; and pin that enabling an `slo_us` policy
//! changes scheduling only — every served prediction stays
//! bit-identical to the sequential engine.

mod common;

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use aquant::config::{PolicyOverrides, ServeConfig};
use aquant::nn::registry::ModelRegistry;
use aquant::util::json::Json;
use aquant::util::rng::Rng;

use common::{
    expected, random_images, read_response, start, start_with_stats, synth_engine,
    v1_request_bytes, v2_request_bytes, Watchdog,
};

/// One scrape: send `GET <target>`, read to EOF, split head and body.
fn http_get(addr: SocketAddr, target: &str) -> (String, String) {
    let mut s = TcpStream::connect(addr).expect("connect stats");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(format!("GET {target} HTTP/1.0\r\n\r\n").as_bytes())
        .expect("send stats request");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read stats response");
    let raw = String::from_utf8(raw).expect("utf8 response");
    let (head, body) = raw.split_once("\r\n\r\n").expect("head/body split");
    (head.to_string(), body.to_string())
}

/// Send raw bytes to the stats endpoint and return the status head.
fn http_raw(addr: SocketAddr, bytes: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect stats");
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(bytes).expect("send raw");
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read raw response");
    let raw = String::from_utf8_lossy(&raw);
    raw.split_once("\r\n\r\n")
        .map(|(h, _)| h.to_string())
        .unwrap_or_else(|| raw.into_owned())
}

fn quantiles_monotone(h: &Json) {
    let q = |k: &str| h.get(k).and_then(Json::as_f64);
    if let (Some(p50), Some(p90), Some(p99)) = (q("p50_us"), q("p90_us"), q("p99_us")) {
        assert!(p50 <= p90 && p90 <= p99, "quantiles dip: {p50} {p90} {p99}");
    }
}

#[test]
fn stats_scrape_live_under_mixed_load() {
    let _wd = Watchdog::arm("stats_scrape_live_under_mixed_load", Duration::from_secs(60));
    let engines = [synth_engine(1), synth_engine(2)];
    let registry = Arc::new(
        ModelRegistry::new(vec![
            ("a".into(), engines[0].clone()),
            ("b".into(), engines[1].clone()),
        ])
        .unwrap(),
    );
    let n_clients = 6usize;
    let n_req = 5usize;
    let n = 3usize; // images per request
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 200,
        max_accepts: Some(n_clients + 1),
        stats_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    };
    let (addr, stats_addr, _stats, server) = start_with_stats(registry, cfg);

    // Clients: even -> model 0 (client 0 over bare v1), odd -> model 1.
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let model_id = (c % 2) as u16;
        let engine = engines[model_id as usize].clone();
        clients.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(0x57A75 + c as u64);
            for _ in 0..n_req {
                let images = random_images(&mut rng, n, engine.img_elems());
                let req = if c == 0 {
                    v1_request_bytes(&images, n as u32)
                } else {
                    v2_request_bytes(model_id, &images, n as u32)
                };
                s.write_all(&req).unwrap();
                let got = read_response(&mut s).unwrap();
                assert_eq!(got, expected(&engine, &images, n), "served answer diverged");
            }
        }));
    }

    // Concurrent scrapes while the load is (likely) in flight: every
    // response must be valid JSON with both models and sane counters,
    // whatever instant it lands on.
    let scraper = std::thread::spawn(move || {
        for _ in 0..10 {
            let (head, body) = http_get(stats_addr, "/stats");
            assert!(head.starts_with("HTTP/1.0 200 OK"), "head: {head}");
            let j = Json::parse(&body).expect("stats body parses");
            assert!(j.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0);
            let models = j.get("models").and_then(Json::as_arr).unwrap();
            assert_eq!(models.len(), 2);
            for m in models {
                for hist in ["e2e", "queue_wait", "service"] {
                    quantiles_monotone(m.get(hist).unwrap());
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    });
    for c in clients {
        c.join().unwrap();
    }
    scraper.join().unwrap();

    // Every reply has been read, so the final scrape sees settled
    // counters: requests/images must equal exactly what was sent.
    let (_, body) = http_get(stats_addr, "/stats");
    let j = Json::parse(&body).unwrap();
    let models = j.get("models").and_then(Json::as_arr).unwrap();
    let per_model_reqs = (n_clients / 2 * n_req) as i64;
    for m in models {
        let g = |k: &str| m.get(k).and_then(Json::as_i64).unwrap();
        assert_eq!(g("requests"), per_model_reqs);
        assert_eq!(g("images"), per_model_reqs * n as i64);
        assert!(g("batches") >= 1);
        // one e2e + one queue-wait observation per request, one
        // service observation per engine batch
        let count = |h: &str| m.get(h).unwrap().get("count").and_then(Json::as_i64).unwrap();
        assert_eq!(count("e2e"), per_model_reqs);
        assert_eq!(count("queue_wait"), per_model_reqs);
        assert_eq!(count("service"), g("batches"));
        assert_eq!(g("queue_depth"), 0, "drained after the load");
        assert!(g("queue_peak") >= 0);
    }
    let srv = j.get("server").unwrap();
    assert_eq!(
        srv.get("conns_accepted").and_then(Json::as_i64).unwrap(),
        n_clients as i64,
        "stats connections must not count as serving accepts"
    );

    // Plaintext rendering of the same snapshot.
    let (head, text) = http_get(stats_addr, "/stats?fmt=text");
    assert!(head.contains("text/plain"), "head: {head}");
    assert!(text.starts_with("aquant stats:"), "text: {text}");
    assert!(text.contains("model 0 a:") && text.contains("model 1 b:"));

    // Burn the final serving accept so the bounded loop drains.
    drop(TcpStream::connect(addr).unwrap());
    server.join().unwrap().unwrap();
}

#[test]
fn bad_stats_requests_rejected_without_touching_serving() {
    let _wd = Watchdog::arm(
        "bad_stats_requests_rejected_without_touching_serving",
        Duration::from_secs(60),
    );
    let engine = synth_engine(3);
    let registry = Arc::new(ModelRegistry::single(engine.clone()).unwrap());
    let cfg = ServeConfig {
        workers: 1,
        max_batch: 4,
        batch_wait_us: 0,
        max_accepts: Some(1),
        stats_addr: Some("127.0.0.1:0".into()),
        ..ServeConfig::default()
    };
    let (addr, stats_addr, stats, server) = start_with_stats(registry, cfg);

    assert!(http_raw(stats_addr, b"POST /stats HTTP/1.0\r\n\r\n").contains("405"));
    assert!(http_raw(stats_addr, b"GET /nope HTTP/1.0\r\n\r\n").contains("404"));
    assert!(http_raw(stats_addr, b"GET /stats?fmt=xml HTTP/1.0\r\n\r\n").contains("400"));
    assert!(http_raw(stats_addr, b"GET\r\n\r\n").contains("400"), "no target");
    assert!(
        http_raw(stats_addr, &[0xff, 0xfe, 0x0d, 0x0a, 0x0d, 0x0a]).contains("400"),
        "non-UTF8"
    );
    // head at the cap with no terminator: rejected, not buffered
    // forever (exactly the cap, so no unread bytes remain to turn the
    // server's close into an RST that could eat the response)
    assert!(http_raw(stats_addr, &[b'A'; 4096]).contains("431"));

    // Serving is untouched: the one real connection round-trips
    // bit-identically and the reject storm shows up nowhere in the
    // serving counters.
    let mut rng = Rng::new(9);
    let images = random_images(&mut rng, 2, engine.img_elems());
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&v1_request_bytes(&images, 2)).unwrap();
    assert_eq!(read_response(&mut s).unwrap(), expected(&engine, &images, 2));
    drop(s);
    server.join().unwrap().unwrap();
    let snap = stats.snapshot();
    assert_eq!(snap.conns_accepted, 1);
    assert_eq!(snap.conns_rejected, 0);
    assert_eq!(snap.models[0].requests, 1);
}

#[test]
fn history_lines_persist_across_restart() {
    let _wd = Watchdog::arm("history_lines_persist_across_restart", Duration::from_secs(60));
    let path = std::env::temp_dir().join(format!(
        "aquant-stats-history-{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&path);
    let engine = synth_engine(4);

    // Two bounded runs against the same history path: each appends a
    // startup snapshot and a shutdown flush. Restarting must append,
    // not truncate.
    for run in 0..2 {
        let registry = Arc::new(ModelRegistry::single(engine.clone()).unwrap());
        let cfg = ServeConfig {
            workers: 1,
            max_batch: 4,
            batch_wait_us: 0,
            max_accepts: Some(1),
            stats_history: Some(path.to_str().unwrap().to_string()),
            stats_history_every_s: 3600, // only startup + final flush
            ..ServeConfig::default()
        };
        let (addr, _stats, server) = start(registry, cfg);
        let mut rng = Rng::new(10 + run);
        let images = random_images(&mut rng, 1, engine.img_elems());
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&v1_request_bytes(&images, 1)).unwrap();
        assert_eq!(read_response(&mut s).unwrap(), expected(&engine, &images, 1));
        drop(s);
        server.join().unwrap().unwrap();
    }

    let text = std::fs::read_to_string(&path).expect("history file exists");
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(
        lines.len() >= 4,
        "two runs x (startup + final flush), got {} lines",
        lines.len()
    );
    let mut final_requests = Vec::new();
    for line in &lines {
        let j = Json::parse(line).expect("history line parses");
        assert!(j.get("t").and_then(Json::as_f64).unwrap() > 0.0, "unix stamp");
        assert!(j.get("uptime_s").and_then(Json::as_f64).unwrap() >= 0.0);
        let models = j.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 1);
        final_requests.push(models[0].get("requests").and_then(Json::as_i64).unwrap());
    }
    // the last line of each run recorded that run's one request
    assert_eq!(*final_requests.last().unwrap(), 1);
    assert!(final_requests.contains(&1));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn slo_policy_changes_scheduling_only() {
    let _wd = Watchdog::arm("slo_policy_changes_scheduling_only", Duration::from_secs(60));
    let engines = [synth_engine(5), synth_engine(6)];
    // Model 0 carries an unmeetable 1us p99 SLO: the adapter will push
    // its effective weight toward the bound as soon as it has samples.
    // Predictions must not care.
    let registry = Arc::new(
        ModelRegistry::with_policies(vec![
            (
                "slo".into(),
                engines[0].clone(),
                PolicyOverrides {
                    weight: Some(2),
                    slo_us: Some(1),
                    ..PolicyOverrides::default()
                },
            ),
            ("plain".into(), engines[1].clone(), PolicyOverrides::default()),
        ])
        .unwrap(),
    );
    let n_clients = 4usize;
    let n_req = 10usize;
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 8,
        batch_wait_us: 100,
        max_accepts: Some(n_clients),
        ..ServeConfig::default()
    };
    let (addr, stats, server) = start(registry, cfg);
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let model_id = (c % 2) as u16;
        let engine = engines[model_id as usize].clone();
        clients.push(std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(0x510 + c as u64);
            for _ in 0..n_req {
                let images = random_images(&mut rng, 2, engine.img_elems());
                s.write_all(&v2_request_bytes(model_id, &images, 2)).unwrap();
                let got = read_response(&mut s).unwrap();
                assert_eq!(
                    got,
                    expected(&engine, &images, 2),
                    "slo_us must never change predictions"
                );
                // spread the load across adaptation intervals
                std::thread::sleep(Duration::from_millis(15));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    server.join().unwrap().unwrap();
    let snap = stats.snapshot();
    assert_eq!(snap.models[0].slo_us, 1);
    assert_eq!(snap.models[1].slo_us, 0);
    assert_eq!(snap.models[0].weight, 2);
    // boost-only adaptation: effective weight never drops below static
    assert!(
        snap.models[0].effective_weight_milli >= 2000,
        "effective weight {} fell below static",
        snap.models[0].effective_weight_milli
    );
    assert_eq!(snap.models[1].effective_weight_milli, 1000);
    for m in &snap.models {
        assert_eq!(m.requests, (n_clients / 2 * n_req) as u64);
        assert_eq!(m.e2e.count, m.requests);
    }
}
