//! AQuant: adaptive activation-rounding-border post-training quantization.
//!
//! A three-layer reproduction of *"Efficient Activation Quantization via
//! Adaptive Rounding Border for Post-Training Quantization"* (AQuant):
//!
//! * **L3 (this crate)** — the PTQ coordinator: block-wise calibration
//!   scheduling, rounding/annealing schedules, the pure-Rust quantization
//!   substrate and integer inference engine, evaluation and serving.
//! * **L2 (python/compile)** — JAX models and PTQ step graphs, AOT-lowered
//!   to HLO text artifacts consumed by [`runtime`].
//! * **L1 (python/compile/kernels)** — the Pallas fused border-quantization
//!   kernel, verified against a pure-jnp oracle.
//!
//! Python runs only at build time (`make artifacts`); the request path is
//! Rust + PJRT.

pub mod config;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod exp;
