//! Figures 1–3 and the §5.3 overhead analysis, as printable series.

use anyhow::Result;

use super::cell::Ctx;
use crate::config::{Bits, Method, RunConfig};
use crate::coordinator::chain::QuantCtx;
use crate::coordinator::state::Knobs;
use crate::eval::overhead::overhead;
use crate::eval::profile::propagated_error;
use crate::nn::engine::{ActQuant, Engine, FusionMode};
use crate::quant::border::BorderFn;

/// Figure 1: the element-wise error function g(Δx) = (w+Δw)Δx + Δw·x' + w·e
/// and how the adjusted border equalizes the rounding pair / removes the
/// bias of the expected error. Analytic — prints the series the figure
/// plots.
pub fn fig1() -> String {
    // Example configuration shaping the error curve (as in Fig. 1): the
    // border solves |g(-B)| = |g(1-B)|  =>  B = Δw/(w+Δw)·x' + w/(w+Δw)·e + 1/2.
    let (w, dw, e, x) = (1.0f32, 0.25, -0.3, 1.6);
    let g = |dx: f32| (w + dw) * dx + dw * x + w * e;
    let b_star = (dw / (w + dw)) * x + (w / (w + dw)) * e + 0.5;
    let b_star = b_star.clamp(0.0, 1.0);
    let mut out = vec![
        "Figure 1: element-wise error and the adjusted rounding border".to_string(),
        format!("w={w} dw={dw} e={e} x'={x}"),
        format!("adjusted border B* = {b_star:.4} (nearest uses 0.5)"),
        format!(
            "rounding pair at B*: |g(-B*)| = {:.4}, |g(1-B*)| = {:.4} (equal)",
            g(-b_star).abs(),
            g(1.0 - b_star).abs()
        ),
    ];
    // Expected element-wise error when the fractional part is uniform:
    // integral of g over [-B, 1-B].
    let expected = |b: f32| {
        let n = 1000;
        (0..n)
            .map(|i| {
                let dx = -b + (i as f32 + 0.5) / n as f32;
                g(dx)
            })
            .sum::<f32>()
            / n as f32
    };
    out.push(format!(
        "expected error: nearest (B=0.5) = {:+.4}, adjusted (B=B*) = {:+.4}",
        expected(0.5),
        expected(b_star)
    ));
    out.push("g(dx) series (dx, g):".to_string());
    for i in 0..11 {
        let dx = -0.5 + i as f32 * 0.1;
        out.push(format!("  {dx:+.2} {:+.4}", g(dx)));
    }
    out.join("\n") + "\n"
}

/// Figure 2: propagated error vs noised activation magnitude, 16 clusters,
/// at a mid-network layer under W2A4 nearest quantization.
pub fn fig2(ctx: &Ctx, model: &str) -> Result<String> {
    let bits = Bits { w: 2, a: 4 };
    let cfg = RunConfig::new(model, Method::Nearest, bits);
    let st = ctx.calibrated_state(&cfg)?; // nearest: scale init only
    let chain = ctx.chain(model)?;
    let topo = ctx.topo(model)?;
    // input of the second block's first layer (the paper profiles the
    // second block of ResNet-18)
    let layer = topo.blocks[2].layers[0].name.clone();
    let q = QuantCtx {
        state: &st,
        bits,
        knobs: Knobs::inference(Method::Nearest, bits),
    };
    let clusters = propagated_error(&chain, &ctx.dataset.calib, &q, &layer, 16)?;
    let mut out = vec![
        format!("Figure 2: propagated error vs |x'| — {model}/{layer}, W2A4 nearest"),
        format!("{:>4} {:>12} {:>12} {:>8}", "bin", "|x'| center", "mean err", "n"),
    ];
    for (i, c) in clusters.iter().enumerate() {
        out.push(format!(
            "{:>4} {:>12.4} {:>12.5} {:>8}",
            i, c.x_center, c.mean_err, c.n
        ));
    }
    Ok(out.join("\n") + "\n")
}

/// Figure 3: per-layer latency breakdown — original conv vs conv with the
/// border function fused into im2col vs unfused (second pass).
pub fn fig3(ctx: &Ctx, model: &str, abits: u32, reps: usize) -> Result<String> {
    let topo = ctx.topo(model)?.clone();
    let weights = ctx.weights(model)?.clone();
    let bits = Bits { w: 32, a: abits };
    // Latency is independent of the border parameter values; random-ish
    // nonzero params exercise the full code path.
    let make_engine = |mode: Option<FusionMode>| {
        let mut eng = Engine::new(topo.clone(), weights.clone());
        if let Some(m) = mode {
            eng.fusion = m;
            for l in topo.all_layers() {
                let row = crate::coordinator::state::bits_row_for(&topo, bits, &l.name);
                let mut params = vec![0.0f32; l.rows * 4];
                for (i, p) in params.iter_mut().enumerate() {
                    *p = ((i % 7) as f32 - 3.0) * 0.05;
                }
                // §5.3: the paper's latency experiment "adopts the
                // element-wise border function B(x) since its improvement
                // is enough in most cases" — fusion off, quadratic on.
                let border = BorderFn::from_params(params, l.k2(), false, true)
                    .expect("figure border table is well-formed by construction");
                eng.set_act_quant(
                    &l.name,
                    ActQuant::Border {
                        border,
                        s: 0.05,
                        qmin: row.qmin_a,
                        qmax: row.qmax_a,
                    },
                );
            }
        }
        eng
    };
    let image = ctx.dataset.test.image(0);
    let modes: [(&str, Option<FusionMode>); 3] = [
        ("original", None),
        ("border-fused", Some(FusionMode::Fused)),
        ("border-unfused", Some(FusionMode::Unfused)),
    ];
    let mut per_layer: Vec<Vec<f64>> = Vec::new(); // [mode][layer] total us
    let mut names: Vec<String> = Vec::new();
    for (_, mode) in &modes {
        let eng = make_engine(*mode);
        // warmup
        let _ = eng.forward_timed(image)?;
        let mut sums: Vec<f64> = Vec::new();
        for _ in 0..reps {
            let ts = eng.forward_timed(image)?;
            if sums.is_empty() {
                sums = vec![0.0; ts.len()];
                names = ts.iter().map(|t| t.layer.clone()).collect();
            }
            for (s, t) in sums.iter_mut().zip(&ts) {
                *s += t.im2col_quant_us + t.gemm_us;
            }
        }
        per_layer.push(sums.iter().map(|s| s / reps as f64).collect());
    }
    let mut out = vec![
        format!("Figure 3: per-layer conv latency (µs/image, {model}, A{abits}, {reps} reps)"),
        format!(
            "{:<14} {:>12} {:>14} {:>16}",
            "layer", "original", "border-fused", "border-unfused"
        ),
    ];
    let mut totals = [0.0f64; 3];
    for (i, name) in names.iter().enumerate() {
        out.push(format!(
            "{:<14} {:>12.1} {:>14.1} {:>16.1}",
            name, per_layer[0][i], per_layer[1][i], per_layer[2][i]
        ));
        for m in 0..3 {
            totals[m] += per_layer[m][i];
        }
    }
    out.push(format!(
        "{:<14} {:>12.1} {:>14.1} {:>16.1}",
        "TOTAL", totals[0], totals[1], totals[2]
    ));
    out.push(format!(
        "fused overhead: {:+.2}%   unfused overhead: {:+.2}%",
        (totals[1] / totals[0] - 1.0) * 100.0,
        (totals[2] / totals[0] - 1.0) * 100.0
    ));
    Ok(out.join("\n") + "\n")
}

/// §5.3: extra parameter / model-size ratios of the border functions.
pub fn overhead_table(ctx: &Ctx) -> Result<String> {
    let mut out = vec![
        "§5.3 overhead: border-function parameters vs model weights".to_string(),
        format!(
            "{:<14} {:>12} {:>14} {:>12} {:>16}",
            "model", "weights", "border params", "ratio", "size ratio (W4)"
        ),
    ];
    for model in ctx.models() {
        let r = overhead(ctx.topo(&model)?);
        out.push(format!(
            "{:<14} {:>12} {:>14} {:>11.2}% {:>15.2}%",
            r.model,
            r.weight_params,
            r.border_params,
            r.param_ratio * 100.0,
            r.size_ratio_w4 * 100.0
        ));
    }
    Ok(out.join("\n") + "\n")
}
