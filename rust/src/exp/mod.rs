//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5) on this testbed. See DESIGN.md §5 for the experiment
//! index and the expected shape of each result.

pub mod cell;
pub mod figs;
pub mod tables;

pub use cell::{Ctx, QUANT_METHODS};
