//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§5) on this testbed. See DESIGN.md §5 for the experiment
//! index and the expected shape of each result.
//!
//! Everything here calibrates/evaluates through the PJRT runtime, so the
//! whole harness sits behind the `pjrt` feature.

#[cfg(feature = "pjrt")]
pub mod cell;
#[cfg(feature = "pjrt")]
pub mod figs;
#[cfg(feature = "pjrt")]
pub mod tables;

#[cfg(feature = "pjrt")]
pub use cell::{Ctx, QUANT_METHODS};
