//! One experiment cell = model × method × bits: calibrate (cached on
//! disk under artifacts/qstate/<tag>/) and evaluate top-1 accuracy.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use crate::config::{Bits, Method, ModelSource, ModelSpec, RunConfig};
use crate::coordinator::chain::{ChainRunner, QuantCtx};
use crate::coordinator::state::{Knobs, StateStore};
use crate::coordinator::Calibrator;
use crate::data::Dataset;
use crate::eval::{eval_fp_accuracy_limited, eval_quant_accuracy_limited};
use crate::nn::engine::LayerWeights;
use crate::nn::loader;
use crate::nn::topology::ModelTopo;
use crate::runtime::Runtime;

/// Methods compared in Table 3 (order matches the paper's rows).
pub const QUANT_METHODS: &[Method] = &[
    Method::AdaRound,
    Method::Brecq,
    Method::QDrop,
    Method::AQuant,
];

/// Shared experiment context: runtime + dataset + per-model caches.
pub struct Ctx {
    pub rt: Runtime,
    pub dataset: Dataset,
    pub results_dir: PathBuf,
    pub iters_override: Option<u32>,
    pub verbose: bool,
    /// Cap on test images per accuracy evaluation (keeps the experiment
    /// sweep tractable on a single-core testbed; the full split is 1536).
    pub eval_limit: usize,
    topos: HashMap<String, ModelTopo>,
    weights: HashMap<String, HashMap<String, LayerWeights>>,
}

impl Ctx {
    pub fn new(artifacts_dir: &str, iters_override: Option<u32>) -> Result<Ctx> {
        let rt = Runtime::new(artifacts_dir)?;
        let manifest = rt
            .manifest()
            .ok_or_else(|| anyhow!("no manifest at {artifacts_dir}; run `make artifacts`"))?
            .clone();
        let dataset = Dataset::load(rt.artifacts_dir(), &manifest)?;
        let mut topos = HashMap::new();
        let mut weights = HashMap::new();
        let models = manifest
            .meta_section("models")?
            .as_obj()
            .ok_or_else(|| anyhow!("models meta"))?;
        for name in models.keys() {
            topos.insert(name.clone(), loader::load_topology(&manifest, name)?);
            weights.insert(
                name.clone(),
                loader::load_weights(rt.artifacts_dir(), &manifest, name)?,
            );
        }
        let results_dir = PathBuf::from("results");
        std::fs::create_dir_all(&results_dir)?;
        Ok(Ctx {
            rt,
            dataset,
            results_dir,
            iters_override,
            verbose: false,
            eval_limit: 512,
            topos,
            weights,
        })
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.topos.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn topo(&self, model: &str) -> Result<&ModelTopo> {
        self.topos
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))
    }

    pub fn weights(&self, model: &str) -> Result<&HashMap<String, LayerWeights>> {
        self.weights
            .get(model)
            .ok_or_else(|| anyhow!("unknown model {model}"))
    }

    pub fn chain(&self, model: &str) -> Result<ChainRunner<'_>> {
        ChainRunner::new(&self.rt, self.topo(model)?, self.weights(model)?)
    }

    /// FP baseline accuracy via the fp_full program.
    pub fn fp_accuracy(&self, model: &str) -> Result<f64> {
        eval_fp_accuracy_limited(&self.chain(model)?, &self.dataset.test, self.eval_limit)
    }

    /// Calibrate a cell (or load its cached state) and return the state.
    pub fn calibrated_state(&self, cfg: &RunConfig) -> Result<StateStore> {
        let qdir = self
            .rt
            .artifacts_dir()
            .join("qstate")
            .join(cfg.tag())
            .join(format!("it{}", self.effective_iters(cfg)));
        if qdir.join("index.tsv").exists() {
            return StateStore::load(&qdir);
        }
        let mut cfg = cfg.clone();
        cfg.calib.iters = self.effective_iters(&cfg);
        let chain = self.chain(&cfg.model)?;
        let mut calibrator = Calibrator::new(chain, cfg.clone());
        calibrator.verbose = self.verbose;
        let (st, _reports) = calibrator.run(&self.dataset.calib)?;
        st.save(&qdir)?;
        Ok(st)
    }

    fn effective_iters(&self, cfg: &RunConfig) -> u32 {
        self.iters_override.unwrap_or(cfg.calib.iters)
    }

    /// Calibrate + evaluate one cell. Returns top-1 accuracy.
    pub fn run_cell(&self, model: &str, method: Method, bits: Bits) -> Result<f64> {
        let cfg = RunConfig::new(model, method, bits);
        let st = self.calibrated_state(&cfg)?;
        let chain = self.chain(model)?;
        let q = QuantCtx {
            state: &st,
            bits,
            knobs: Knobs::inference(method, bits),
        };
        eval_quant_accuracy_limited(&chain, &self.dataset.test, &q, self.eval_limit)
    }

    /// Append a rendered table to results/<file> and stdout.
    pub fn emit(&self, file: &str, text: &str) -> Result<()> {
        println!("{text}");
        std::fs::write(self.results_dir.join(file), text)?;
        Ok(())
    }
}

/// Build a pure-Rust quantized inference engine from a calibrated cell:
/// hard-quantized weights + the learned border function per layer. This is
/// the serving path (no PJRT on the hot loop).
pub fn build_quantized_engine(
    ctx: &Ctx,
    model: &str,
    method: Method,
    bits: Bits,
) -> Result<crate::nn::engine::Engine> {
    use crate::coordinator::state::bits_row_for;
    use crate::nn::engine::{ActQuant, Engine};
    use crate::quant::border::BorderFn;
    use crate::quant::weights::harden;

    let cfg = RunConfig::new(model, method, bits);
    let st = ctx.calibrated_state(&cfg)?;
    let topo = ctx.topo(model)?.clone();
    let fp_weights = ctx.weights(model)?;
    let knobs = Knobs::inference(method, bits);
    let mut weights = HashMap::new();
    let mut engine_quant: Vec<(String, ActQuant)> = Vec::new();
    for l in topo.all_layers() {
        let row = bits_row_for(&topo, bits, &l.name);
        let lw = &fp_weights[&l.name];
        let w = if bits.w_quantized() {
            let s_w = st.get(&format!("state:{}.s_w", l.name))?;
            let v = st.get(&format!("state:{}.V", l.name))?;
            harden(&lw.w, &s_w.data, &v.data, l.oc, row.qmin_w, row.qmax_w)
        } else {
            lw.w.clone()
        };
        weights.insert(
            l.name.clone(),
            LayerWeights {
                w,
                b: lw.b.clone(),
            },
        );
        if bits.a_quantized() {
            let s_a = st.get(&format!("state:{}.s_a", l.name))?.data[0];
            let bp = st.get(&format!("state:{}.bp", l.name))?;
            let border = if knobs.border_en {
                BorderFn::from_params(bp.data.clone(), l.k2(), knobs.fuse_en, knobs.b2_en)?
            } else {
                BorderFn::nearest(l.rows, l.k2())
            };
            engine_quant.push((
                l.name.clone(),
                ActQuant::Border {
                    border,
                    s: s_a,
                    qmin: row.qmin_a,
                    qmax: row.qmax_a,
                },
            ));
        }
    }
    let mut engine = Engine::new(topo, weights);
    for (name, q) in engine_quant {
        engine.set_act_quant(&name, q);
    }
    Ok(engine)
}

/// Manifest-engine builder for `ModelRegistry::from_specs` in pjrt
/// builds: calibrates + hardens each manifest spec via
/// [`build_quantized_engine`], creating the [`Ctx`] lazily on the first
/// manifest spec (a synth-only registry never pays artifact loading).
/// Shared by `aquant serve` and `examples/serve.rs` so the two cannot
/// drift.
pub struct QuantManifestBuilder {
    artifacts_dir: String,
    iters_override: Option<u32>,
    verbose: bool,
    ctx: Option<Ctx>,
}

impl QuantManifestBuilder {
    pub fn new(artifacts_dir: &str, iters_override: Option<u32>, verbose: bool) -> Self {
        QuantManifestBuilder {
            artifacts_dir: artifacts_dir.to_string(),
            iters_override,
            verbose,
            ctx: None,
        }
    }

    /// Build the quantized engine for one manifest spec.
    pub fn build(&mut self, spec: &ModelSpec) -> Result<crate::nn::engine::Engine> {
        let ModelSource::Manifest {
            model,
            method,
            bits,
        } = &spec.source
        else {
            bail!("spec {:?} is not a manifest model", spec.name);
        };
        if self.ctx.is_none() {
            let mut ctx = Ctx::new(&self.artifacts_dir, self.iters_override)?;
            ctx.verbose = self.verbose;
            self.ctx = Some(ctx);
        }
        println!(
            "aquant-serve: building engine {} = {model} {} {}",
            spec.name,
            method.name(),
            bits.name()
        );
        build_quantized_engine(
            self.ctx.as_ref().expect("ctx just built"),
            model,
            *method,
            *bits,
        )
    }
}
