//! Tables 1–4 of the paper, regenerated on this testbed.

use anyhow::Result;

use super::cell::{Ctx, QUANT_METHODS};
use crate::config::{Bits, Method};
use crate::coordinator::state::bits_row_for;
use crate::nn::engine::{ActQuant, Engine};
use crate::quant::border::BorderFn;
use crate::quant::scale_search;

fn pct(x: f64) -> String {
    format!("{:.2}", x * 100.0)
}

/// Table 1: A-rounding vs N-rounding under W32A2 (FP weights, 2-bit
/// activations) — the motivation experiment. Pure-Rust engine + the
/// SQuant-style flip algorithm.
pub fn table1(ctx: &Ctx, test_limit: usize) -> Result<String> {
    let mut rows = vec![
        "Table 1: adjusted rounding (A-rounding) vs nearest rounding, W32A2".to_string(),
        format!("(test subset: {test_limit} images; FP weights; 8-bit first/last layer)"),
        format!("{:<14} {:>10} {:>12} {:>12}", "model", "FP", "N-rounding", "A-rounding"),
    ];
    let bits = Bits { w: 32, a: 2 };
    for model in ctx.models() {
        let topo = ctx.topo(&model)?.clone();
        let weights = ctx.weights(&model)?.clone();
        // FP engine accuracy (sanity anchor).
        let fp_engine = Engine::new(topo.clone(), weights.clone());
        let fp_acc =
            crate::eval::eval_engine_accuracy(&fp_engine, &ctx.dataset.test, Some(test_limit))?;

        // Per-layer activation scales from FP taps over a calib subset.
        let mut taps: std::collections::HashMap<String, Vec<f32>> = Default::default();
        for i in 0..64.min(ctx.dataset.calib.n) {
            let mut t = Default::default();
            fp_engine.forward(ctx.dataset.calib.image(i), Some(&mut t))?;
            for (k, v) in t {
                taps.entry(k).or_default().extend_from_slice(&v.data);
            }
        }
        let mut scales = std::collections::HashMap::new();
        for l in topo.all_layers() {
            let row = bits_row_for(&topo, bits, &l.name);
            let sample = scale_search::sample_values(&taps[&l.name], 8192, 0x7AB1E);
            let s = scale_search::search_scale(&sample, row.qmin_a, row.qmax_a, 60);
            scales.insert(l.name.clone(), (s, row));
        }

        let mut accs = Vec::new();
        for around in [false, true] {
            let mut eng = Engine::new(topo.clone(), weights.clone());
            for l in topo.all_layers() {
                let (s, row) = scales[&l.name];
                let q = if around {
                    ActQuant::ARound {
                        s,
                        qmin: row.qmin_a,
                        qmax: row.qmax_a,
                    }
                } else {
                    ActQuant::Border {
                        border: BorderFn::nearest(l.rows, l.k2()),
                        s,
                        qmin: row.qmin_a,
                        qmax: row.qmax_a,
                    }
                };
                eng.set_act_quant(&l.name, q);
            }
            accs.push(crate::eval::eval_engine_accuracy(
                &eng,
                &ctx.dataset.test,
                Some(test_limit),
            )?);
        }
        rows.push(format!(
            "{:<14} {:>10} {:>12} {:>12}",
            model,
            pct(fp_acc),
            pct(accs[0]),
            pct(accs[1])
        ));
    }
    Ok(rows.join("\n") + "\n")
}

/// Table 2: activation-only quantization (W32A4, W32A2) — nearest vs
/// QDrop vs AQuant. QDrop degenerates with FP weights (its optimization
/// lives in the weights), which is exactly the paper's point.
pub fn table2(ctx: &Ctx, models: &[String]) -> Result<String> {
    let methods = [Method::Nearest, Method::QDrop, Method::AQuant];
    let mut rows = vec![
        "Table 2: activation-only quantization".to_string(),
        format!(
            "{:<14} {:>8} {:>10} {:>10} {:>10}",
            "model", "bits", "Rounding", "QDrop", "AQuant"
        ),
    ];
    for model in models {
        let fp = ctx.fp_accuracy(model)?;
        rows.push(format!("{:<14} {:>8} FP acc {}", model, "W32A32", pct(fp)));
        for bits_s in ["W32A4", "W32A2"] {
            let bits = Bits::parse(bits_s)?;
            let mut accs = Vec::new();
            for m in methods {
                accs.push(ctx.run_cell(model, m, bits)?);
            }
            rows.push(format!(
                "{:<14} {:>8} {:>10} {:>10} {:>10}",
                model,
                bits_s,
                pct(accs[0]),
                pct(accs[1]),
                pct(accs[2])
            ));
        }
    }
    Ok(rows.join("\n") + "\n")
}

/// Table 3: fully quantized models, AdaRound / BRECQ / QDrop / AQuant at
/// W4A4, W2A4, W3A3, W2A2.
pub fn table3(ctx: &Ctx, models: &[String]) -> Result<String> {
    let mut rows = vec![
        "Table 3: fully quantized models".to_string(),
        format!(
            "{:<14} {:>6} {:>10} {:>10} {:>10} {:>10}",
            "model", "bits", "AdaRound", "BRECQ", "QDrop", "AQuant"
        ),
    ];
    for model in models {
        let fp = ctx.fp_accuracy(model)?;
        rows.push(format!("{:<14} {:>6} FP acc {}", model, "FP", pct(fp)));
        for bits_s in ["W4A4", "W2A4", "W3A3", "W2A2"] {
            let bits = Bits::parse(bits_s)?;
            let mut accs = Vec::new();
            for &m in QUANT_METHODS {
                accs.push(ctx.run_cell(model, m, bits)?);
            }
            rows.push(format!(
                "{:<14} {:>6} {:>10} {:>10} {:>10} {:>10}",
                model,
                bits_s,
                pct(accs[0]),
                pct(accs[1]),
                pct(accs[2]),
                pct(accs[3])
            ));
        }
    }
    Ok(rows.join("\n") + "\n")
}

/// Table 4: ablations — border function form (linear vs quadratic) and
/// border fusion (on vs off), at W2A2 and W3A3.
pub fn table4(ctx: &Ctx, models: &[String]) -> Result<String> {
    let mut rows = vec![
        "Table 4: border-function and border-fusion ablations".to_string(),
        format!(
            "{:<14} {:>6} {:>10} {:>10} | {:>10} {:>10}",
            "model", "bits", "linear", "quadratic", "no-fusion", "fusion"
        ),
    ];
    for model in models {
        for bits_s in ["W2A2", "W3A3"] {
            let bits = Bits::parse(bits_s)?;
            let lin = ctx.run_cell(model, Method::AQuantLinear, bits)?;
            let quad = ctx.run_cell(model, Method::AQuant, bits)?;
            let nofuse = ctx.run_cell(model, Method::AQuantNoFusion, bits)?;
            rows.push(format!(
                "{:<14} {:>6} {:>10} {:>10} | {:>10} {:>10}",
                model,
                bits_s,
                pct(lin),
                pct(quad),
                pct(nofuse),
                pct(quad)
            ));
        }
    }
    Ok(rows.join("\n") + "\n")
}
