//! Quantization run configuration: method × bit-width → per-layer bit
//! policy and calibration hyper-parameters (the paper's §5 experimental
//! setup, expressed as data).

use anyhow::{bail, Result};

/// PTQ method under evaluation. `Nearest` is the "Rounding" row of the
/// paper's tables; the rest map 1:1 onto the compared methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Rounding-to-nearest, MSE-searched scales, no calibration.
    Nearest,
    /// AdaRound: per-layer weight-rounding reconstruction.
    AdaRound,
    /// BRECQ: block-wise weight-rounding + learned activation step size.
    Brecq,
    /// QDrop: BRECQ + random dropping of block-input quantization.
    QDrop,
    /// AQuant: QDrop-style dropping + the adaptive rounding border.
    AQuant,
    /// Ablation: linear border (b2 disabled). Table 4.
    AQuantLinear,
    /// Ablation: element-wise border only (fusion disabled). Table 4.
    AQuantNoFusion,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "nearest" | "rounding" => Method::Nearest,
            "adaround" => Method::AdaRound,
            "brecq" => Method::Brecq,
            "qdrop" => Method::QDrop,
            "aquant" => Method::AQuant,
            "aquant-linear" => Method::AQuantLinear,
            "aquant-nofusion" => Method::AQuantNoFusion,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Nearest => "nearest",
            Method::AdaRound => "adaround",
            Method::Brecq => "brecq",
            Method::QDrop => "qdrop",
            Method::AQuant => "aquant",
            Method::AQuantLinear => "aquant-linear",
            Method::AQuantNoFusion => "aquant-nofusion",
        }
    }

    /// Does this method learn an adaptive border?
    pub fn uses_border(&self) -> bool {
        matches!(
            self,
            Method::AQuant | Method::AQuantLinear | Method::AQuantNoFusion
        )
    }

    /// Reconstruction granularity: layer-wise (AdaRound) or block-wise.
    pub fn layer_wise(&self) -> bool {
        matches!(self, Method::AdaRound)
    }

    /// QDrop-style block-input drop probability.
    pub fn drop_prob(&self) -> f32 {
        match self {
            Method::QDrop | Method::AQuant | Method::AQuantLinear | Method::AQuantNoFusion => 0.5,
            _ => 0.0,
        }
    }

    /// Requires any calibration at all?
    pub fn calibrates(&self) -> bool {
        !matches!(self, Method::Nearest)
    }

    pub fn all() -> &'static [Method] {
        &[
            Method::Nearest,
            Method::AdaRound,
            Method::Brecq,
            Method::QDrop,
            Method::AQuant,
            Method::AQuantLinear,
            Method::AQuantNoFusion,
        ]
    }
}

/// Bit-width setting, e.g. W2A2 or W32A4 (32 = keep full precision).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bits {
    pub w: u32,
    pub a: u32,
}

impl Bits {
    pub fn parse(s: &str) -> Result<Bits> {
        let s = s.to_ascii_uppercase();
        let Some(rest) = s.strip_prefix('W') else {
            bail!("bits spec must look like W4A4, got {s:?}")
        };
        let Some((w, a)) = rest.split_once('A') else {
            bail!("bits spec must look like W4A4, got {s:?}")
        };
        Ok(Bits {
            w: w.parse()?,
            a: a.parse()?,
        })
    }

    pub fn name(&self) -> String {
        format!("W{}A{}", self.w, self.a)
    }

    pub fn w_quantized(&self) -> bool {
        self.w < 32
    }

    pub fn a_quantized(&self) -> bool {
        self.a < 32
    }
}

/// Per-layer integer ranges fed to the HLO programs as the `hyper:bits`
/// rows [qmin_a, qmax_a, qmin_w, qmax_w].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitsRow {
    pub qmin_a: f32,
    pub qmax_a: f32,
    pub qmin_w: f32,
    pub qmax_w: f32,
    /// Which qinit directory (wbits) this layer's scales come from.
    pub w_init_bits: u32,
}

impl BitsRow {
    /// Flat [qmin_a, qmax_a, qmin_w, qmax_w] as fed to HLO.
    pub fn as_row(&self) -> [f32; 4] {
        [self.qmin_a, self.qmax_a, self.qmin_w, self.qmax_w]
    }
}

/// The paper keeps the first and last layer at 8 bits (Appendix C).
pub fn layer_bits(bits: Bits, is_first: bool, is_last: bool, signed_act: bool) -> BitsRow {
    let ab = if bits.a >= 32 {
        8 // unused when aq_en = 0
    } else if is_first || is_last {
        8
    } else {
        bits.a
    };
    let wb = if bits.w >= 32 {
        8 // unused when wq_en = 0
    } else if is_first || is_last {
        8
    } else {
        bits.w
    };
    let (qmin_a, qmax_a) = if signed_act {
        (-(2f32.powi(ab as i32 - 1)), 2f32.powi(ab as i32 - 1) - 1.0)
    } else {
        (0.0, 2f32.powi(ab as i32) - 1.0)
    };
    let qmin_w = -(2f32.powi(wb as i32 - 1));
    let qmax_w = 2f32.powi(wb as i32 - 1) - 1.0;
    BitsRow {
        qmin_a,
        qmax_a,
        qmin_w,
        qmax_w,
        w_init_bits: wb,
    }
}

/// Calibration hyper-parameters (Appendix B/C defaults, iteration count
/// scaled to this testbed — the paper uses 20k iterations on ImageNet).
#[derive(Debug, Clone)]
pub struct CalibConfig {
    pub iters: u32,
    pub batch: usize,
    pub lr_v: f32,
    pub lr_s: f32,
    pub lr_b: f32,
    /// AdaRound regularizer weight λ and β anneal range.
    pub lam: f32,
    pub beta_start: f32,
    pub beta_end: f32,
    /// Fraction of iterations with α_round = 0 before the linear ramp
    /// (Appendix B rounding schedule).
    pub warmup_frac: f32,
    pub seed: u64,
}

impl Default for CalibConfig {
    fn default() -> Self {
        CalibConfig {
            iters: 600,
            batch: 32,
            lr_v: 3e-3,
            lr_s: 4e-5,
            lr_b: 1e-3,
            lam: 0.01,
            beta_start: 20.0,
            beta_end: 2.0,
            warmup_frac: 0.2,
            seed: 0xCA11B,
        }
    }
}

/// Per-model serving-policy overrides parsed from a `--model` spec's
/// `;key=value` tail. `None` fields fall back to the server-level
/// defaults (the global `--max-batch/--batch-wait-us/--queue-images`
/// knobs, weight 1) when resolved into a
/// [`crate::server::sched::Policy`] at bind time. Spec-side only —
/// bounds are enforced at resolution, except `weight=0`, which is
/// rejected here too so the CLI fails before engines are built.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PolicyOverrides {
    pub max_batch: Option<usize>,
    pub batch_wait_us: Option<u64>,
    pub queue_images: Option<usize>,
    pub weight: Option<u32>,
    /// End-to-end p99 latency target in µs: when set, the scheduler
    /// nudges this model's effective weight up (never below `weight`,
    /// bounded) while its observed p99 misses the target. None = no
    /// SLO, weight stays static.
    pub slo_us: Option<u64>,
}

impl PolicyOverrides {
    /// Parse the `;key=value` pairs trailing a model spec. Known keys:
    /// `max_batch`, `batch_wait_us`, `queue_images`, `weight`,
    /// `slo_us`. Unknown keys, duplicates, bad numbers, `weight=0`,
    /// and `slo_us=0` are errors (`spec` is quoted in messages).
    pub fn parse_pairs<'a>(
        pairs: impl Iterator<Item = &'a str>,
        spec: &str,
    ) -> Result<PolicyOverrides> {
        fn num<T: std::str::FromStr>(spec: &str, k: &str, v: &str) -> Result<T> {
            v.parse()
                .map_err(|_| anyhow::anyhow!("model spec {spec:?}: {k}={v:?} is not a valid number"))
        }
        let mut out = PolicyOverrides::default();
        for pair in pairs {
            let (k, v) = crate::util::cli::split_kv(pair)
                .map_err(|e| anyhow::anyhow!("model spec {spec:?}: {e}"))?;
            let dup = match k {
                "max_batch" => out.max_batch.replace(num(spec, k, v)?).is_some(),
                "batch_wait_us" => out.batch_wait_us.replace(num(spec, k, v)?).is_some(),
                "queue_images" => out.queue_images.replace(num(spec, k, v)?).is_some(),
                "weight" => {
                    let w: u32 = num(spec, k, v)?;
                    if w == 0 {
                        bail!("model spec {spec:?}: weight=0 would starve the model (use >= 1)");
                    }
                    out.weight.replace(w).is_some()
                }
                "slo_us" => {
                    let us: u64 = num(spec, k, v)?;
                    if us == 0 {
                        bail!(
                            "model spec {spec:?}: slo_us=0 is unmeetable \
                             (omit the key for no SLO)"
                        );
                    }
                    out.slo_us.replace(us).is_some()
                }
                other => bail!(
                    "model spec {spec:?}: unknown policy key {other:?} \
                     (known: max_batch, batch_wait_us, queue_images, weight, slo_us)"
                ),
            };
            if dup {
                bail!("model spec {spec:?}: duplicate policy key {k:?}");
            }
        }
        Ok(out)
    }

    /// True when no knob is overridden (the spec had no policy tail).
    pub fn is_empty(&self) -> bool {
        *self == PolicyOverrides::default()
    }
}

/// One model a serving process hosts: a routing name, where the engine
/// comes from, and its serving-policy overrides. Parsed from repeated
/// `--model` flags and threaded end to end (CLI → registry → protocol-v2
/// routing → fair scheduler); the first spec becomes model id 0, the
/// default model that also serves v1 clients.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Registry / routing name (unique per server).
    pub name: String,
    pub source: ModelSource,
    /// Per-model serving knobs from the spec's `;key=value` tail.
    pub policy: PolicyOverrides,
}

/// Where a hosted model's engine comes from.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSource {
    /// Synthetic model from `nn::synth` (no artifacts, no PJRT):
    /// kind is "tiny" | "bench" | "rand".
    Synth { kind: String, seed: u64 },
    /// Calibrated engine built from the artifacts manifest.
    Manifest {
        model: String,
        method: Method,
        bits: Bits,
    },
}

impl ModelSpec {
    /// Parse one `--model` spec:
    ///
    /// ```text
    ///   [NAME=]synth:KIND[:SEED][;key=value...]   KIND = tiny | bench | rand
    ///   [NAME=]MODEL[:METHOD:BITS][;key=value...] manifest model; METHOD/BITS
    ///                                             fall back to --method/--bits
    /// ```
    ///
    /// `NAME` defaults to the synth kind / manifest model name. The
    /// `synth:` prefix is reserved (a manifest model cannot be named
    /// "synth"). The `;key=value` tail sets this model's serving
    /// policy ([`PolicyOverrides`]): `;max_batch=`, `;batch_wait_us=`,
    /// `;queue_images=`, `;weight=`, `;slo_us=` — anything unset
    /// inherits the server-level knobs.
    pub fn parse(
        spec: &str,
        default_method: Option<Method>,
        default_bits: Option<Bits>,
    ) -> Result<ModelSpec> {
        let mut fields = spec.split(';');
        let base = fields.next().unwrap_or("");
        let policy = PolicyOverrides::parse_pairs(fields, spec)?;
        let (name, rest) = match base.split_once('=') {
            Some((n, r)) => (Some(n), r),
            None => (None, base),
        };
        if let Some(n) = name {
            if n.is_empty() {
                bail!("model spec {spec:?}: empty name before '='");
            }
        }
        if rest.is_empty() {
            bail!("model spec {spec:?}: empty source");
        }
        if let Some(synth) = rest.strip_prefix("synth:") {
            let mut it = synth.split(':');
            let kind = it.next().unwrap_or("").to_string();
            if !matches!(kind.as_str(), "tiny" | "bench" | "rand") {
                bail!("model spec {spec:?}: synth kind must be tiny|bench|rand, got {kind:?}");
            }
            let seed = match it.next() {
                None => 42,
                Some(s) => s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("model spec {spec:?}: bad seed {s:?}"))?,
            };
            if it.next().is_some() {
                bail!("model spec {spec:?}: trailing fields after synth:KIND:SEED");
            }
            return Ok(ModelSpec {
                name: name.unwrap_or(&kind).to_string(),
                source: ModelSource::Synth { kind, seed },
                policy,
            });
        }
        let mut it = rest.split(':');
        let model = it.next().unwrap_or("").to_string();
        if model.is_empty() || model == "synth" {
            bail!("model spec {spec:?}: bad model name {model:?}");
        }
        let (method, bits) = match (it.next(), it.next()) {
            (None, _) => {
                let m = default_method
                    .ok_or_else(|| anyhow::anyhow!("model spec {spec:?}: no method (give MODEL:METHOD:BITS or --method)"))?;
                let b = default_bits
                    .ok_or_else(|| anyhow::anyhow!("model spec {spec:?}: no bits (give MODEL:METHOD:BITS or --bits)"))?;
                (m, b)
            }
            (Some(m), Some(b)) => (Method::parse(m)?, Bits::parse(b)?),
            (Some(_), None) => {
                bail!("model spec {spec:?}: METHOD given without BITS (want MODEL:METHOD:BITS)")
            }
        };
        if it.next().is_some() {
            bail!("model spec {spec:?}: trailing fields after MODEL:METHOD:BITS");
        }
        Ok(ModelSpec {
            name: name.unwrap_or(&model).to_string(),
            source: ModelSource::Manifest { model, method, bits },
            policy,
        })
    }

    /// Parse a repeated `--model` flag list; errors on empty input or
    /// duplicate routing names (the registry would reject them later,
    /// but the CLI error is clearer).
    pub fn parse_all(
        specs: &[String],
        default_method: Option<Method>,
        default_bits: Option<Bits>,
    ) -> Result<Vec<ModelSpec>> {
        if specs.is_empty() {
            bail!("no --model specs given");
        }
        let mut out: Vec<ModelSpec> = Vec::with_capacity(specs.len());
        for s in specs {
            let spec = ModelSpec::parse(s, default_method, default_bits)?;
            if out.iter().any(|o| o.name == spec.name) {
                bail!(
                    "duplicate model name {:?} (disambiguate with NAME=SPEC)",
                    spec.name
                );
            }
            out.push(spec);
        }
        Ok(out)
    }
}

/// One routing rule a router-mode process forwards by: a model name
/// and the backend `host:port` hosting it. Parsed from repeated
/// `--route` flags; route order assigns the router-visible model ids
/// (first route is id 0, the model protocol-v1 clients reach), so
/// backends must host each routed model at the SAME id — frames are
/// forwarded byte-identically, ids are never rewritten.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RouteSpec {
    /// Route key (the model name clients know; unique per router).
    pub name: String,
    /// Backend address, `host:port`.
    pub addr: String,
}

impl RouteSpec {
    /// Parse one `--route MODEL=host:port` value. The address is
    /// validated structurally here — non-empty host, port a nonzero
    /// `u16` — so `m=foo:`, `m=:9000`, and `m=a:b` fail at startup
    /// with a config error instead of at first connect. `rsplit_once`
    /// keeps bracketed IPv6 (`[::1]:9000`) working: the LAST colon
    /// separates the port.
    pub fn parse(spec: &str) -> Result<RouteSpec> {
        let (name, addr) = crate::util::cli::split_kv(spec)
            .map_err(|e| anyhow::anyhow!("route spec {spec:?}: {e} (want MODEL=host:port)"))?;
        let Some((host, port)) = addr.rsplit_once(':') else {
            bail!("route spec {spec:?}: backend {addr:?} is not host:port");
        };
        if host.is_empty() {
            bail!("route spec {spec:?}: backend {addr:?} has an empty host");
        }
        match port.parse::<u16>() {
            Ok(p) if p != 0 => {}
            _ => bail!(
                "route spec {spec:?}: backend {addr:?} port {port:?} is not \
                 a nonzero u16"
            ),
        }
        Ok(RouteSpec {
            name: name.to_string(),
            addr: addr.to_string(),
        })
    }

    /// Parse a repeated `--route` flag list; errors on empty input and
    /// duplicate route keys (the same rule `--model` names get — a
    /// duplicate would silently shadow the earlier backend).
    pub fn parse_all(specs: &[String]) -> Result<Vec<RouteSpec>> {
        if specs.is_empty() {
            bail!("no --route specs given");
        }
        let mut out: Vec<RouteSpec> = Vec::with_capacity(specs.len());
        for s in specs {
            let spec = RouteSpec::parse(s)?;
            if out.iter().any(|o| o.name == spec.name) {
                bail!("duplicate route key {:?} (each model routes to one backend)", spec.name);
            }
            out.push(spec);
        }
        Ok(out)
    }
}

/// Serving-runtime knobs, threaded from the CLI (`aquant serve` /
/// `examples/serve.rs`) into the event-loop server: `--workers`,
/// `--max-batch`, `--batch-wait-us`, `--queue-images`, `--max-conns`,
/// `--conn-timeout-ms`, `--max-accepts`, `--io-poll`, `--stats-addr`,
/// `--stats-history`, `--stats-history-every-s`, `--intra-split`,
/// `--fast-kernels`, `--admin-addr`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServeConfig {
    /// Inference worker threads. 0 = auto (cores − 1).
    pub workers: usize,
    /// Intra-image parallelism (`--intra-split`): chunks a large conv
    /// layer's gather/GEMM phases are split into so idle workers can
    /// help with a single image (bounds single-image latency by more
    /// than one core). 0 = auto (one chunk per worker), 1 = off.
    pub intra_split: usize,
    /// Max images coalesced into one engine batch.
    pub max_batch: usize,
    /// How long the batcher waits for more images once one request is
    /// pending (0 = dispatch immediately; larger = better coalescing,
    /// worse tail latency).
    pub batch_wait_us: u64,
    /// Bound on queued images; full queue backpressures connections.
    pub queue_images: usize,
    /// Concurrent-connection cap (`--max-conns`): accepts beyond it
    /// are closed immediately and counted; None = unbounded.
    pub max_conns: Option<usize>,
    /// Idle/read timeout per connection in ms (`--conn-timeout-ms`):
    /// a connection the server owes nothing that moves no bytes for
    /// this long is closed (slow-loris / dead-peer reclamation).
    /// 0 = never.
    pub conn_timeout_ms: u64,
    /// Accept at most this many connections then drain and return
    /// (`--max-accepts`; bounded runs for tests/examples); None = run
    /// until killed.
    pub max_accepts: Option<usize>,
    /// Force the portable `poll(2)` readiness backend (`--io-poll`)
    /// instead of the platform default (epoll on Linux).
    pub poll_fallback: bool,
    /// Opt into the relaxed FMA GEMM kernels (`--fast-kernels`, same as
    /// `AQUANT_FAST=fma`): faster, but results are only allclose to —
    /// not bit-identical with — the exact default. Off by default.
    pub fast_kernels: bool,
    /// Bind a read-only stats endpoint here (`--stats-addr`, e.g.
    /// `127.0.0.1:9100`): `GET /stats` returns a JSON snapshot,
    /// `GET /stats?fmt=text` plaintext. None = no endpoint.
    pub stats_addr: Option<String>,
    /// Bind the control-plane admin listener here (`--admin-addr`,
    /// e.g. `127.0.0.1:9200`): a line-oriented protocol (`add`,
    /// `remove`, `policy`, `reload`) that epoch-swaps the model
    /// registry under live traffic. None = no control plane (the
    /// registry stays immutable after bind).
    pub admin_addr: Option<String>,
    /// Append periodic stats snapshots to this file as JSON lines
    /// (`--stats-history`); None = no history.
    pub stats_history: Option<String>,
    /// Seconds between history snapshots (`--stats-history-every-s`).
    pub stats_history_every_s: u64,
    /// Router mode: persistent connections kept per backend
    /// (`--route-pool`). More connections = more pipelining lanes and
    /// isolation domains per backend.
    pub route_pool: usize,
    /// Router mode: forwarded-but-unanswered requests allowed per
    /// backend connection before client reads park
    /// (`--route-inflight`).
    pub route_inflight: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 0,
            intra_split: 0,
            max_batch: 64,
            batch_wait_us: 200,
            queue_images: 8192,
            max_conns: None,
            conn_timeout_ms: 0,
            max_accepts: None,
            poll_fallback: false,
            fast_kernels: false,
            stats_addr: None,
            admin_addr: None,
            stats_history: None,
            stats_history_every_s: 5,
            route_pool: 2,
            route_inflight: 32,
        }
    }
}

impl ServeConfig {
    /// Parse the serving flags (absent flags keep defaults;
    /// `--workers auto` is the same as omitting it).
    pub fn from_args(args: &crate::util::cli::Args) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let workers = match args.str_flag_opt("workers") {
            None => d.workers,
            Some("auto") => 0,
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--workers={v} is not a number (or 'auto')"))?,
        };
        let intra_split = match args.str_flag_opt("intra-split") {
            None => d.intra_split,
            Some("auto") => 0,
            Some("off") => 1,
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--intra-split={v} is not a number (or 'auto'/'off')")
            })?,
        };
        let opt_count = |flag: &str| -> Result<Option<usize>> {
            match args.str_flag_opt(flag) {
                None => Ok(None),
                Some(v) => Ok(Some(v.parse().map_err(|_| {
                    anyhow::anyhow!("--{flag}={v} is not a number")
                })?)),
            }
        };
        let cfg = ServeConfig {
            workers,
            intra_split,
            max_batch: args.num_flag("max-batch", d.max_batch)?,
            batch_wait_us: args.num_flag("batch-wait-us", d.batch_wait_us)?,
            queue_images: args.num_flag("queue-images", d.queue_images)?,
            max_conns: opt_count("max-conns")?,
            conn_timeout_ms: args.num_flag("conn-timeout-ms", d.conn_timeout_ms)?,
            max_accepts: opt_count("max-accepts")?,
            poll_fallback: args.bool_flag("io-poll"),
            fast_kernels: args.bool_flag("fast-kernels"),
            stats_addr: args.str_flag_opt("stats-addr").map(str::to_string),
            admin_addr: args.str_flag_opt("admin-addr").map(str::to_string),
            stats_history: args.str_flag_opt("stats-history").map(str::to_string),
            stats_history_every_s: args
                .num_flag("stats-history-every-s", d.stats_history_every_s)?,
            route_pool: args.num_flag("route-pool", d.route_pool)?,
            route_inflight: args.num_flag("route-inflight", d.route_inflight)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Upper bound on the straggler deadline (60 s): far beyond any
    /// useful coalescing window, and small enough that
    /// `Instant::now() + wait` can never overflow.
    pub const MAX_BATCH_WAIT_US: u64 = 60_000_000;

    /// Upper bound on `max_batch` (global and per-model): 16x the
    /// protocol's per-request cap — coalescing beyond it wins nothing,
    /// and the bound keeps the fair scheduler's quantum arithmetic
    /// (`quantum * weight`) far from integer overflow.
    pub const MAX_MAX_BATCH: usize = 65_536;

    /// Upper bound on explicit worker counts — far above any core count
    /// this serves on, low enough that thread spawning cannot fail
    /// halfway through startup.
    pub const MAX_WORKERS: usize = 1024;

    /// Upper bound on the per-connection idle/read timeout (1 hour):
    /// beyond that "never" (`0`) is what the operator means.
    pub const MAX_CONN_TIMEOUT_MS: u64 = 3_600_000;

    /// Upper bound on the stats-history snapshot interval (1 day):
    /// beyond that the operator almost certainly typo'd the unit.
    pub const MAX_STATS_HISTORY_EVERY_S: u64 = 86_400;

    /// Upper bound on `--route-pool`: the router's backend-connection
    /// token space strides by 64 per backend.
    pub const MAX_ROUTE_POOL: usize = 64;

    /// Upper bound on `--route-inflight`: a window deeper than the
    /// protocol's request cap buys nothing.
    pub const MAX_ROUTE_INFLIGHT: usize = 4096;

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("--max-batch must be >= 1");
        }
        if self.max_batch > Self::MAX_MAX_BATCH {
            bail!(
                "--max-batch ({}) must be <= {}",
                self.max_batch,
                Self::MAX_MAX_BATCH
            );
        }
        if self.queue_images < self.max_batch {
            bail!(
                "--queue-images ({}) must be >= --max-batch ({})",
                self.queue_images,
                self.max_batch
            );
        }
        if self.batch_wait_us > Self::MAX_BATCH_WAIT_US {
            bail!(
                "--batch-wait-us ({}) must be <= {} (60s)",
                self.batch_wait_us,
                Self::MAX_BATCH_WAIT_US
            );
        }
        if self.workers > Self::MAX_WORKERS {
            bail!(
                "--workers ({}) must be <= {} (a clean config error beats \
                 panicking mid-way through thread spawning)",
                self.workers,
                Self::MAX_WORKERS
            );
        }
        if self.intra_split > Self::MAX_WORKERS {
            bail!(
                "--intra-split ({}) must be <= {} (chunks beyond the worker \
                 cap only add claim-cursor overhead)",
                self.intra_split,
                Self::MAX_WORKERS
            );
        }
        if self.max_conns == Some(0) {
            bail!(
                "--max-conns 0 would reject every connection \
                 (use --max-accepts 0 for a bind-only run)"
            );
        }
        if self.conn_timeout_ms > Self::MAX_CONN_TIMEOUT_MS {
            bail!(
                "--conn-timeout-ms ({}) must be <= {} (1h); use 0 for no timeout",
                self.conn_timeout_ms,
                Self::MAX_CONN_TIMEOUT_MS
            );
        }
        if self.stats_history_every_s == 0 {
            bail!("--stats-history-every-s must be >= 1 (omit --stats-history for none)");
        }
        if self.stats_history_every_s > Self::MAX_STATS_HISTORY_EVERY_S {
            bail!(
                "--stats-history-every-s ({}) must be <= {} (1 day)",
                self.stats_history_every_s,
                Self::MAX_STATS_HISTORY_EVERY_S
            );
        }
        if self.route_pool == 0 || self.route_pool > Self::MAX_ROUTE_POOL {
            bail!(
                "--route-pool ({}) must be in 1..={} (connections per backend)",
                self.route_pool,
                Self::MAX_ROUTE_POOL
            );
        }
        if self.route_inflight == 0 || self.route_inflight > Self::MAX_ROUTE_INFLIGHT {
            bail!(
                "--route-inflight ({}) must be in 1..={} (in-flight window per \
                 backend connection)",
                self.route_inflight,
                Self::MAX_ROUTE_INFLIGHT
            );
        }
        Ok(())
    }

    /// Worker count with `0 = auto` resolved to cores − 1.
    pub fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::threadpool::default_threads()
        } else {
            self.workers
        }
    }
}

/// One full experiment cell: model × method × bits.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub method: Method,
    pub bits: Bits,
    pub calib: CalibConfig,
}

impl RunConfig {
    pub fn new(model: &str, method: Method, bits: Bits) -> Self {
        let mut calib = CalibConfig::default();
        if method.uses_border() {
            // AQuant slows h(V) convergence (Appendix C): stronger
            // regularization, lower starting β.
            calib.beta_start = 16.0;
            calib.lam = 0.05;
        }
        RunConfig {
            model: model.to_string(),
            method,
            bits,
            calib,
        }
    }

    /// Tag used for qstate directories and result rows.
    pub fn tag(&self) -> String {
        format!("{}_{}_{}", self.model, self.method.name(), self.bits.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_bits() {
        let b = Bits::parse("W2A4").unwrap();
        assert_eq!((b.w, b.a), (2, 4));
        assert_eq!(Bits::parse("w32a2").unwrap().name(), "W32A2");
        assert!(Bits::parse("4A4").is_err());
        assert!(Bits::parse("WxAy").is_err());
    }

    #[test]
    fn parse_method() {
        assert_eq!(Method::parse("AQuant").unwrap(), Method::AQuant);
        assert_eq!(Method::parse("rounding").unwrap(), Method::Nearest);
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn first_last_kept_8bit() {
        let b = Bits::parse("W2A2").unwrap();
        let mid = layer_bits(b, false, false, false);
        assert_eq!((mid.qmin_a, mid.qmax_a), (0.0, 3.0));
        assert_eq!((mid.qmin_w, mid.qmax_w), (-2.0, 1.0));
        let first = layer_bits(b, true, false, true);
        assert_eq!((first.qmin_a, first.qmax_a), (-128.0, 127.0));
        assert_eq!(first.w_init_bits, 8);
        let last = layer_bits(b, false, true, false);
        assert_eq!((last.qmin_w, last.qmax_w), (-128.0, 127.0));
    }

    #[test]
    fn method_traits() {
        assert!(Method::AQuant.uses_border());
        assert!(!Method::QDrop.uses_border());
        assert!(Method::AdaRound.layer_wise());
        assert_eq!(Method::QDrop.drop_prob(), 0.5);
        assert_eq!(Method::Brecq.drop_prob(), 0.0);
        assert!(!Method::Nearest.calibrates());
        assert_eq!(Method::all().len(), 7);
    }

    #[test]
    fn serve_config_from_args() {
        use crate::util::cli::Args;
        let a = |s: &[&str]| Args::parse(s.iter().map(|x| x.to_string())).unwrap();

        let cfg = ServeConfig::from_args(&a(&["serve"])).unwrap();
        assert_eq!(cfg, ServeConfig::default());

        let cfg = ServeConfig::from_args(&a(&[
            "serve",
            "--workers",
            "4",
            "--max-batch",
            "32",
            "--batch-wait-us",
            "500",
            "--queue-images",
            "64",
        ]))
        .unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.batch_wait_us, 500);
        assert_eq!(cfg.queue_images, 64);
        assert_eq!(cfg.resolved_workers(), 4);

        let cfg = ServeConfig::from_args(&a(&["serve", "--workers", "auto"])).unwrap();
        assert_eq!(cfg.workers, 0);
        assert!(cfg.resolved_workers() >= 1);
        assert_eq!(cfg.max_conns, None);
        assert_eq!(cfg.max_accepts, None);
        assert_eq!(cfg.conn_timeout_ms, 0);
        assert!(!cfg.poll_fallback);
        assert!(!cfg.fast_kernels, "fast kernels must be opt-in");
        assert_eq!(cfg.stats_addr, None);
        assert_eq!(cfg.admin_addr, None, "control plane must be opt-in");
        assert_eq!(cfg.stats_history, None);
        assert_eq!(cfg.stats_history_every_s, 5);

        // control-plane listener flag
        let cfg =
            ServeConfig::from_args(&a(&["serve", "--admin-addr", "127.0.0.1:9200"])).unwrap();
        assert_eq!(cfg.admin_addr.as_deref(), Some("127.0.0.1:9200"));

        // stats endpoint + history flags
        let cfg = ServeConfig::from_args(&a(&[
            "serve",
            "--stats-addr",
            "127.0.0.1:9100",
            "--stats-history",
            "/tmp/aquant-stats.jsonl",
            "--stats-history-every-s",
            "30",
        ]))
        .unwrap();
        assert_eq!(cfg.stats_addr.as_deref(), Some("127.0.0.1:9100"));
        assert_eq!(cfg.stats_history.as_deref(), Some("/tmp/aquant-stats.jsonl"));
        assert_eq!(cfg.stats_history_every_s, 30);
        // interval is bounded away from 0 (busy-loop) and absurd values
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--stats-history-every-s",
            "0"
        ]))
        .is_err());
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--stats-history-every-s",
            "86401"
        ]))
        .is_err());

        let cfg = ServeConfig::from_args(&a(&["serve", "--max-conns", "12"])).unwrap();
        assert_eq!(cfg.max_conns, Some(12));
        assert!(ServeConfig::from_args(&a(&["serve", "--max-conns", "many"])).is_err());
        // 0 concurrent connections is a config error, not a silent DoS
        assert!(ServeConfig::from_args(&a(&["serve", "--max-conns", "0"])).is_err());

        let cfg = ServeConfig::from_args(&a(&[
            "serve",
            "--max-accepts",
            "3",
            "--conn-timeout-ms",
            "250",
            "--io-poll",
            "--fast-kernels",
        ]))
        .unwrap();
        assert_eq!(cfg.max_accepts, Some(3));
        assert_eq!(cfg.conn_timeout_ms, 250);
        assert!(cfg.poll_fallback);
        assert!(cfg.fast_kernels);
        // --max-accepts 0 is the bind-only run used by tests
        let cfg = ServeConfig::from_args(&a(&["serve", "--max-accepts", "0"])).unwrap();
        assert_eq!(cfg.max_accepts, Some(0));
        assert!(ServeConfig::from_args(&a(&["serve", "--max-accepts", "soon"])).is_err());
        // timeout is bounded (1h); 0 = never
        assert!(
            ServeConfig::from_args(&a(&["serve", "--conn-timeout-ms", "3600000"])).is_ok()
        );
        assert!(
            ServeConfig::from_args(&a(&["serve", "--conn-timeout-ms", "3600001"])).is_err()
        );

        assert!(ServeConfig::from_args(&a(&["serve", "--workers", "lots"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--max-batch", "0"])).is_err());
        // straggler deadline is bounded so Instant + wait cannot overflow
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--batch-wait-us",
            "18446744073709551615"
        ]))
        .is_err());
        assert!(
            ServeConfig::from_args(&a(&["serve", "--batch-wait-us", "60000000"])).is_ok()
        );
        assert!(ServeConfig::from_args(&a(&["serve", "--workers", "1000000"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--workers", "1024"])).is_ok());

        // intra-image sharding knob: auto (0) by default, "off" = 1,
        // bounded like --workers
        assert_eq!(ServeConfig::default().intra_split, 0);
        let cfg = ServeConfig::from_args(&a(&["serve", "--intra-split", "4"])).unwrap();
        assert_eq!(cfg.intra_split, 4);
        let cfg = ServeConfig::from_args(&a(&["serve", "--intra-split", "auto"])).unwrap();
        assert_eq!(cfg.intra_split, 0);
        let cfg = ServeConfig::from_args(&a(&["serve", "--intra-split", "off"])).unwrap();
        assert_eq!(cfg.intra_split, 1);
        assert!(ServeConfig::from_args(&a(&["serve", "--intra-split", "some"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--intra-split", "1000000"])).is_err());
        // max-batch is bounded so quantum*weight arithmetic can't overflow
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--max-batch",
            "65537",
            "--queue-images",
            "65537"
        ]))
        .is_err());
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--max-batch",
            "65536",
            "--queue-images",
            "65536"
        ]))
        .is_ok());
        assert!(ServeConfig::from_args(&a(&[
            "serve",
            "--max-batch",
            "128",
            "--queue-images",
            "16"
        ]))
        .is_err());
    }

    #[test]
    fn model_spec_parsing() {
        let m = Some(Method::AQuant);
        let b = Some(Bits { w: 4, a: 4 });

        let s = ModelSpec::parse("synth:tiny", None, None).unwrap();
        assert_eq!(s.name, "tiny");
        assert_eq!(
            s.source,
            ModelSource::Synth {
                kind: "tiny".into(),
                seed: 42
            }
        );

        let s = ModelSpec::parse("b=synth:rand:7", None, None).unwrap();
        assert_eq!(s.name, "b");
        assert_eq!(
            s.source,
            ModelSource::Synth {
                kind: "rand".into(),
                seed: 7
            }
        );

        // manifest model falling back to --method/--bits defaults
        let s = ModelSpec::parse("mobiles", m, b).unwrap();
        assert_eq!(s.name, "mobiles");
        assert_eq!(
            s.source,
            ModelSource::Manifest {
                model: "mobiles".into(),
                method: Method::AQuant,
                bits: Bits { w: 4, a: 4 }
            }
        );

        // fully inline method/bits, with a rename
        let s = ModelSpec::parse("prod=resnet10s:qdrop:W2A2", None, None).unwrap();
        assert_eq!(s.name, "prod");
        assert_eq!(
            s.source,
            ModelSource::Manifest {
                model: "resnet10s".into(),
                method: Method::QDrop,
                bits: Bits { w: 2, a: 2 }
            }
        );

        assert!(ModelSpec::parse("mobiles", None, b).is_err(), "no method");
        assert!(ModelSpec::parse("mobiles", m, None).is_err(), "no bits");
        assert!(ModelSpec::parse("mobiles:qdrop", m, b).is_err(), "method sans bits");
        assert!(ModelSpec::parse("synth:cube", None, None).is_err(), "bad kind");
        assert!(ModelSpec::parse("synth:rand:x", None, None).is_err(), "bad seed");
        assert!(ModelSpec::parse("=synth:tiny", None, None).is_err(), "empty name");
        assert!(ModelSpec::parse("", m, b).is_err());
        assert!(ModelSpec::parse("synth", m, b).is_err(), "reserved");
        assert!(ModelSpec::parse("a:b:c:d", m, b).is_err(), "trailing");
    }

    #[test]
    fn model_spec_policy_tail_parsing() {
        // no tail -> empty overrides (server defaults apply)
        let s = ModelSpec::parse("synth:tiny", None, None).unwrap();
        assert!(s.policy.is_empty());

        // full tail, any order, on a renamed synth spec with a seed
        let s = ModelSpec::parse(
            "hot=synth:bench:7;weight=3;max_batch=32;batch_wait_us=50;queue_images=256;slo_us=5000",
            None,
            None,
        )
        .unwrap();
        assert_eq!(s.name, "hot");
        assert_eq!(
            s.source,
            ModelSource::Synth {
                kind: "bench".into(),
                seed: 7
            }
        );
        assert_eq!(
            s.policy,
            PolicyOverrides {
                max_batch: Some(32),
                batch_wait_us: Some(50),
                queue_images: Some(256),
                weight: Some(3),
                slo_us: Some(5000),
            }
        );
        assert!(!s.policy.is_empty());

        // manifest specs take the same tail
        let s = ModelSpec::parse("prod=resnet10s:qdrop:W2A2;weight=4", None, None).unwrap();
        assert_eq!(s.policy.weight, Some(4));
        assert_eq!(s.policy.max_batch, None);
        assert_eq!(s.policy.slo_us, None);

        // an SLO without a weight override rides on the default weight
        let s = ModelSpec::parse("synth:tiny;slo_us=2000", None, None).unwrap();
        assert_eq!(s.policy.slo_us, Some(2000));
        assert_eq!(s.policy.weight, None);

        // rejections: unknown key, duplicate key, bad number, weight=0,
        // slo_us=0, malformed pair, empty pair
        assert!(ModelSpec::parse("synth:tiny;turbo=1", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;weight=1;weight=2", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;max_batch=lots", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;weight=0", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;slo_us=0", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;slo_us=fast", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;weight", None, None).is_err());
        assert!(ModelSpec::parse("synth:tiny;", None, None).is_err());

        // the tail must not leak into name/source parsing
        let a = ModelSpec::parse("a=synth:tiny;weight=2", None, None).unwrap();
        assert_eq!(a.name, "a");
        let b = ModelSpec::parse("a=synth:tiny", None, None).unwrap();
        assert_eq!(a.source, b.source);
    }

    #[test]
    fn model_spec_list_rejects_duplicates() {
        let specs: Vec<String> = vec!["synth:tiny".into(), "synth:tiny:7".into()];
        assert!(ModelSpec::parse_all(&specs, None, None).is_err());
        let specs: Vec<String> = vec!["a=synth:tiny".into(), "b=synth:tiny:7".into()];
        let parsed = ModelSpec::parse_all(&specs, None, None).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "a");
        assert!(ModelSpec::parse_all(&[], None, None).is_err());
    }

    #[test]
    fn route_spec_parsing() {
        let r = RouteSpec::parse("tiny=127.0.0.1:7001").unwrap();
        assert_eq!(r.name, "tiny");
        assert_eq!(r.addr, "127.0.0.1:7001");
        let r = RouteSpec::parse("bench=gpu-host:9000").unwrap();
        assert_eq!((r.name.as_str(), r.addr.as_str()), ("bench", "gpu-host:9000"));
        // the last colon splits the port, so bracketed IPv6 parses
        let r = RouteSpec::parse("tiny=[::1]:9000").unwrap();
        assert_eq!(r.addr, "[::1]:9000");
        assert!(RouteSpec::parse("tiny").is_err(), "no '='");
        assert!(RouteSpec::parse("=127.0.0.1:7001").is_err(), "empty name");
        assert!(RouteSpec::parse("tiny=").is_err(), "empty addr");
        assert!(RouteSpec::parse("tiny=nohostport").is_err(), "no port");
    }

    #[test]
    fn route_spec_rejects_malformed_addresses() {
        // Structural address validation happens at parse (startup),
        // not at first connect: each of these used to pass the old
        // `contains(':')` check and then fail only when the router
        // dialed the backend.
        assert!(RouteSpec::parse("m=foo:").is_err(), "empty port");
        assert!(RouteSpec::parse("m=:9000").is_err(), "empty host");
        assert!(RouteSpec::parse("m=a:b").is_err(), "non-numeric port");
        assert!(RouteSpec::parse("m=h:0").is_err(), "port 0");
        assert!(RouteSpec::parse("m=h:65536").is_err(), "port > u16::MAX");
        assert!(RouteSpec::parse("m=h:-1").is_err(), "negative port");
        assert!(RouteSpec::parse("m=h: 9000").is_err(), "spacey port");
        // boundary values stay accepted
        assert!(RouteSpec::parse("m=h:1").is_ok());
        assert!(RouteSpec::parse("m=h:65535").is_ok());
    }

    #[test]
    fn route_spec_list_rejects_duplicate_keys() {
        // same key twice — even to different backends — is an error,
        // mirroring the duplicate --model name rule
        let specs: Vec<String> =
            vec!["a=h1:7001".into(), "b=h2:7002".into(), "a=h3:7003".into()];
        let err = RouteSpec::parse_all(&specs).unwrap_err().to_string();
        assert!(err.contains("duplicate route key \"a\""), "{err}");
        let ok = RouteSpec::parse_all(&specs[..2].to_vec()).unwrap();
        assert_eq!(ok.len(), 2);
        // two keys on ONE backend is fine (shared pool, not a dup)
        let specs: Vec<String> = vec!["a=h1:7001".into(), "b=h1:7001".into()];
        assert_eq!(RouteSpec::parse_all(&specs).unwrap().len(), 2);
        assert!(RouteSpec::parse_all(&[]).is_err());
    }

    #[test]
    fn serve_config_router_knobs() {
        use crate::util::cli::Args;
        let a = |s: &[&str]| Args::parse(s.iter().map(|x| x.to_string())).unwrap();
        let d = ServeConfig::default();
        assert_eq!((d.route_pool, d.route_inflight), (2, 32));
        let cfg = ServeConfig::from_args(&a(&[
            "serve",
            "--route-pool",
            "4",
            "--route-inflight",
            "128",
        ]))
        .unwrap();
        assert_eq!((cfg.route_pool, cfg.route_inflight), (4, 128));
        // both bounded away from 0 and absurdity
        assert!(ServeConfig::from_args(&a(&["serve", "--route-pool", "0"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--route-pool", "65"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--route-pool", "64"])).is_ok());
        assert!(ServeConfig::from_args(&a(&["serve", "--route-inflight", "0"])).is_err());
        assert!(ServeConfig::from_args(&a(&["serve", "--route-inflight", "4097"])).is_err());
    }

    #[test]
    fn run_config_tag() {
        let rc = RunConfig::new("resnet10s", Method::AQuant, Bits::parse("W2A2").unwrap());
        assert_eq!(rc.tag(), "resnet10s_aquant_W2A2");
        assert_eq!(rc.calib.beta_start, 16.0);
        let rc2 = RunConfig::new("resnet10s", Method::QDrop, Bits::parse("W2A2").unwrap());
        assert_eq!(rc2.calib.beta_start, 20.0);
    }
}
