//! Activation scale initialization: percentile start + MSE grid refinement.
//!
//! The paper inherits its scale-search from the baselines (AdaRound/QDrop
//! use an MSE-optimal step). The coordinator runs this at calibration time
//! over the FP activations of each layer (gathered via the `fp_*` chain).

use crate::util::rng::Rng;

/// Search a scalar scale minimizing quantization MSE over `values`.
///
/// `qmin/qmax` define the integer range (0..2^M−1 unsigned, symmetric when
/// signed). Grid-searches `grid` candidates from the max-based scale down
/// to 20% of it.
pub fn search_scale(values: &[f32], qmin: f32, qmax: f32, grid: usize) -> f32 {
    assert!(!values.is_empty(), "scale search over empty sample");
    let hi = if qmin < 0.0 {
        values.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    } else {
        values.iter().fold(0.0f32, |m, &v| m.max(v))
    };
    let hi = hi.max(1e-8);
    let denom = if qmin < 0.0 { -qmin } else { qmax };
    let s0 = hi / denom;
    let mut best_s = s0;
    let mut best_err = f32::INFINITY;
    for i in 0..grid {
        let s = s0 * (1.0 - 0.8 * i as f32 / grid as f32);
        let mut err = 0.0f64;
        for &v in values {
            let q = (v / s - 0.5).ceil().clamp(qmin, qmax);
            let d = (s * q - v) as f64;
            err += d * d;
        }
        if (err as f32) < best_err {
            best_err = err as f32;
            best_s = s;
        }
    }
    best_s
}

/// Subsample up to `cap` values deterministically (scale search over the
/// full calibration activations would be needlessly slow).
pub fn sample_values(values: &[f32], cap: usize, seed: u64) -> Vec<f32> {
    if values.len() <= cap {
        return values.to_vec();
    }
    let mut rng = Rng::new(seed);
    (0..cap).map(|_| values[rng.below(values.len())]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn recovers_known_scale() {
        // values on an exact grid of step 0.1 in [0, 1.5] with 4 bits
        let values: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let s = search_scale(&values, 0.0, 15.0, 80);
        // quantizing with the found scale should be near-lossless
        let mse: f32 = values
            .iter()
            .map(|&v| {
                let q = (v / s - 0.5).ceil().clamp(0.0, 15.0);
                (s * q - v) * (s * q - v)
            })
            .sum::<f32>()
            / values.len() as f32;
        assert!(mse < 1e-6, "mse {mse} with s {s}");
    }

    #[test]
    fn signed_search_uses_absmax() {
        let values = vec![-2.0f32, -1.0, 0.5, 1.9];
        let s = search_scale(&values, -8.0, 7.0, 60);
        assert!(s > 0.0 && s <= 2.0 / 8.0 + 1e-5);
    }

    #[test]
    fn prop_beats_naive_max_scale() {
        prop::check("MSE-searched scale >= max-based scale", 64, |rng| {
            // heavy-tailed sample: mostly small values + one outlier
            let mut values = prop::vec_f32(rng, 256, 0.0, 1.0);
            values.push(rng.range_f32(5.0, 20.0));
            let qmax = 15.0;
            let s_naive = values.iter().cloned().fold(0.0f32, f32::max) / qmax;
            let s_opt = search_scale(&values, 0.0, qmax, 80);
            let mse = |s: f32| {
                values
                    .iter()
                    .map(|&v| {
                        let q = (v / s - 0.5).ceil().clamp(0.0, qmax);
                        (s * q - v) * (s * q - v)
                    })
                    .sum::<f32>()
            };
            assert!(mse(s_opt) <= mse(s_naive) + 1e-6);
        });
    }

    #[test]
    fn sampling_is_deterministic_and_capped() {
        let values: Vec<f32> = (0..10_000).map(|i| i as f32).collect();
        let a = sample_values(&values, 512, 9);
        let b = sample_values(&values, 512, 9);
        assert_eq!(a, b);
        assert_eq!(a.len(), 512);
        let small = sample_values(&values[..10], 512, 9);
        assert_eq!(small.len(), 10);
    }
}
