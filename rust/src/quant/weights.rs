//! Weight hard-quantization from the learned AdaRound state — mirrors
//! `python/compile/quant.py::weight_quant_hard`:
//! ``w_q = s·clip(floor(w/s) + [h(V) ≥ 0.5], qmin, qmax)`` with
//! ``h(V) = clip(sigmoid(V)·1.2 − 0.1, 0, 1)``.

/// AdaRound's rectified sigmoid.
#[inline]
pub fn rect_sigmoid(v: f32) -> f32 {
    let s = 1.0 / (1.0 + (-v).exp());
    (s * 1.2 - 0.1).clamp(0.0, 1.0)
}

/// Hard-quantize a weight matrix (oc rows) with per-row scales and the
/// learned rounding logits V.
pub fn harden(
    w: &[f32],
    s_w: &[f32],
    v: &[f32],
    oc: usize,
    qmin: f32,
    qmax: f32,
) -> Vec<f32> {
    let cols = w.len() / oc;
    let mut out = vec![0.0f32; w.len()];
    for r in 0..oc {
        let s = s_w[r];
        for c in 0..cols {
            let i = r * cols + c;
            let up = if rect_sigmoid(v[i]) >= 0.5 { 1.0 } else { 0.0 };
            out[i] = s * ((w[i] / s).floor() + up).clamp(qmin, qmax);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn rect_sigmoid_range() {
        assert_eq!(rect_sigmoid(-20.0), 0.0);
        assert_eq!(rect_sigmoid(20.0), 1.0);
        assert!((rect_sigmoid(0.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn harden_at_v_init_is_nearest() {
        // V init makes h(V) equal the fractional part; hardening then
        // rounds up exactly when frac >= 0.5 (nearest with half-up).
        prop::check_default("harden(V_init) == nearest", |rng| {
            let oc = 2;
            let w = prop::vec_f32(rng, oc * 3, -2.0, 2.0);
            let s = vec![rng.range_f32(0.05, 0.5), rng.range_f32(0.05, 0.5)];
            // python v_init: rect_sigmoid_inv(frac)
            let v: Vec<f32> = w
                .iter()
                .enumerate()
                .map(|(i, &wi)| {
                    let sc = s[i / 3];
                    let frac = (wi / sc - (wi / sc).floor()).clamp(1e-4, 1.0 - 1e-4);
                    let p = (frac + 0.1) / 1.2;
                    (p / (1.0 - p)).ln()
                })
                .collect();
            let q = harden(&w, &s, &v, oc, -128.0, 127.0);
            for (i, (&qi, &wi)) in q.iter().zip(&w).enumerate() {
                let sc = s[i / 3];
                let frac = wi / sc - (wi / sc).floor();
                // skip razor-edge cases where clamp in v_init flips the call
                if (frac - 0.5).abs() < 1e-3 {
                    continue;
                }
                let expect = sc * ((wi / sc).floor() + if frac >= 0.5 { 1.0 } else { 0.0 });
                assert!((qi - expect).abs() < 1e-5, "w={wi} s={sc} q={qi} expect={expect}");
            }
        });
    }
}
