//! A small dense f32 tensor (row-major) — the host-side currency of the
//! integer inference engine and the coordinator's state store.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elems, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape in place (element count must match).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {shape:?}", self.shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Shape as i64 dims (for PJRT literals).
    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }

    /// Elementwise max abs.
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Mean squared error against another tensor.
    pub fn mse(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let n = self.data.len().max(1);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / n as f32
    }

    /// Batched view: shape [N, ...rest]; returns (rest_elems, slice of item i).
    pub fn item(&self, i: usize) -> &[f32] {
        let per: usize = self.shape[1..].iter().product();
        &self.data[i * per..(i + 1) * per]
    }

    /// ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Elementwise add in place.
    pub fn add_inplace(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.len(), 6);
        let t = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(t.shape, vec![3, 2]);
        assert!(t.clone().reshape(vec![4]).is_err());
        assert!(Tensor::new(vec![2, 2], vec![0.0; 3]).is_err());
    }

    #[test]
    fn math_helpers() {
        let mut t = Tensor::new(vec![4], vec![-1.0, 2.0, -3.0, 0.5]).unwrap();
        assert_eq!(t.abs_max(), 3.0);
        let z = Tensor::zeros(vec![4]);
        assert!((t.mse(&z) - (1.0 + 4.0 + 9.0 + 0.25) / 4.0).abs() < 1e-6);
        t.relu_inplace();
        assert_eq!(t.data, vec![0.0, 2.0, 0.0, 0.5]);
        let mut a = Tensor::full(vec![2], 1.0);
        a.add_inplace(&Tensor::full(vec![2], 2.0));
        assert_eq!(a.data, vec![3.0, 3.0]);
    }

    #[test]
    fn item_slicing() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.item(1), &[3.0, 4.0, 5.0]);
    }
}
