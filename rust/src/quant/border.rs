//! The paper's adaptive rounding border (Eq. 8 + Eq. 9), mirroring
//! `python/compile/quant.py` / the Pallas kernel bit-for-bit:
//!
//!   xs = x / s
//!   u  = b2·xs² + b1·xs + b0
//!   Bᴱ = 0.5 + (sigmoid(2.5·u) − 0.5)        (bounded, Appendix B)
//!   Bᴵ = segment mean of α·Bᴱ over each input channel's k² taps (fusion)
//!   x̂  = s·clip(⌈xs − B⌉, qmin, qmax)
//!
//! The per-column hot loops live in `nn/kernels.rs` (runtime-dispatched
//! AVX2/NEON with a bit-identical scalar reference); this module owns
//! the parameter layout, fusion, and the exact-sigmoid reference.

use anyhow::{ensure, Result};

use crate::nn::kernels;

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Fast `sigmoid(2.5u) − 0.5 = 0.5·tanh(1.25u)` for the inference hot
/// path (clamped rational tanh, max abs error vs the exact offset
/// < 2e-3 — a rounding decision flips only when an activation sits
/// within that distance of the border; the accuracy effect is below
/// eval noise, see EXPERIMENTS.md §Perf). Shared with the SIMD kernels
/// so `be` agrees with the column paths on every backend.
#[inline(always)]
fn fast_offset(u: f32) -> f32 {
    kernels::fast_offset(u)
}

/// Border parameters for one layer: rows = i_c·k² im2col rows, columns
/// [b0, b1, b2, alpha] (matching the `(R, 4)` state tensor).
#[derive(Debug, Clone)]
pub struct BorderFn {
    /// (R, 4) row-major (as shipped in the `state:*.bp` tensors).
    pub params: Vec<f32>,
    /// Structure-of-arrays copies for the vectorizable hot loop.
    b0: Vec<f32>,
    b1: Vec<f32>,
    b2: Vec<f32>,
    alpha: Vec<f32>,
    pub rows: usize,
    /// Segment length for fusion (k²).
    pub k2: usize,
    pub border_en: bool,
    pub fuse_en: bool,
    pub b2_en: bool,
}

impl BorderFn {
    /// Identity border (nearest rounding): all params zero. Built
    /// directly (not via `from_params`) so it stays infallible; the
    /// shape invariants hold trivially for an all-zero table.
    pub fn nearest(rows: usize, k2: usize) -> Self {
        BorderFn {
            params: vec![0.0; rows * 4],
            b0: vec![0.0; rows],
            b1: vec![0.0; rows],
            b2: vec![0.0; rows],
            alpha: vec![0.0; rows],
            rows,
            k2: k2.max(1),
            border_en: false,
            fuse_en: false,
            b2_en: false,
        }
    }

    /// From a learned (R,4) table. Rejects malformed tables instead of
    /// silently truncating: a `params` length not divisible by 4 used to
    /// yield unequal-length SoA columns (the tail was dropped), and
    /// `rows % k2 != 0` made the fusion loop skip the tail rows of the
    /// last partial channel segment.
    pub fn from_params(params: Vec<f32>, k2: usize, fuse_en: bool, b2_en: bool) -> Result<Self> {
        ensure!(
            params.len() % 4 == 0,
            "border table length {} is not a multiple of 4 (expected (R,4) row-major)",
            params.len()
        );
        let rows = params.len() / 4;
        ensure!(k2 > 0, "border fusion segment k2 must be >= 1");
        ensure!(
            rows % k2 == 0,
            "border table rows {rows} not divisible by k2={k2} (rows must cover whole channel segments)"
        );
        let col = |i: usize| params.iter().skip(i).step_by(4).copied().collect::<Vec<f32>>();
        Ok(BorderFn {
            b0: col(0),
            b1: col(1),
            b2: col(2),
            alpha: col(3),
            params,
            rows,
            k2,
            border_en: true,
            fuse_en,
            b2_en,
        })
    }

    #[inline]
    fn row(&self, r: usize) -> (f32, f32, f32, f32) {
        let p = &self.params[r * 4..r * 4 + 4];
        (p[0], p[1], p[2], p[3])
    }

    /// Element-wise border Bᴱ for one normalized activation. Uses the
    /// fast tanh-rational offset (see `fast_offset`); `be_exact` keeps the
    /// exp-based reference.
    #[inline(always)]
    pub fn be(&self, r: usize, xs: f32) -> f32 {
        if !self.border_en {
            return 0.5;
        }
        let (b0, b1, b2, _) = self.row(r);
        let b2 = if self.b2_en { b2 } else { 0.0 };
        let u = b2 * xs * xs + b1 * xs + b0;
        0.5 + fast_offset(u)
    }

    /// Exact (exp-based) element-wise border, matching the JAX reference
    /// bit-for-bit; used by tests to bound the fast path's deviation.
    pub fn be_exact(&self, r: usize, xs: f32) -> f32 {
        if !self.border_en {
            return 0.5;
        }
        let (b0, b1, b2, _) = self.row(r);
        let b2 = if self.b2_en { b2 } else { 0.0 };
        let u = b2 * xs * xs + b1 * xs + b0;
        0.5 + (sigmoid(2.5 * u) - 0.5)
    }

    /// Compute borders for one im2col column (R normalized activations),
    /// applying fusion when enabled. `out` has length R.
    pub fn borders_column(&self, xs: &[f32], out: &mut [f32]) {
        debug_assert_eq!(xs.len(), self.rows);
        debug_assert_eq!(out.len(), self.rows);
        if !self.border_en {
            out.fill(0.5);
            return;
        }
        if self.b2_en {
            kernels::borders_col_quad(xs, &self.b0, &self.b1, &self.b2, out);
        } else {
            kernels::borders_col_lin(xs, &self.b0, &self.b1, out);
        }
        if self.fuse_en {
            // per-channel weighted mean of α·Bᴱ over k² taps (Eq. 9).
            // Segment means are a short sequential reduction per channel;
            // the construction invariant rows % k2 == 0 guarantees the
            // segments tile all R rows.
            let k2 = self.k2;
            for seg in 0..self.rows / k2 {
                let mut acc = 0.0f32;
                for j in 0..k2 {
                    let r = seg * k2 + j;
                    acc += self.alpha[r] * out[r];
                }
                let fused = acc / k2 as f32;
                out[seg * k2..(seg + 1) * k2].fill(fused);
            }
        }
    }

    /// Quantize-dequantize one im2col column in place. Allocation-free
    /// after the first call (`scratch` is reused); single-pass when no
    /// fusion is involved — this is the engine's per-column hot loop,
    /// dispatched to the active SIMD backend (`nn/kernels.rs`).
    pub fn quant_column(&self, col: &mut [f32], s: f32, qmin: f32, qmax: f32, scratch: &mut Vec<f32>) {
        let inv_s = 1.0 / s;
        if !self.border_en {
            kernels::nearest_col(col, s, inv_s, qmin, qmax);
            return;
        }
        if !self.fuse_en {
            // one fused pass: normalize, border, round, dequantize
            if self.b2_en {
                kernels::quant_col_quad(col, &self.b0, &self.b1, &self.b2, s, inv_s, qmin, qmax);
            } else {
                kernels::quant_col_lin(col, &self.b0, &self.b1, s, inv_s, qmin, qmax);
            }
            return;
        }
        // fusion: need the whole channel segment before rounding.
        // Grow-only: the scratch is shared across layers (and, under
        // multi-model serving, across models) with different R, so slice
        // exactly 2R instead of assuming the buffer length equals 2R.
        if scratch.len() < 2 * self.rows {
            scratch.resize(2 * self.rows, 0.0);
        }
        let (xs, rest) = scratch.split_at_mut(self.rows);
        let borders = &mut rest[..self.rows];
        kernels::scale_col(col, inv_s, xs);
        self.borders_column(xs, borders);
        kernels::round_col(col, xs, borders, s, qmin, qmax);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn zero_params_is_nearest() {
        let b = BorderFn::from_params(vec![0.0; 9 * 4], 9, true, true).unwrap();
        for xs in [-3.0f32, -0.4, 0.0, 0.49, 0.51, 7.3] {
            assert_eq!(b.be(0, xs), 0.5, "xs={xs}");
        }
    }

    #[test]
    fn from_params_rejects_ragged_tables() {
        // length not a multiple of 4: used to silently truncate the SoA
        // columns (rows = len/4 dropped the tail elements)
        assert!(BorderFn::from_params(vec![0.0; 9], 1, false, false).is_err());
        // rows not divisible by k2: the fusion loop used to skip the
        // tail rows of the last partial segment
        assert!(BorderFn::from_params(vec![0.0; 10 * 4], 4, true, false).is_err());
        // k2 = 0 would divide by zero in the fusion mean
        assert!(BorderFn::from_params(vec![0.0; 4 * 4], 0, false, false).is_err());
        // well-formed table still accepted
        assert!(BorderFn::from_params(vec![0.0; 8 * 4], 4, true, true).is_ok());
    }

    #[test]
    fn border_bounded_in_unit_interval() {
        prop::check_default("border in (0,1)", |rng| {
            let rows = 9;
            let params = prop::vec_f32(rng, rows * 4, -3.0, 3.0);
            let b = BorderFn::from_params(params, 9, false, true).unwrap();
            let xs = rng.range_f32(-10.0, 10.0);
            let v = b.be(rng.below(rows), xs);
            assert!((0.0..=1.0).contains(&v), "border {v}");
        });
    }

    #[test]
    fn fusion_shares_border_within_channel() {
        let mut rng = Rng::new(1);
        let rows = 2 * 4; // 2 channels, k2 = 4
        let mut params = prop::vec_f32(&mut rng, rows * 4, -0.5, 0.5);
        // alpha = 1
        for r in 0..rows {
            params[r * 4 + 3] = 1.0;
        }
        let b = BorderFn::from_params(params, 4, true, true).unwrap();
        let xs = prop::vec_f32(&mut rng, rows, -2.0, 2.0);
        let mut out = vec![0.0; rows];
        b.borders_column(&xs, &mut out);
        for seg in 0..2 {
            for j in 1..4 {
                assert_eq!(out[seg * 4], out[seg * 4 + j]);
            }
        }
        // fused value is the mean of the element-wise borders
        let mut out_e = vec![0.0; rows];
        let be = BorderFn {
            fuse_en: false,
            ..b.clone()
        };
        be.borders_column(&xs, &mut out_e);
        let expect: f32 = out_e[0..4].iter().sum::<f32>() / 4.0;
        assert!((out[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn fast_offset_close_to_exact_sigmoid() {
        prop::check_default("fast border within 2e-3 of exact", |rng| {
            let rows = 8;
            let params = prop::vec_f32(rng, rows * 4, -2.0, 2.0);
            let b = BorderFn::from_params(params, 4, false, true).unwrap();
            let r = rng.below(rows);
            let xs = rng.range_f32(-8.0, 8.0);
            let fast = b.be(r, xs);
            let exact = b.be_exact(r, xs);
            assert!(
                (fast - exact).abs() < 2e-3,
                "fast {fast} vs exact {exact} (xs={xs})"
            );
        });
    }

    #[test]
    fn quant_column_fused_path_matches_unfused_math() {
        // the fused single-pass branch must equal the generic two-pass
        // branch when fusion is off in both
        let mut rng = Rng::new(9);
        let rows = 18;
        let params = prop::vec_f32(&mut rng, rows * 4, -1.0, 1.0);
        let b = BorderFn::from_params(params, 9, false, true).unwrap();
        let col0 = prop::vec_f32(&mut rng, rows, -0.5, 3.0);
        let mut fast = col0.clone();
        let mut scratch = Vec::new();
        b.quant_column(&mut fast, 0.2, 0.0, 15.0, &mut scratch);
        // reference: explicit borders_column + round
        let xs: Vec<f32> = col0.iter().map(|v| v * (1.0 / 0.2)).collect();
        let mut borders = vec![0.0; rows];
        b.borders_column(&xs, &mut borders);
        for r in 0..rows {
            let want = 0.2 * (xs[r] - borders[r]).ceil().clamp(0.0, 15.0);
            assert_eq!(fast[r], want, "row {r}");
        }
    }

    #[test]
    fn quant_column_nearest_matches_uniform() {
        let mut rng = Rng::new(2);
        let rows = 12;
        let b = BorderFn::nearest(rows, 4);
        let mut col = prop::vec_f32(&mut rng, rows, -0.5, 3.0);
        let orig = col.clone();
        let mut scratch = Vec::new();
        b.quant_column(&mut col, 0.25, 0.0, 15.0, &mut scratch);
        for (q, x) in col.iter().zip(&orig) {
            assert_eq!(*q, crate::quant::uniform::nearest(*x, 0.25, 0.0, 15.0));
        }
    }

    #[test]
    fn prop_border_rounding_consistent() {
        // Definition 2.1: values with fractional part below B round down.
        prop::check_default("border rounding direction", |rng| {
            let rows = 4;
            let params = prop::vec_f32(rng, rows * 4, -1.0, 1.0);
            let b = BorderFn::from_params(params, 1, false, true).unwrap();
            let r = rng.below(rows);
            let xs = rng.range_f32(0.0, 6.0);
            let border = b.be(r, xs);
            let q = (xs - border).ceil();
            let frac = xs - xs.floor();
            // Note the border moves with xs (it is evaluated at xs), so we
            // only check the local rounding decision.
            if frac < border - 1e-6 {
                assert_eq!(q, xs.floor(), "rounds down below border");
            } else if frac > border + 1e-6 {
                assert_eq!(q, xs.floor() + 1.0, "rounds up above border");
            }
        });
    }
}
