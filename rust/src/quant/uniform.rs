//! Uniform quantization primitives (Definition 2.1's notation):
//! ``x̂ = s · clip(⌈x/s − B⌉, qmin, qmax)`` with B = 0.5 for nearest.

/// Quantize-dequantize one value with a given border.
#[inline]
pub fn quant_dequant(x: f32, s: f32, border: f32, qmin: f32, qmax: f32) -> f32 {
    let q = (x / s - border).ceil().clamp(qmin, qmax);
    s * q
}

/// Nearest rounding (B = 0.5). `ceil(u - 0.5)` rounds exact halves down,
/// matching the JAX pipeline bit-for-bit.
#[inline]
pub fn nearest(x: f32, s: f32, qmin: f32, qmax: f32) -> f32 {
    quant_dequant(x, s, 0.5, qmin, qmax)
}

/// Integer code for a value (used by the A-rounding flip algorithm, which
/// manipulates codes rather than dequantized values).
#[inline]
pub fn code(x: f32, s: f32, border: f32, qmin: f32, qmax: f32) -> f32 {
    (x / s - border).ceil().clamp(qmin, qmax)
}

/// Quantize a slice in place (nearest).
pub fn nearest_slice(xs: &mut [f32], s: f32, qmin: f32, qmax: f32) {
    for x in xs {
        *x = nearest(*x, s, qmin, qmax);
    }
}

/// Signed symmetric weight quantization: round-to-nearest codes.
pub fn quant_weights(w: &[f32], s_per_row: &[f32], rows: usize, qmin: f32, qmax: f32) -> Vec<f32> {
    let cols = w.len() / rows;
    let mut out = vec![0.0; w.len()];
    for r in 0..rows {
        let s = s_per_row[r];
        for c in 0..cols {
            let i = r * cols + c;
            // round() (half away from zero) matches jnp.round for weights
            // up to the half-ulp cases the scale search avoids.
            out[i] = s * (w[i] / s).round().clamp(qmin, qmax);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn nearest_matches_manual() {
        // s = 1: values round to integers in [0, 3]
        assert_eq!(nearest(1.4, 1.0, 0.0, 3.0), 1.0);
        assert_eq!(nearest(1.6, 1.0, 0.0, 3.0), 2.0);
        assert_eq!(nearest(-0.7, 1.0, 0.0, 3.0), 0.0); // clipped
        assert_eq!(nearest(9.0, 1.0, 0.0, 3.0), 3.0); // clipped
    }

    #[test]
    fn border_shifts_rounding() {
        // B = 0.3: fractional parts below 0.3 round down, above round up.
        assert_eq!(quant_dequant(1.2, 1.0, 0.3, 0.0, 7.0), 1.0);
        assert_eq!(quant_dequant(1.4, 1.0, 0.3, 0.0, 7.0), 2.0);
    }

    #[test]
    fn prop_error_bounded_by_scale() {
        prop::check_default("nearest error <= s/2 inside range", |rng| {
            let s = rng.range_f32(0.01, 2.0);
            let qmax = 15.0;
            // stay strictly inside the representable range
            let x = rng.range_f32(0.0, s * qmax);
            let xq = nearest(x, s, 0.0, qmax);
            assert!(
                (xq - x).abs() <= s / 2.0 + 1e-5,
                "x={x} s={s} xq={xq}"
            );
        });
    }

    #[test]
    fn prop_idempotent() {
        prop::check_default("quantization is idempotent", |rng| {
            let s = rng.range_f32(0.01, 2.0);
            let x = rng.range_f32(-1.0, 10.0);
            let q1 = nearest(x, s, 0.0, 15.0);
            let q2 = nearest(q1, s, 0.0, 15.0);
            assert!((q1 - q2).abs() < 1e-5);
        });
    }

    #[test]
    fn prop_monotone() {
        prop::check_default("quantization preserves order", |rng| {
            let s = rng.range_f32(0.01, 2.0);
            let a = rng.range_f32(-2.0, 10.0);
            let b = rng.range_f32(-2.0, 10.0);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(nearest(lo, s, 0.0, 15.0) <= nearest(hi, s, 0.0, 15.0));
        });
    }

    #[test]
    fn weight_quant_rows() {
        let w = vec![0.9, -1.1, 0.2, 0.4];
        let s = vec![1.0, 0.1];
        let q = quant_weights(&w, &s, 2, -2.0, 1.0);
        assert_eq!(q, vec![1.0, -1.0, 0.1, 0.1]); // second row clipped at qmax=1 -> 0.1
    }
}
