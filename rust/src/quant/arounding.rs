//! A-rounding: the SQuant-style activation flip algorithm from the paper's
//! §3 / Appendix A — the *motivation* baseline of Table 1.
//!
//! Given one im2col activation vector (reshaped (i_c, k²)), start from
//! nearest rounding and flip individual elements up/down so that
//!   1. each input channel's rounding-error sum |s_i| ≤ 0.5, then
//!   2. the whole vector's error sum |Σ s_i| ≤ 0.5, flipping at most one
//!      element per channel to preserve the per-channel constraint.
//! Flips prefer elements whose rounding error is closest to ±0.5 (smallest
//! element-wise damage). This costs O(R log R) per vector at inference —
//! exactly the "heavy overhead, impractical to use" scheme the paper
//! replaces with the border function; we implement it to reproduce
//! Table 1.

/// One element's state during flipping.
#[derive(Clone, Copy)]
struct Elem {
    /// quantized code
    q: f32,
    /// rounding error in code units: q − x/s
    err: f32,
}

/// Flip-quantize one im2col column in place (dequantized values written
/// back). `k2` = per-channel segment length; `col.len()` must be a
/// multiple of `k2`.
pub fn around_column(col: &mut [f32], s: f32, qmin: f32, qmax: f32, k2: usize) {
    let rows = col.len();
    debug_assert_eq!(rows % k2, 0);
    let mut elems: Vec<Elem> = col
        .iter()
        .map(|&x| {
            let xs = x / s;
            let q = (xs - 0.5).ceil().clamp(qmin, qmax);
            Elem { q, err: q - xs }
        })
        .collect();

    let n_ch = rows / k2;
    let mut ch_sum = vec![0.0f32; n_ch];

    // Stage 1: per-channel constraint |s_i| <= 0.5.
    for ch in 0..n_ch {
        let seg = ch * k2..(ch + 1) * k2;
        let mut sum: f32 = elems[seg.clone()].iter().map(|e| e.err).sum();
        // flips needed (each changes sum by ∓1)
        while sum > 0.5 {
            if !flip_best(&mut elems, ch * k2, k2, true, qmin, qmax) {
                break;
            }
            sum -= 1.0;
        }
        while sum < -0.5 {
            if !flip_best(&mut elems, ch * k2, k2, false, qmin, qmax) {
                break;
            }
            sum += 1.0;
        }
        ch_sum[ch] = elems[seg].iter().map(|e| e.err).sum();
    }

    // Stage 2: global constraint, at most one flip per channel.
    let mut total: f32 = ch_sum.iter().sum();
    let mut used = vec![false; n_ch];
    while total > 0.5 {
        let Some(ch) = best_channel(&elems, &used, k2, true, qmin, qmax) else {
            break;
        };
        flip_best(&mut elems, ch * k2, k2, true, qmin, qmax);
        used[ch] = true;
        total -= 1.0;
    }
    while total < -0.5 {
        let Some(ch) = best_channel(&elems, &used, k2, false, qmin, qmax) else {
            break;
        };
        flip_best(&mut elems, ch * k2, k2, false, qmin, qmax);
        used[ch] = true;
        total += 1.0;
    }

    for (c, e) in col.iter_mut().zip(&elems) {
        *c = s * e.q;
    }
}

/// Flip the element in `seg` whose post-flip |error| is smallest.
/// `down`: flip code down (err -= 1) else up (err += 1).
/// Returns false if no element can flip (clip bounds).
fn flip_best(elems: &mut [Elem], start: usize, k2: usize, down: bool, qmin: f32, qmax: f32) -> bool {
    let mut best: Option<(usize, f32)> = None;
    for j in start..start + k2 {
        let e = elems[j];
        let (new_q, new_err) = if down {
            (e.q - 1.0, e.err - 1.0)
        } else {
            (e.q + 1.0, e.err + 1.0)
        };
        if new_q < qmin || new_q > qmax {
            continue;
        }
        let cost = new_err.abs();
        if best.map(|(_, c)| cost < c).unwrap_or(true) {
            best = Some((j, cost));
        }
    }
    if let Some((j, _)) = best {
        if down {
            elems[j].q -= 1.0;
            elems[j].err -= 1.0;
        } else {
            elems[j].q += 1.0;
            elems[j].err += 1.0;
        }
        true
    } else {
        false
    }
}

/// Channel (not yet `used`) offering the cheapest flip in the needed
/// direction.
fn best_channel(
    elems: &[Elem],
    used: &[bool],
    k2: usize,
    down: bool,
    qmin: f32,
    qmax: f32,
) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (ch, &u) in used.iter().enumerate() {
        if u {
            continue;
        }
        for j in ch * k2..(ch + 1) * k2 {
            let e = elems[j];
            let (new_q, new_err) = if down {
                (e.q - 1.0, e.err - 1.0)
            } else {
                (e.q + 1.0, e.err + 1.0)
            };
            if new_q < qmin || new_q > qmax {
                continue;
            }
            let cost = new_err.abs();
            if best.map(|(_, c)| cost < c).unwrap_or(true) {
                best = Some((ch, cost));
            }
        }
    }
    best.map(|(ch, _)| ch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn err_sum(col: &[f32], orig: &[f32], s: f32) -> f32 {
        col.iter().zip(orig).map(|(q, x)| q / s - x / s).sum()
    }

    #[test]
    fn error_sum_constrained() {
        prop::check_default("A-rounding bounds the error sum", |rng| {
            let k2 = 4;
            let n_ch = 1 + rng.below(8);
            let rows = n_ch * k2;
            let s = rng.range_f32(0.05, 0.5);
            // strictly interior values so flips are always possible
            let orig = prop::vec_f32(rng, rows, 2.0 * s, 10.0 * s);
            let mut col = orig.clone();
            around_column(&mut col, s, 0.0, 63.0, k2);
            let total = err_sum(&col, &orig, s);
            assert!(total.abs() <= 0.5 + 1e-4, "total err {total}");
            // per-channel sums bounded by 1.5 (stage-2 flips may add 1 to a
            // channel that was already ≤ 0.5)
            for ch in 0..n_ch {
                let e = err_sum(&col[ch * k2..(ch + 1) * k2], &orig[ch * k2..(ch + 1) * k2], s);
                assert!(e.abs() <= 1.5 + 1e-4, "channel err {e}");
            }
        });
    }

    #[test]
    fn element_error_stays_bounded() {
        prop::check_default("A-rounding flips at most once per element scale", |rng| {
            let k2 = 9;
            let rows = 2 * k2;
            let s = 0.25;
            let orig = prop::vec_f32(rng, rows, 1.0, 10.0);
            let mut col = orig.clone();
            around_column(&mut col, s, 0.0, 63.0, k2);
            for (q, x) in col.iter().zip(&orig) {
                // nearest gives |err| <= 0.5; one flip can push it to 1.5
                assert!((q / s - x / s).abs() <= 1.5 + 1e-4);
            }
        });
    }

    #[test]
    fn respects_clip_bounds() {
        let k2 = 2;
        let s = 1.0;
        let mut col = vec![0.2, 0.3, 0.1, 0.4]; // all round to 0 at qmin
        around_column(&mut col, s, 0.0, 3.0, k2);
        for &v in &col {
            assert!((0.0..=3.0).contains(&v));
        }
    }
}
