//! Pure-Rust quantization substrate.
//!
//! Everything here mirrors the JAX-side math in `python/compile/quant.py`
//! exactly (cross-checked in integration tests against the PJRT programs):
//! uniform quantizers, the paper's adaptive rounding border, the
//! A-rounding flip algorithm (Table 1's motivation baseline), and
//! activation scale search.

pub mod arounding;
pub mod border;
pub mod scale_search;
pub mod tensor;
pub mod uniform;
pub mod weights;

pub use border::BorderFn;
pub use tensor::Tensor;
