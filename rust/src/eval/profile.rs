//! Figure 2: the propagated error w.r.t. the noised activation x'.
//!
//! For a pixel of a mid-network layer's input, compare the noised
//! (quantized-prefix) activation x' against the full-precision x over the
//! calibration set, group x' into 16 magnitude clusters, and report the
//! mean error per cluster — reproducing the two-phase trend the paper
//! uses to justify the *quadratic* border (§4.2).

use anyhow::{anyhow, Result};

use crate::coordinator::chain::{ChainRunner, QuantCtx};
use crate::data::Split;
use crate::quant::tensor::Tensor;

/// One cluster row of Figure 2.
#[derive(Debug, Clone)]
pub struct ErrorCluster {
    /// Cluster center (mean |x'| of members).
    pub x_center: f32,
    /// Mean error x' − x.
    pub mean_err: f32,
    /// Member count.
    pub n: usize,
}

/// Profile the propagated error of `layer`'s input.
pub fn propagated_error(
    chain: &ChainRunner<'_>,
    calib: &Split,
    q: &QuantCtx<'_>,
    layer: &str,
    n_clusters: usize,
) -> Result<Vec<ErrorCluster>> {
    let b = chain.batch;
    let n_groups = calib.n / b;
    let mut fp_vals = Vec::new();
    let mut nz_vals = Vec::new();
    for g in 0..n_groups {
        let idx: Vec<usize> = (g * b..(g + 1) * b).collect();
        let x = Tensor::new(vec![b, calib.c, calib.h, calib.w], calib.gather(&idx))?;
        let fp = chain.walk(&x, None)?;
        let nz = chain.walk(&x, Some(q))?;
        let fp_tap = fp.taps.get(layer).ok_or_else(|| anyhow!("no tap {layer}"))?;
        let nz_tap = nz.taps.get(layer).ok_or_else(|| anyhow!("no tap {layer}"))?;
        fp_vals.extend_from_slice(&fp_tap.data);
        nz_vals.extend_from_slice(&nz_tap.data);
    }
    // Cluster by |x'| into equal-count bins (the paper's 16 clusters).
    let mut order: Vec<usize> = (0..nz_vals.len()).collect();
    order.sort_by(|&a, &b| nz_vals[a].abs().partial_cmp(&nz_vals[b].abs()).unwrap());
    let per = order.len() / n_clusters;
    let mut out = Vec::with_capacity(n_clusters);
    for c in 0..n_clusters {
        let members = &order[c * per..if c == n_clusters - 1 { order.len() } else { (c + 1) * per }];
        let mut x_sum = 0.0f64;
        let mut e_sum = 0.0f64;
        for &i in members {
            x_sum += nz_vals[i].abs() as f64;
            e_sum += (nz_vals[i] - fp_vals[i]) as f64;
        }
        out.push(ErrorCluster {
            x_center: (x_sum / members.len() as f64) as f32,
            mean_err: (e_sum / members.len() as f64) as f32,
            n: members.len(),
        });
    }
    Ok(out)
}
