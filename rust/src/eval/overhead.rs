//! §5.3 parameter-overhead accounting: extra border-function parameters
//! (3·i_c·k² per conv for the polynomial — α is absorbable) relative to
//! the model's weight count, and the extra model size under a given
//! weight bit-width with 16-bit border parameters.

use crate::nn::topology::ModelTopo;

/// Overhead of one model.
#[derive(Debug, Clone)]
pub struct OverheadReport {
    pub model: String,
    pub weight_params: usize,
    pub border_params: usize,
    /// border / weights.
    pub param_ratio: f64,
    /// Extra model size with 16-bit borders over `wbits`-bit weights.
    pub size_ratio_w4: f64,
}

/// Compute the report (border = 3 polynomial coefficients per im2col row,
/// shared across the layer's o_c output channels — the paper's 3/o_c
/// argument).
pub fn overhead(topo: &ModelTopo) -> OverheadReport {
    let mut weight_params = 0usize;
    let mut border_params = 0usize;
    for l in topo.all_layers() {
        weight_params += l.weight_elems();
        border_params += 3 * l.rows;
    }
    let param_ratio = border_params as f64 / weight_params as f64;
    // 16-bit borders vs 4-bit weights (paper's "3% of the model size" case)
    let size_ratio_w4 = (border_params as f64 * 16.0) / (weight_params as f64 * 4.0);
    OverheadReport {
        model: topo.name.clone(),
        weight_params,
        border_params,
        param_ratio,
        size_ratio_w4,
    }
}
