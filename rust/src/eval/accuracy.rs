//! Top-1 accuracy over the test split.

use anyhow::Result;

use crate::coordinator::chain::{argmax_rows, ChainRunner, QuantCtx};
use crate::data::Split;
use crate::nn::engine::Engine;
use crate::quant::tensor::Tensor;

/// Accuracy via the full-model PJRT program (FP).
pub fn eval_fp_accuracy(chain: &ChainRunner<'_>, test: &Split) -> Result<f64> {
    eval_impl(chain, test, None, None)
}

/// FP accuracy over at most `limit` test images.
pub fn eval_fp_accuracy_limited(
    chain: &ChainRunner<'_>,
    test: &Split,
    limit: usize,
) -> Result<f64> {
    eval_impl(chain, test, None, Some(limit))
}

/// Accuracy via the full-model PJRT program (hard-quantized with the
/// Pallas border kernel).
pub fn eval_quant_accuracy(chain: &ChainRunner<'_>, test: &Split, q: &QuantCtx) -> Result<f64> {
    eval_impl(chain, test, Some(q), None)
}

/// Quantized accuracy over at most `limit` test images.
pub fn eval_quant_accuracy_limited(
    chain: &ChainRunner<'_>,
    test: &Split,
    q: &QuantCtx,
    limit: usize,
) -> Result<f64> {
    eval_impl(chain, test, Some(q), Some(limit))
}

fn eval_impl(
    chain: &ChainRunner<'_>,
    test: &Split,
    q: Option<&QuantCtx<'_>>,
    limit: Option<usize>,
) -> Result<f64> {
    let b = chain.batch;
    let n = limit.unwrap_or(test.n).min(test.n);
    let n_full = (n / b) * b;
    let mut hits = 0usize;
    for g in 0..n_full / b {
        let idx: Vec<usize> = (g * b..(g + 1) * b).collect();
        let x = Tensor::new(vec![b, test.c, test.h, test.w], test.gather(&idx))?;
        let logits = chain.full(&x, q)?;
        let pred = argmax_rows(&logits);
        for (&i, &p) in idx.iter().zip(pred.iter()) {
            if test.labels[i] as usize == p {
                hits += 1;
            }
        }
    }
    Ok(hits as f64 / n_full as f64)
}

/// Accuracy via the pure-Rust engine (used for Table 1 and parity tests).
pub fn eval_engine_accuracy(engine: &Engine, test: &Split, limit: Option<usize>) -> Result<f64> {
    let n = limit.unwrap_or(test.n).min(test.n);
    let mut hits = 0usize;
    for i in 0..n {
        let logits = engine.forward(test.image(i), None)?;
        // Offline eval wants a loud failure on NaN (a calibration bug),
        // unlike the NaN-tolerant serving argmax.
        anyhow::ensure!(
            !logits.iter().any(|v| v.is_nan()),
            "NaN logits at test image {i} — calibration produced divergent params"
        );
        let pred = crate::nn::engine::argmax(&logits);
        if test.labels[i] as usize == pred {
            hits += 1;
        }
    }
    Ok(hits as f64 / n as f64)
}
