//! Evaluation harness: accuracy over the test split (via the full-model
//! PJRT programs), the Fig. 2 propagated-error profile, and the §5.3
//! parameter-overhead accounting.
//!
//! Accuracy and profiling run PJRT programs (`pjrt` feature); the
//! overhead accounting is pure topology arithmetic and always builds.

#[cfg(feature = "pjrt")]
pub mod accuracy;
pub mod overhead;
#[cfg(feature = "pjrt")]
pub mod profile;

#[cfg(feature = "pjrt")]
pub use accuracy::{
    eval_engine_accuracy, eval_fp_accuracy, eval_fp_accuracy_limited, eval_quant_accuracy,
    eval_quant_accuracy_limited,
};
