//! Smoke test for the AOT chain: load the prototype calibration step
//! (fwd + bwd + Adam, with a Pallas fake-quant kernel inside) lowered by
//! /tmp/proto_gen.py, execute it on the PJRT CPU client, and print results.
//!
//! Usage: smoke_aot [path/to/step.hlo.txt]

use anyhow::Result;

fn main() -> Result<()> {
    let path = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/proto_step.hlo.txt".to_string());
    if !std::path::Path::new(&path).exists() {
        eprintln!(
            "smoke_aot: {path} not found — generate it with `python scripts/proto_gen.py` \
             (see DESIGN.md §6); skipping."
        );
        return Ok(());
    }
    let rt_dir = std::path::Path::new(&path).parent().unwrap().to_path_buf();
    let rt = aquant::runtime::Runtime::new(&rt_dir)?;
    println!("platform={}", rt.platform());
    let exe = rt.compile_file("proto_step", std::path::Path::new(&path), 6)?;

    // Same inputs as proto_gen.py (seed 0 via numpy is replicated there; it
    // dumped the concatenated inputs to /tmp/proto_inputs.npy — but for the
    // smoke we just re-derive the deterministic parts and check the border
    // update magnitude).
    let (n, d, o) = (4usize, 3usize, 2usize);
    let raw = std::fs::read("/tmp/proto_inputs.npy")?;
    // .npy v1 header: 128-byte aligned; find data offset
    let hlen = u16::from_le_bytes([raw[8], raw[9]]) as usize;
    let data = &raw[10 + hlen..];
    let f: Vec<f32> = data
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut off = 0usize;
    let mut take = |k: usize| {
        let s = f[off..off + k].to_vec();
        off += k;
        s
    };
    let w = take(d * o);
    let b = take(n * o);
    let m = take(n * o);
    let v = take(n * o);
    let t = take(1);
    let x = take(n * d);
    let y = take(n * o);
    let lr = take(1);

    use aquant::runtime::literal_f32 as lf;
    let args = vec![
        lf(&w, &[d as i64, o as i64])?,
        lf(&b, &[n as i64, o as i64])?,
        lf(&m, &[n as i64, o as i64])?,
        lf(&v, &[n as i64, o as i64])?,
        xla::Literal::scalar(t[0]),
        lf(&x, &[n as i64, d as i64])?,
        lf(&y, &[n as i64, o as i64])?,
        xla::Literal::scalar(lr[0]),
    ];
    let outs = exe.run(&args)?;
    println!("n_results={}", outs.len());
    let w1 = outs[0].to_vec::<f32>()?;
    let b1 = outs[1].to_vec::<f32>()?;
    let loss = outs[5].to_vec::<f32>()?;
    println!("loss={} b1[0]={} w1[0]={}", loss[0], b1[0], w1[0]);
    // values printed by proto_gen.py:
    assert!((loss[0] - 1.7301981449127197).abs() < 1e-5, "loss mismatch");
    assert!((b1[0] - 0.5099999308586121).abs() < 1e-6, "border mismatch");
    assert!((w1[0] - 1.7580479383468628).abs() < 1e-5, "weight mismatch");
    println!("smoke_aot OK");
    Ok(())
}
