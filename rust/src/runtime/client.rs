//! Thin, safe wrapper around the `xla` crate's PJRT CPU client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::manifest::Manifest;

/// A compiled HLO program, ready to execute.
///
/// All programs are lowered with `return_tuple=True`, so execution always
/// yields a flat `Vec<xla::Literal>` of the tuple elements.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Number of results the lowered tuple carries (from the manifest; 0 if
    /// loaded outside a manifest, in which case we trust `decompose_tuple`).
    n_results: usize,
}

impl Executable {
    /// Program name (manifest key or file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals; returns the decomposed result tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let parts = lit.to_tuple()?;
        if self.n_results != 0 && parts.len() != self.n_results {
            return Err(anyhow!(
                "{}: manifest promises {} results, got {}",
                self.name,
                self.n_results,
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Execute over device buffers, keeping the (tuple) result on device.
    ///
    /// This is the calibration hot path: optimizer / quant state never
    /// round-trips the host between steps.
    pub fn run_b(&self, args: &[xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let mut outs = self
            .exe
            .execute_b::<xla::PjRtBuffer>(args)
            .with_context(|| format!("executing {} (buffers)", self.name))?;
        Ok(outs.remove(0).remove(0))
    }
}

/// PJRT CPU runtime: artifact registry + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    manifest: Option<Manifest>,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU runtime rooted at an artifacts directory. Loads
    /// `manifest.json` when present.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest_path = artifacts_dir.join("manifest.json");
        let manifest = if manifest_path.exists() {
            Some(Manifest::load(&manifest_path)?)
        } else {
            None
        };
        Ok(Self {
            client,
            artifacts_dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The artifacts directory this runtime serves from.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// The manifest, if `manifest.json` was present.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) a program by manifest name, memoized.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let (path, n_results) = match &self.manifest {
            Some(m) => {
                let spec = m
                    .program(name)
                    .ok_or_else(|| anyhow!("program {name:?} not in manifest"))?;
                (self.artifacts_dir.join(&spec.path), spec.results.len())
            }
            None => (self.artifacts_dir.join(format!("{name}.hlo.txt")), 0),
        };
        let exe = self.compile_file(name, &path, n_results)?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile an HLO-text file directly (no manifest).
    pub fn compile_file(&self, name: &str, path: &Path, n_results: usize) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable {
            name: name.to_string(),
            exe,
            n_results,
        })
    }

    /// Move a host literal onto the device (for `Executable::run_b`).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("host->device: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {dims:?} wants {n} elems, got {}", data.len()));
    }
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("shape {dims:?} wants {n} elems, got {}", data.len()));
    }
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}
