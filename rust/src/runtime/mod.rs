//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. See `python/compile/aot.py`.
//!
//! The executor (`client`) wraps the environment-provided `xla` crate
//! and is gated behind the `pjrt` cargo feature so the pure-Rust world
//! (quantization substrate, inference engine, serving runtime) builds
//! and tests without it. The manifest parser is dependency-free and
//! always available — topology/weight loading and synthetic serving
//! never need PJRT.

#[cfg(feature = "pjrt")]
mod client;
mod manifest;

#[cfg(feature = "pjrt")]
pub use client::{literal_f32, literal_i32, Executable, Runtime};
pub use manifest::{Manifest, ProgramSpec, TensorSpec};
