//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the crate's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly. See `python/compile/aot.py`.

mod client;
mod manifest;

pub use client::{literal_f32, literal_i32, Executable, Runtime};
pub use manifest::{Manifest, ProgramSpec, TensorSpec};
