//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! (producer) and the Rust runtime (consumer). Parsed with the in-tree
//! JSON module.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one program argument or result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Argument name as traced in python. Names are namespaced by role:
    /// `w:`, `state:`, `adam:`, `batch:`, `hyper:` (see aot.py).
    pub name: String,
    /// Dimensions; empty = scalar.
    pub shape: Vec<i64>,
    /// "f32" | "i32" | "u32".
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }

    /// Role prefix of the name (`w`, `state`, `adam`, `batch`, `hyper`).
    pub fn role(&self) -> &str {
        self.name.split(':').next().unwrap_or("")
    }

    /// Name with the role prefix stripped.
    pub fn local_name(&self) -> &str {
        self.name.split_once(':').map(|(_, n)| n).unwrap_or(&self.name)
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow!("name not a string"))?
                .to_string(),
            shape: j.req("shape")?.as_i64_vec()?,
            dtype: j
                .req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow!("dtype not a string"))?
                .to_string(),
        })
    }
}

/// One lowered HLO program.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    /// Path of the HLO text file, relative to the artifacts dir.
    pub path: String,
    /// Positional argument specs, in trace order.
    pub args: Vec<TensorSpec>,
    /// Result tuple element specs, in order.
    pub results: Vec<TensorSpec>,
}

impl ProgramSpec {
    /// Indices of args whose role matches.
    pub fn arg_indices(&self, role: &str) -> Vec<usize> {
        self.args
            .iter()
            .enumerate()
            .filter(|(_, a)| a.role() == role)
            .map(|(i, _)| i)
            .collect()
    }

    /// Index of the arg with this exact name.
    pub fn arg_index(&self, name: &str) -> Result<usize> {
        self.args
            .iter()
            .position(|a| a.name == name)
            .ok_or_else(|| anyhow!("no arg named {name:?}"))
    }
}

/// The manifest: program registry + free-form metadata sections.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Producer version string (jax version etc), for diagnostics.
    pub producer: String,
    /// name -> program
    pub programs: BTreeMap<String, ProgramSpec>,
    /// Free-form sections: model topologies, dataset info, weight files.
    pub meta: Json,
}

impl Manifest {
    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading manifest {path:?}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing manifest {path:?}"))?;
        Self::from_json(&j)
    }

    /// Build from parsed JSON.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut programs = BTreeMap::new();
        let progs = j
            .req("programs")?
            .as_obj()
            .ok_or_else(|| anyhow!("programs not an object"))?;
        for (name, p) in progs {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                p.req(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            programs.insert(
                name.clone(),
                ProgramSpec {
                    path: p
                        .req("path")?
                        .as_str()
                        .ok_or_else(|| anyhow!("path not a string"))?
                        .to_string(),
                    args: parse_specs("args").with_context(|| format!("program {name}"))?,
                    results: parse_specs("results").with_context(|| format!("program {name}"))?,
                },
            );
        }
        Ok(Manifest {
            producer: j
                .get("producer")
                .and_then(|p| p.as_str())
                .unwrap_or("")
                .to_string(),
            programs,
            meta: j.get("meta").cloned().unwrap_or(Json::Null),
        })
    }

    /// Look up a program by name.
    pub fn program(&self, name: &str) -> Option<&ProgramSpec> {
        self.programs.get(name)
    }

    /// Program lookup that errors with the name.
    pub fn req_program(&self, name: &str) -> Result<&ProgramSpec> {
        self.program(name)
            .ok_or_else(|| anyhow!("program {name:?} not in manifest"))
    }

    /// All program names, sorted.
    pub fn program_names(&self) -> Vec<&str> {
        self.programs.keys().map(|s| s.as_str()).collect()
    }

    /// A meta section (e.g. "models", "data", "weights").
    pub fn meta_section(&self, key: &str) -> Result<&Json> {
        self.meta
            .get(key)
            .ok_or_else(|| anyhow!("manifest meta section {key:?} missing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "producer": "jax 0.x",
      "programs": {
        "step_resnet10s_B0": {
          "path": "step_resnet10s_B0.hlo.txt",
          "args": [
            {"name": "w:conv0", "shape": [16, 27], "dtype": "f32"},
            {"name": "state:b0", "shape": [27], "dtype": "f32"},
            {"name": "hyper:lr", "shape": [], "dtype": "f32"}
          ],
          "results": [{"name": "loss", "shape": [], "dtype": "f32"}]
        }
      },
      "meta": {"data": {"n_classes": 16}}
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        let p = m.req_program("step_resnet10s_B0").unwrap();
        assert_eq!(p.args.len(), 3);
        assert_eq!(p.args[0].elems(), 16 * 27);
        assert_eq!(p.args[0].role(), "w");
        assert_eq!(p.args[0].local_name(), "conv0");
        assert_eq!(p.arg_indices("state"), vec![1]);
        assert_eq!(p.arg_index("hyper:lr").unwrap(), 2);
        assert_eq!(
            m.meta_section("data").unwrap().get("n_classes").unwrap().as_i64(),
            Some(16)
        );
        assert!(m.program("nope").is_none());
    }
}
