//! Calibration schedules (Appendix B/C):
//!   * rounding schedule α_round — 0 for the first `warmup_frac` of the
//!     iterations, then a linear ramp to 1 (stabilizes border-induced
//!     rounding flips);
//!   * β anneal for the AdaRound regularizer — `beta_start` → `beta_end`
//!     (linear in iteration, after the warmup);
//!   * learning rates — constant (matching the baselines' setup).

use crate::config::CalibConfig;

/// Schedule evaluator over a block's finetuning iterations.
#[derive(Debug, Clone)]
pub struct Schedule {
    cfg: CalibConfig,
}

impl Schedule {
    pub fn new(cfg: &CalibConfig) -> Self {
        Schedule { cfg: cfg.clone() }
    }

    /// Progress in [0, 1].
    fn frac(&self, iter: u32) -> f32 {
        if self.cfg.iters <= 1 {
            return 1.0;
        }
        iter as f32 / (self.cfg.iters - 1) as f32
    }

    /// Rounding schedule α_round(iter).
    pub fn alpha_round(&self, iter: u32) -> f32 {
        let f = self.frac(iter);
        let w = self.cfg.warmup_frac;
        if f < w {
            0.0
        } else if w >= 1.0 {
            1.0
        } else {
            ((f - w) / (1.0 - w)).min(1.0)
        }
    }

    /// β anneal (AdaRound): high → low so h(V) converges to {0, 1}.
    pub fn beta(&self, iter: u32) -> f32 {
        let f = self.frac(iter);
        self.cfg.beta_start + (self.cfg.beta_end - self.cfg.beta_start) * f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(iters: u32) -> CalibConfig {
        CalibConfig {
            iters,
            ..CalibConfig::default()
        }
    }

    #[test]
    fn alpha_ramps_zero_to_one() {
        let s = Schedule::new(&cfg(100));
        assert_eq!(s.alpha_round(0), 0.0);
        assert_eq!(s.alpha_round(10), 0.0); // inside 20% warmup
        assert!(s.alpha_round(50) > 0.0 && s.alpha_round(50) < 1.0);
        assert!((s.alpha_round(99) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn alpha_monotone() {
        let s = Schedule::new(&cfg(137));
        let mut last = -1.0;
        for i in 0..137 {
            let a = s.alpha_round(i);
            assert!(a >= last);
            last = a;
        }
    }

    #[test]
    fn beta_anneals_down() {
        let s = Schedule::new(&cfg(100));
        assert_eq!(s.beta(0), 20.0);
        assert!((s.beta(99) - 2.0).abs() < 1e-5);
        assert!(s.beta(50) < s.beta(10));
    }

    #[test]
    fn degenerate_single_iter() {
        let s = Schedule::new(&cfg(1));
        assert_eq!(s.alpha_round(0), 1.0);
        assert_eq!(s.beta(0), 2.0);
    }
}
