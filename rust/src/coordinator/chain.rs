//! Forward-chain runner: drives the per-layer PJRT programs (`fp_*`, `q_*`)
//! with the block wiring (relu, residual adds, downsample) done on the
//! host. Produces per-layer input taps and per-block outputs — the
//! calibration inputs/targets of Algorithm 1 — and runs the full-model
//! programs for evaluation.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::state::{bits_row_for, Knobs, StateStore};
use crate::config::Bits;
use crate::nn::engine::LayerWeights;
use crate::nn::topology::{LayerTopo, ModelTopo};
use crate::quant::tensor::Tensor;
use crate::runtime::{literal_f32, Runtime};

/// Tensor -> literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    literal_f32(&t.data, &t.dims_i64())
}

/// literal -> Tensor (shape supplied by caller; PJRT literals know their
/// shape but the manifest is the contract we trust).
pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Tensor> {
    let data = lit.to_vec::<f32>()?;
    Tensor::new(shape, data)
}

/// Quantization context for a chain walk.
pub struct QuantCtx<'a> {
    pub state: &'a StateStore,
    pub bits: Bits,
    pub knobs: Knobs,
}

/// Output of a chain walk.
#[derive(Debug)]
pub struct WalkRecord {
    /// Input feature map of every layer (downsample layers see the block
    /// input), shape (B, C, H, W).
    pub taps: HashMap<String, Tensor>,
    /// Output of every block (post-residual, post-relu).
    pub block_out: HashMap<String, Tensor>,
    /// Final model output (logits), shape (B, n_classes).
    pub logits: Tensor,
}

/// Chain runner bound to one model.
pub struct ChainRunner<'a> {
    pub rt: &'a Runtime,
    pub topo: &'a ModelTopo,
    weights: &'a HashMap<String, LayerWeights>,
    /// Static batch size the programs were lowered with.
    pub batch: usize,
}

impl<'a> ChainRunner<'a> {
    pub fn new(
        rt: &'a Runtime,
        topo: &'a ModelTopo,
        weights: &'a HashMap<String, LayerWeights>,
    ) -> Result<Self> {
        let batch = rt
            .manifest()
            .ok_or_else(|| anyhow!("runtime has no manifest"))?
            .meta_section("calib_batch")?
            .as_usize()
            .ok_or_else(|| anyhow!("calib_batch"))?;
        Ok(ChainRunner {
            rt,
            topo,
            weights,
            batch,
        })
    }

    /// The host-side folded FP weights this chain runs with.
    pub fn weights(&self) -> &HashMap<String, LayerWeights> {
        self.weights
    }

    fn weight_args(&self, l: &LayerTopo) -> Result<Vec<xla::Literal>> {
        let lw = self
            .weights
            .get(&l.name)
            .ok_or_else(|| anyhow!("missing weights {}", l.name))?;
        Ok(vec![
            literal_f32(&lw.w, &[l.oc as i64, l.rows_per_group() as i64])?,
            literal_f32(&lw.b, &[l.oc as i64])?,
        ])
    }

    fn state_args(&self, l: &LayerTopo, st: &StateStore) -> Result<Vec<xla::Literal>> {
        // Order must match ptq.layer_state_shapes: V, s_w, s_a, bp.
        Ok(vec![
            to_literal(st.get(&format!("state:{}.V", l.name))?)?,
            to_literal(st.get(&format!("state:{}.s_w", l.name))?)?,
            to_literal(st.get(&format!("state:{}.s_a", l.name))?)?,
            to_literal(st.get(&format!("state:{}.bp", l.name))?)?,
        ])
    }

    /// One FP layer forward (no relu).
    pub fn fp_layer(&self, l: &LayerTopo, x: &Tensor) -> Result<Tensor> {
        let exe = self.rt.load(&format!("fp_{}_{}", self.topo.name, l.name))?;
        let mut args = self.weight_args(l)?;
        args.push(to_literal(x)?);
        let out = exe.run(&args)?;
        let shape = self.layer_out_shape(l);
        from_literal(&out[0], shape)
    }

    /// One quantized layer forward (hard quant, Pallas border kernel).
    pub fn q_layer(&self, l: &LayerTopo, x: &Tensor, q: &QuantCtx) -> Result<Tensor> {
        let exe = self.rt.load(&format!("q_{}_{}", self.topo.name, l.name))?;
        let mut args = self.weight_args(l)?;
        args.extend(self.state_args(l, q.state)?);
        let row = bits_row_for(self.topo, q.bits, &l.name);
        args.push(literal_f32(&row.as_row(), &[1, 4])?);
        args.push(literal_f32(&q.knobs.to_vec(), &[12])?);
        args.push(to_literal(x)?);
        let out = exe.run(&args)?;
        let shape = self.layer_out_shape(l);
        from_literal(&out[0], shape)
    }

    fn layer_out_shape(&self, l: &LayerTopo) -> Vec<usize> {
        if l.kind == "fc" {
            vec![self.batch, l.oc]
        } else {
            vec![self.batch, l.out_chw.0, l.out_chw.1, l.out_chw.2]
        }
    }

    /// Walk the whole model, batched (x: (B, C, H, W)); `quant` = None for
    /// the FP chain. Records layer-input taps and block outputs.
    pub fn walk(&self, x: &Tensor, quant: Option<&QuantCtx>) -> Result<WalkRecord> {
        self.walk_until(x, quant, None)
    }

    /// Walk, stopping as soon as the tap for `stop_at` has been recorded
    /// (the calibration loop only needs a unit's *input*, so the suffix of
    /// the model need not be executed).
    pub fn walk_until(
        &self,
        x: &Tensor,
        quant: Option<&QuantCtx>,
        stop_at: Option<&str>,
    ) -> Result<WalkRecord> {
        let mut rec = WalkRecord {
            taps: HashMap::new(),
            block_out: HashMap::new(),
            logits: Tensor::zeros(vec![0]),
        };
        let mut h = x.clone();
        for blk in &self.topo.blocks {
            let block_input = h.clone();
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                rec.taps.insert(l.name.clone(), h.clone());
                if stop_at == Some(l.name.as_str()) {
                    return Ok(rec);
                }
                let mut out = match quant {
                    Some(q) => self.q_layer(l, &h, q)?,
                    None => self.fp_layer(l, &h)?,
                };
                let is_last = i == main.len() - 1;
                if l.relu && !(is_last && blk.residual) {
                    out.relu_inplace();
                }
                h = out;
            }
            if blk.residual {
                let skip = if let Some(ds) = blk.downsample_layer() {
                    rec.taps.insert(ds.name.clone(), block_input.clone());
                    if stop_at == Some(ds.name.as_str()) {
                        return Ok(rec);
                    }
                    match quant {
                        Some(q) => self.q_layer(ds, &block_input, q)?,
                        None => self.fp_layer(ds, &block_input)?,
                    }
                } else {
                    block_input
                };
                h.add_inplace(&skip);
                h.relu_inplace();
            }
            rec.block_out.insert(blk.name.clone(), h.clone());
        }
        rec.logits = h;
        Ok(rec)
    }

    /// Full-model program (fast path): logits for one batch.
    pub fn full(&self, x: &Tensor, quant: Option<&QuantCtx>) -> Result<Tensor> {
        let layers = self.topo.all_layers();
        let (name, mut args) = match quant {
            None => {
                let mut args = Vec::new();
                for l in &layers {
                    args.extend(self.weight_args(l)?);
                }
                (format!("fp_full_{}", self.topo.name), args)
            }
            Some(q) => {
                let mut args = Vec::new();
                for l in &layers {
                    args.extend(self.weight_args(l)?);
                }
                for l in &layers {
                    args.extend(self.state_args(l, q.state)?);
                }
                let mut bits = Vec::with_capacity(layers.len() * 4);
                for l in &layers {
                    bits.extend(bits_row_for(self.topo, q.bits, &l.name).as_row());
                }
                args.push(literal_f32(&bits, &[layers.len() as i64, 4])?);
                args.push(literal_f32(&q.knobs.to_vec(), &[12])?);
                (format!("q_full_{}", self.topo.name), args)
            }
        };
        args.push(to_literal(x)?);
        let exe = self.rt.load(&name)?;
        let out = exe.run(&args)?;
        from_literal(&out[0], vec![self.batch, self.topo.n_classes])
    }
}

/// Argmax per row of a (B, C) tensor.
pub fn argmax_rows(t: &Tensor) -> Vec<usize> {
    let b = t.shape[0];
    let c = t.shape[1];
    (0..b)
        .map(|i| {
            let row = &t.data[i * c..(i + 1) * c];
            // Loud failure for offline paths (the serving argmax is
            // NaN-tolerant by design; a NaN here is a calibration bug).
            assert!(
                !row.iter().any(|v| v.is_nan()),
                "NaN logits in argmax_rows row {i}"
            );
            crate::nn::engine::argmax(row)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 2.0]).unwrap();
        assert_eq!(argmax_rows(&t), vec![1, 0]);
    }
}
