//! The quant-state store: every learned/fixed tensor of a quantization
//! run, keyed by the manifest's namespaced argument names
//! (`state:<layer>.<leaf>`, `adam:...`), plus persistence.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::config::{layer_bits, Bits, BitsRow, Method, RunConfig};
use crate::nn::loader;
use crate::nn::topology::ModelTopo;
use crate::quant::tensor::Tensor;
use crate::runtime::Manifest;
use crate::util::tensor_io;

/// Host-side tensor store for one calibration run.
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    map: BTreeMap<String, Tensor>,
}

impl StateStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow!("state store missing {name:?}"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Initialize the full quant state for a run: AdaRound V + weight
    /// scales from the qinit artifacts (at each layer's effective
    /// bit-width), zero border polynomial with α = 1, and a placeholder
    /// activation scale (filled by scale search before calibration).
    pub fn init_for_run(
        artifacts_dir: &Path,
        manifest: &Manifest,
        topo: &ModelTopo,
        cfg: &RunConfig,
    ) -> Result<StateStore> {
        let mut st = StateStore::new();
        let layers = topo.all_layers();
        for l in &layers {
            let row = bits_row_for(topo, cfg.bits, &l.name);
            let (s_w, v) =
                loader::load_qinit(artifacts_dir, manifest, &topo.name, &l.name, row.w_init_bits)?;
            st.set(
                &format!("state:{}.V", l.name),
                Tensor::new(vec![l.oc, l.rows_per_group()], v)?,
            );
            st.set(
                &format!("state:{}.s_w", l.name),
                Tensor::new(vec![l.oc, 1], s_w)?,
            );
            st.set(&format!("state:{}.s_a", l.name), Tensor::scalar(1.0));
            let mut bp = Tensor::zeros(vec![l.rows, 4]);
            for r in 0..l.rows {
                bp.data[r * 4 + 3] = 1.0; // α init (fusion weights)
            }
            st.set(&format!("state:{}.bp", l.name), bp);
        }
        Ok(st)
    }

    /// Zero the Adam moments for a set of state names (called per block
    /// before its reconstruction, matching fresh-optimizer-per-block).
    pub fn reset_adam(&mut self, state_names: &[String]) {
        for n in state_names {
            if let Some(t) = self.map.get(n) {
                let shape = t.shape.clone();
                let m = Tensor::zeros(shape.clone());
                let v = Tensor::zeros(shape);
                let base = n.strip_prefix("state:").unwrap_or(n);
                self.map.insert(format!("adam:{base}.m"), m);
                self.map.insert(format!("adam:{base}.v"), v);
            }
        }
        self.map.insert("adam:t".into(), Tensor::scalar(0.0));
    }

    /// Persist the `state:` entries to a directory.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut index = Vec::new();
        for (name, t) in &self.map {
            if !name.starts_with("state:") {
                continue;
            }
            let file = format!("{}.bin", name.replace([':', '/'], "_"));
            tensor_io::write_f32(&dir.join(&file), &t.data)?;
            index.push(format!(
                "{name}\t{file}\t{}",
                t.shape
                    .iter()
                    .map(|d| d.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            ));
        }
        std::fs::write(dir.join("index.tsv"), index.join("\n") + "\n")?;
        Ok(())
    }

    /// Load previously saved `state:` entries.
    pub fn load(dir: &Path) -> Result<StateStore> {
        let mut st = StateStore::new();
        let index = std::fs::read_to_string(dir.join("index.tsv"))?;
        for line in index.lines() {
            let mut parts = line.split('\t');
            let name = parts.next().ok_or_else(|| anyhow!("bad index line"))?;
            let file = parts.next().ok_or_else(|| anyhow!("bad index line"))?;
            let shape: Vec<usize> = parts
                .next()
                .ok_or_else(|| anyhow!("bad index line"))?
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            let data = tensor_io::read_f32(&dir.join(file))?;
            st.set(name, Tensor::new(shape, data)?);
        }
        Ok(st)
    }
}

/// The per-layer bits row under the paper's policy (first/last at 8 bits,
/// first layer's activations signed — it sees the raw image).
pub fn bits_row_for(topo: &ModelTopo, bits: Bits, layer: &str) -> BitsRow {
    let is_first = topo.first_layer() == layer;
    let is_last = topo.last_layer() == layer;
    layer_bits(bits, is_first, is_last, is_first)
}

/// Knob vector assembly (must match `python/compile/ptq.py::KNOBS`).
#[derive(Debug, Clone, Copy)]
pub struct Knobs {
    pub lr_v: f32,
    pub lr_s: f32,
    pub lr_b: f32,
    pub alpha_round: f32,
    pub beta: f32,
    pub lam: f32,
    pub wq_en: bool,
    pub aq_en: bool,
    pub border_en: bool,
    pub fuse_en: bool,
    pub b2_en: bool,
}

impl Knobs {
    /// Inference-time knobs for a method × bits cell.
    pub fn inference(method: Method, bits: Bits) -> Knobs {
        Knobs {
            lr_v: 0.0,
            lr_s: 0.0,
            lr_b: 0.0,
            alpha_round: 1.0,
            beta: 2.0,
            lam: 0.0,
            wq_en: bits.w_quantized(),
            aq_en: bits.a_quantized(),
            border_en: method.uses_border(),
            fuse_en: method.uses_border() && method != Method::AQuantNoFusion,
            b2_en: method.uses_border() && method != Method::AQuantLinear,
        }
    }

    pub fn to_vec(self) -> Vec<f32> {
        vec![
            self.lr_v,
            self.lr_s,
            self.lr_b,
            self.alpha_round,
            self.beta,
            self.lam,
            self.wq_en as u8 as f32,
            self.aq_en as u8 as f32,
            self.border_en as u8 as f32,
            self.fuse_en as u8 as f32,
            self.b2_en as u8 as f32,
            0.0,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip_disk() {
        let mut st = StateStore::new();
        st.set("state:l1.V", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        st.set("state:l1.s_a", Tensor::scalar(0.25));
        st.set("adam:t", Tensor::scalar(5.0)); // not persisted
        let dir = std::env::temp_dir().join("aquant_state_test");
        let _ = std::fs::remove_dir_all(&dir);
        st.save(&dir).unwrap();
        let st2 = StateStore::load(&dir).unwrap();
        assert_eq!(st2.get("state:l1.V").unwrap().shape, vec![2, 3]);
        assert_eq!(st2.get("state:l1.s_a").unwrap().data, vec![0.25]);
        assert!(st2.get("adam:t").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reset_adam_creates_moments() {
        let mut st = StateStore::new();
        st.set("state:l1.V", Tensor::zeros(vec![2, 2]));
        st.reset_adam(&["state:l1.V".to_string()]);
        assert_eq!(st.get("adam:l1.V.m").unwrap().shape, vec![2, 2]);
        assert_eq!(st.get("adam:t").unwrap().data, vec![0.0]);
    }

    #[test]
    fn knobs_vector_matches_convention() {
        let k = Knobs::inference(Method::AQuant, Bits { w: 2, a: 2 });
        let v = k.to_vec();
        assert_eq!(v.len(), 12);
        assert_eq!(v[6], 1.0); // wq_en
        assert_eq!(v[7], 1.0); // aq_en
        assert_eq!(v[8], 1.0); // border_en
        let k = Knobs::inference(Method::QDrop, Bits { w: 32, a: 4 });
        let v = k.to_vec();
        assert_eq!(v[6], 0.0); // weights FP
        assert_eq!(v[8], 0.0); // no border
        let k = Knobs::inference(Method::AQuantLinear, Bits { w: 2, a: 2 });
        assert!(!k.b2_en && k.fuse_en);
        let k = Knobs::inference(Method::AQuantNoFusion, Bits { w: 2, a: 2 });
        assert!(k.b2_en && !k.fuse_en);
    }
}
