//! The calibration driver: Algorithm 1 over the model's reconstruction
//! units (blocks for BRECQ/QDrop/AQuant, single layers for AdaRound),
//! entirely in Rust — the JAX step programs are pure state-in/state-out
//! functions selected from the manifest.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use super::chain::{from_literal, to_literal, ChainRunner, QuantCtx};
use super::schedule::Schedule;
use super::state::{bits_row_for, Knobs, StateStore};
use crate::config::RunConfig;
use crate::data::Split;
use crate::nn::topology::{BlockTopo, LayerTopo, ModelTopo};
use crate::quant::scale_search;
use crate::quant::tensor::Tensor;
use crate::runtime::{literal_f32, ProgramSpec, Runtime};
use crate::util::rng::Rng;

/// One reconstruction unit: a block or a single layer.
struct Unit<'t> {
    /// step program name
    program: String,
    /// layers in the unit, python `all_layers()` order
    layers: Vec<&'t LayerTopo>,
    /// name of the layer whose input is the unit input
    input_layer: String,
    /// block name when this is a block unit (targets come from block_out)
    block: Option<String>,
}

/// Progress line emitted per unit.
#[derive(Debug, Clone)]
pub struct UnitReport {
    pub unit: String,
    pub first_loss: f32,
    pub last_loss: f32,
    pub iters: u32,
}

/// The calibrator for one run-config cell.
pub struct Calibrator<'a> {
    pub chain: ChainRunner<'a>,
    pub cfg: RunConfig,
    pub verbose: bool,
}

impl<'a> Calibrator<'a> {
    pub fn new(chain: ChainRunner<'a>, cfg: RunConfig) -> Self {
        Calibrator {
            chain,
            cfg,
            verbose: false,
        }
    }

    fn units(&self) -> Vec<Unit<'_>> {
        let topo: &ModelTopo = self.chain.topo;
        if self.cfg.method.layer_wise() {
            topo.all_layers()
                .into_iter()
                .map(|l| Unit {
                    program: format!("step_{}_L_{}", topo.name, l.name),
                    layers: vec![l],
                    input_layer: l.name.clone(),
                    block: None,
                })
                .collect()
        } else {
            topo.blocks
                .iter()
                .map(|b: &BlockTopo| Unit {
                    program: format!("step_{}_B_{}", topo.name, b.name),
                    layers: b.layers.iter().collect(),
                    input_layer: b.layers[0].name.clone(),
                    block: Some(b.name.clone()),
                })
                .collect()
        }
    }

    /// Concatenate per-group layer taps into one (N, ...) tensor.
    fn concat_groups(groups: &[Tensor]) -> Tensor {
        let mut shape = groups[0].shape.clone();
        shape[0] = groups.iter().map(|g| g.shape[0]).sum();
        let mut data = Vec::with_capacity(shape.iter().product());
        for g in groups {
            data.extend_from_slice(&g.data);
        }
        Tensor::new(shape, data).unwrap()
    }

    /// Gather rows `idx` of a (N, ...) tensor into a (len, ...) tensor.
    fn gather_rows(t: &Tensor, idx: &[usize]) -> Tensor {
        let per: usize = t.shape[1..].iter().product();
        let mut shape = t.shape.clone();
        shape[0] = idx.len();
        let mut data = Vec::with_capacity(idx.len() * per);
        for &i in idx {
            data.extend_from_slice(&t.data[i * per..(i + 1) * per]);
        }
        Tensor::new(shape, data).unwrap()
    }

    /// Run the full calibration; returns (state, per-unit reports).
    pub fn run(&self, calib: &Split) -> Result<(StateStore, Vec<UnitReport>)> {
        let rt: &Runtime = self.chain.rt;
        let topo = self.chain.topo;
        let manifest = rt.manifest().ok_or_else(|| anyhow!("no manifest"))?;
        let b = self.chain.batch;
        if calib.n % b != 0 {
            bail!("calib set size {} not a multiple of program batch {b}", calib.n);
        }
        let n_groups = calib.n / b;

        let mut st = StateStore::init_for_run(
            rt.artifacts_dir(),
            manifest,
            topo,
            &self.cfg,
        )?;

        // ---- FP chain over the calibration set: taps + block outputs ----
        let mut fp_taps: HashMap<String, Vec<Tensor>> = HashMap::new();
        let mut fp_block_out: HashMap<String, Vec<Tensor>> = HashMap::new();
        for g in 0..n_groups {
            let idx: Vec<usize> = (g * b..(g + 1) * b).collect();
            let x = Tensor::new(
                vec![b, calib.c, calib.h, calib.w],
                calib.gather(&idx),
            )?;
            let rec = self.chain.walk(&x, None)?;
            for (k, v) in rec.taps {
                fp_taps.entry(k).or_default().push(v);
            }
            for (k, v) in rec.block_out {
                fp_block_out.entry(k).or_default().push(v);
            }
        }
        let fp_taps: HashMap<String, Tensor> = fp_taps
            .into_iter()
            .map(|(k, v)| (k, Self::concat_groups(&v)))
            .collect();
        let fp_block_out: HashMap<String, Tensor> = fp_block_out
            .into_iter()
            .map(|(k, v)| (k, Self::concat_groups(&v)))
            .collect();

        // ---- Activation scale init (MSE search over FP inputs) ----
        for l in topo.all_layers() {
            let row = bits_row_for(topo, self.cfg.bits, &l.name);
            let tap = fp_taps
                .get(&l.name)
                .ok_or_else(|| anyhow!("no FP tap for {}", l.name))?;
            let sample = scale_search::sample_values(&tap.data, 8192, 0x5CA1E);
            let s = scale_search::search_scale(&sample, row.qmin_a, row.qmax_a, 60);
            st.set(&format!("state:{}.s_a", l.name), Tensor::scalar(s));
        }

        if !self.cfg.method.calibrates() {
            return Ok((st, Vec::new()));
        }

        // ---- Unit-by-unit reconstruction ----
        let sched = Schedule::new(&self.cfg.calib);
        let mut rng = Rng::new(self.cfg.calib.seed);
        let mut reports = Vec::new();
        let infer_knobs = Knobs::inference(self.cfg.method, self.cfg.bits);
        for unit in self.units() {
            // Noised inputs: quantized chain with the *current* state.
            let qctx = QuantCtx {
                state: &st,
                bits: self.cfg.bits,
                knobs: infer_knobs,
            };
            let mut q_tap_groups: Vec<Tensor> = Vec::new();
            for g in 0..n_groups {
                let idx: Vec<usize> = (g * b..(g + 1) * b).collect();
                let x = Tensor::new(
                    vec![b, calib.c, calib.h, calib.w],
                    calib.gather(&idx),
                )?;
                let rec = self.chain.walk_until(&x, Some(&qctx), Some(&unit.input_layer))?;
                q_tap_groups.push(
                    rec.taps
                        .get(&unit.input_layer)
                        .ok_or_else(|| anyhow!("no q tap {}", unit.input_layer))?
                        .clone(),
                );
            }
            let x_in_all = Self::concat_groups(&q_tap_groups);
            let x_fp_all = fp_taps
                .get(&unit.input_layer)
                .ok_or_else(|| anyhow!("no fp tap {}", unit.input_layer))?;

            // Targets: FP unit output.
            let y_fp_all = match &unit.block {
                Some(bname) => fp_block_out
                    .get(bname)
                    .ok_or_else(|| anyhow!("no fp block out {bname}"))?
                    .clone(),
                None => {
                    // layer unit: FP layer forward + its own relu
                    let l = unit.layers[0];
                    let mut groups = Vec::new();
                    for g in 0..n_groups {
                        let idx: Vec<usize> = (g * b..(g + 1) * b).collect();
                        let xg = Self::gather_rows(x_fp_all, &idx);
                        let mut y = self.chain.fp_layer(l, &xg)?;
                        if l.relu {
                            y.relu_inplace();
                        }
                        groups.push(y);
                    }
                    Self::concat_groups(&groups)
                }
            };

            // Fresh optimizer per unit.
            let state_names: Vec<String> = unit
                .layers
                .iter()
                .flat_map(|l| {
                    ["V", "s_a", "bp"]
                        .iter()
                        .map(|k| format!("state:{}.{k}", l.name))
                        .collect::<Vec<_>>()
                })
                .collect();
            st.reset_adam(&state_names);

            let spec = manifest.req_program(&unit.program)?.clone();
            let drop_p = self.cfg.method.drop_prob();
            let mut first_loss = f32::NAN;
            let mut last_loss = f32::NAN;
            for iter in 0..self.cfg.calib.iters {
                let idx: Vec<usize> = (0..b).map(|_| rng.below(calib.n)).collect();
                let x_in = Self::gather_rows(&x_in_all, &idx);
                let x_fp = Self::gather_rows(x_fp_all, &idx);
                let y_fp = Self::gather_rows(&y_fp_all, &idx);
                let mut mask = Tensor::zeros(x_in.shape.clone());
                if drop_p > 0.0 {
                    for v in &mut mask.data {
                        *v = rng.bernoulli(drop_p) as u8 as f32;
                    }
                }
                let knobs = self.step_knobs(&sched, iter);
                let loss = self.step(
                    &spec,
                    &mut st,
                    &unit,
                    &x_in,
                    &x_fp,
                    &y_fp,
                    &mask,
                    knobs,
                )?;
                if iter == 0 {
                    first_loss = loss;
                }
                last_loss = loss;
            }
            if self.verbose {
                println!(
                    "  [{}] {}: loss {first_loss:.5} -> {last_loss:.5}",
                    self.cfg.tag(),
                    unit.program
                );
            }
            reports.push(UnitReport {
                unit: unit.program.clone(),
                first_loss,
                last_loss,
                iters: self.cfg.calib.iters,
            });
        }
        Ok((st, reports))
    }

    /// Knobs for a calibration step at `iter`.
    fn step_knobs(&self, sched: &Schedule, iter: u32) -> Knobs {
        let m = self.cfg.method;
        let bits = self.cfg.bits;
        let c = &self.cfg.calib;
        Knobs {
            lr_v: if bits.w_quantized() { c.lr_v } else { 0.0 },
            lr_s: if matches!(m, crate::config::Method::AdaRound) || !bits.a_quantized() {
                0.0
            } else {
                c.lr_s
            },
            lr_b: if m.uses_border() && bits.a_quantized() {
                c.lr_b
            } else {
                0.0
            },
            alpha_round: sched.alpha_round(iter),
            beta: sched.beta(iter),
            lam: c.lam,
            wq_en: bits.w_quantized(),
            aq_en: bits.a_quantized(),
            border_en: m.uses_border(),
            fuse_en: m.uses_border() && m != crate::config::Method::AQuantNoFusion,
            b2_en: m.uses_border() && m != crate::config::Method::AQuantLinear,
        }
    }

    /// One step-program invocation: assemble args by manifest order,
    /// execute, write results back into the store. Returns the loss.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        spec: &ProgramSpec,
        st: &mut StateStore,
        unit: &Unit<'_>,
        x_in: &Tensor,
        x_fp: &Tensor,
        y_fp: &Tensor,
        mask: &Tensor,
        knobs: Knobs,
    ) -> Result<f32> {
        let topo = self.chain.topo;
        let exe = self.chain.rt.load(&spec_name(spec))?;
        let mut args = Vec::with_capacity(spec.args.len());
        for a in &spec.args {
            let lit = match a.role() {
                "w" => {
                    let (lname, field) = a
                        .local_name()
                        .rsplit_once('.')
                        .ok_or_else(|| anyhow!("bad w arg {}", a.name))?;
                    let lw = self
                        .chain_weights(lname)
                        .ok_or_else(|| anyhow!("weights {lname}"))?;
                    match field {
                        "w" => {
                            let l = topo.layer(lname)?;
                            literal_f32(&lw.w, &[l.oc as i64, l.rows_per_group() as i64])?
                        }
                        "b" => literal_f32(&lw.b, &[lw.b.len() as i64])?,
                        _ => bail!("unknown weight field {field}"),
                    }
                }
                "state" | "adam" => to_literal(st.get(&a.name)?)?,
                "batch" => match a.local_name() {
                    "x_in" => to_literal(x_in)?,
                    "x_fp" => to_literal(x_fp)?,
                    "y_fp" => to_literal(y_fp)?,
                    "mask" => to_literal(mask)?,
                    other => bail!("unknown batch arg {other}"),
                },
                "hyper" => match a.local_name() {
                    "bits" => {
                        let mut rows = Vec::with_capacity(unit.layers.len() * 4);
                        for l in &unit.layers {
                            rows.extend(
                                bits_row_for(topo, self.cfg.bits, &l.name).as_row(),
                            );
                        }
                        literal_f32(&rows, &[unit.layers.len() as i64, 4])?
                    }
                    "knobs" => literal_f32(&knobs.to_vec(), &[12])?,
                    other => bail!("unknown hyper arg {other}"),
                },
                role => bail!("unknown arg role {role} in {}", a.name),
            };
            args.push(lit);
        }
        let outs = exe.run(&args)?;
        let mut loss = f32::NAN;
        for (r, lit) in spec.results.iter().zip(outs.iter()) {
            if r.name == "out:loss" {
                loss = lit.to_vec::<f32>()?[0];
            } else {
                let shape: Vec<usize> = r.shape.iter().map(|&d| d as usize).collect();
                st.set(&r.name, from_literal(lit, shape)?);
            }
        }
        if !loss.is_finite() {
            bail!("non-finite loss in {}", spec_name(spec));
        }
        Ok(loss)
    }

    fn chain_weights(&self, lname: &str) -> Option<&crate::nn::engine::LayerWeights> {
        // ChainRunner holds the weights; expose through a helper.
        self.chain.weights().get(lname)
    }
}

fn spec_name(spec: &ProgramSpec) -> String {
    // program name == file stem of its path
    spec.path.trim_end_matches(".hlo.txt").to_string()
}
