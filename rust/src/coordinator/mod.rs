//! The PTQ coordinator (L3): owns the calibration loop of Algorithm 1.
//!
//! The JAX-side step programs are pure functions (state in → state out);
//! everything stateful lives here: the quant-state store, the schedules
//! (α_round ramp, β anneal), QDrop mask generation, batch sampling, the
//! block ordering, and the forward chains that produce each block's
//! calibration inputs/targets.

// The calibration loop and forward chains execute PJRT programs, so
// they live behind the `pjrt` feature; schedules and the state store
// are pure Rust (serving and tooling read cached qstate without PJRT).
#[cfg(feature = "pjrt")]
pub mod calib;
#[cfg(feature = "pjrt")]
pub mod chain;
pub mod schedule;
pub mod state;

#[cfg(feature = "pjrt")]
pub use calib::Calibrator;
#[cfg(feature = "pjrt")]
pub use chain::ChainRunner;
pub use schedule::Schedule;
pub use state::StateStore;
