//! The PTQ coordinator (L3): owns the calibration loop of Algorithm 1.
//!
//! The JAX-side step programs are pure functions (state in → state out);
//! everything stateful lives here: the quant-state store, the schedules
//! (α_round ramp, β anneal), QDrop mask generation, batch sampling, the
//! block ordering, and the forward chains that produce each block's
//! calibration inputs/targets.

pub mod calib;
pub mod chain;
pub mod schedule;
pub mod state;

pub use calib::Calibrator;
pub use chain::ChainRunner;
pub use schedule::Schedule;
pub use state::StateStore;
