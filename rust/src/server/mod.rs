//! Quantized-inference server: multi-model dynamic batching over one
//! shared worker pool (Python never on the request path — engines run
//! quantized weights + the border function natively).
//!
//! # Wire protocol (little-endian)
//!
//! Two request framings share one port; the server byte-sniffs the
//! first 4 bytes of each request:
//!
//! ```text
//!   v1 request:  u32 n_images (1..=4096), then n·(C·H·W) f32 pixels
//!                (routed to model id 0, the default model)
//!   v2 request:  magic "AQSV" | u16 version (=2) | u16 model_id |
//!                u32 n_images (1..=4096), then n·(C·H·W) f32 pixels
//!   describe:    magic "AQSD" | u16 version (=2) | u16 reserved (=0)
//!                → response u32 n_models, then n u32 img_elems
//!                  (f32s per image, indexed by model id)
//!   response:    u32 n_images, then n u32 class ids   (v1 and v2)
//! ```
//!
//! Sniffing is unambiguous: a v1 header reading "AQSV" would mean
//! n = 0x5653_5141 (≈1.4e9), far beyond the 4096-image protocol cap, so
//! no *valid* v1 request can be mistaken for v2 — and "AQSD" reads
//! 0x4453_5141, equally out of range (pinned by the protocol property
//! tests). A connection may pipeline any number of requests — mixing
//! v1 and v2 freely — and the server answers in order. A request with
//! a bad `n`, an unknown model id, or an unsupported version is
//! rejected by closing the connection (counted in stats); a mid-stream
//! EOF drops only that connection. Either way the accept loop and the
//! scheduler keep serving other connections.
//!
//! The describe frame exists for the router tier ([`route`]): a
//! `--route` front-end must size incoming payloads (`n × img_elems ×
//! 4`) without hosting the models, so it asks each backend for its
//! dimension table on connect. Any client may send it.
//!
//! # Architecture
//!
//! ```text
//!   ONE event-loop thread (conn.rs over util::poll — epoll, or
//!   poll(2) as the portable fallback): owns listener + every client
//!   socket, all non-blocking
//!     └─ per-conn state machine: sniff v1/v2 header, resolve model id,
//!        stream payload → f32s ────────────────► per-model BatchQueue
//!        try_push(Pending{images, reply});        (bounded, images-
//!        a full queue PARKS the connection         counted, Mutex+Condvar)
//!        (read interest off = TCP backpressure)     │ poll / try_pop
//!        ◄── completions ring the loop's waker ──┐  ▼
//!            (responses flush with partial-     ONE fair-scheduler
//!             write carry; EPIPE drops only     thread (sched.rs):
//!             that connection)                  weighted deficit-round-
//!                                               robin over every model's
//!                                               queue — each admission
//!                                               coalesces queued same-
//!                                               model requests into one
//!                                               ≤ max_batch batch (per-
//!                                               model straggler dead-
//!                                               lines), admitted in
//!                                               weight proportion,
//!                                               throttled by an
//!                                               in-flight-images cap
//!                                                    │ submit(model_id, …)
//!                                                    ▼
//!                                       shared InferencePool (N workers,
//!                                       model-agnostic per-worker scratch;
//!                                       completions answer the requests)
//! ```
//!
//! Connections cost state, not threads: the readiness loop holds
//! thousands of mostly-idle sockets (slow writers, keep-alives,
//! pipelined bursts) for a few hundred bytes each, with per-connection
//! idle/read timeouts (`--conn-timeout-ms`) and a concurrent-connection
//! cap (`--max-conns`, rejected conns counted) guarding the tail. See
//! [`conn`] for the state machine and `rust/tests/conn_conformance.rs`
//! for the adversarial-client suite (slow loris, mid-payload
//! disconnects, half-open peers, >cap rejection).
//!
//! Queues, policies, and straggler deadlines are **per model** so one
//! model's wait never delays another model's traffic; only the worker
//! pool (the actual CPU) is shared, and the [`sched::FairScheduler`]
//! decides whose queued images reach it next. Jobs carry their
//! `Arc<Engine>` plus their model id, and worker scratch is pre-sized
//! to the registry's max dims, so heterogeneous models reuse the same
//! threads and buffers.
//!
//! Scheduling cannot change results: every image's forward pass is
//! independent and pooled execution is bit-identical to the sequential
//! engine (see `rust/tests/serve_roundtrip.rs`, `rust/tests/multi_model.rs`
//! and `pool_props.rs`).
//!
//! # Knobs ([`ServeConfig`] defaults + per-model [`sched::Policy`])
//!
//! * `workers` — inference threads shared by all models (0 = cores − 1)
//! * `max_batch` — images per engine batch; larger amortizes dispatch,
//!   smaller bounds latency
//! * `batch_wait_us` — straggler deadline; 0 = dispatch immediately
//! * `queue_images` — per-model queue bound; a full queue *parks* that
//!   model's connections (the event loop drops their read interest, so
//!   the kernel receive window backpressures the client) instead of
//!   growing without limit. Payloads still being received are held
//!   per-connection (streamed in, so allocation tracks bytes actually
//!   read, capped by the 4096-image protocol limit).
//! * `weight` (per model only, `--model ...;weight=N`) — fair share of
//!   pool admission when several models are backlogged
//! * `max_conns` — concurrent-connection cap; accepts beyond it are
//!   closed immediately (counted in [`ServerStats::conns_rejected`])
//! * `conn_timeout_ms` — idle/read deadline per connection (0 = never);
//!   applies only while the server owes the client nothing, so slow
//!   *clients* die and slow *batches* don't kill their clients
//! * `max_accepts` — bounded runs (tests/examples): stop accepting
//!   after N connections and return once they finish
//! * `stats_addr` (`--stats-addr`) — optional second listener on the
//!   SAME event loop serving `GET /stats` (JSON snapshot) and
//!   `GET /stats?fmt=text`; read-only, own token space and slab, never
//!   counts against `max_conns`/`max_accepts` (see [`metrics`])
//! * `stats_history` (`--stats-history PATH`) — append a JSON-line
//!   snapshot every `stats_history_every_s` seconds (default 5) plus a
//!   final one at shutdown
//! * `admin_addr` (`--admin-addr`) — optional control-plane listener
//!   on the SAME event loop: line-oriented `add`/`remove`/`policy`/
//!   `reload` commands epoch-swap the model registry under live
//!   traffic (see [`reload`]); own token space, never counts against
//!   `max_conns`, unauthenticated — bind it to localhost
//! * `slo_us` (per model only, `--model ...;slo_us=N`) — p99
//!   end-to-end latency target in µs; a slow EWMA of observed p99
//!   boosts the model's fair-share weight (bounded, up to
//!   [`sched::SLO_FACTOR_MAX`]×) while the target is missed and decays
//!   back once met — scheduling order only, predictions bit-identical
//!
//! Every knob except `workers` can be overridden per model through the
//! `--model NAME=SPEC;key=value...` grammar; the flags above set the
//! server-level defaults.

pub mod conn;
pub mod metrics;
pub mod reload;
pub mod route;
pub mod sched;

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::{ModelSpec, ServeConfig};
use crate::nn::engine::Engine;
use crate::nn::pool::{InferencePool, IntraCfg};
use crate::nn::registry::ModelRegistry;

pub use metrics::{HistSummary, LatencyHist, Snapshot};
pub use route::RouterServer;
pub use sched::{FairScheduler, Grant, Policy, SloAdapter, MAX_WEIGHT, SLO_FACTOR_MAX};

use sched::{Doorbell, SchedCtx};

/// Hard protocol cap on images per request.
pub const MAX_REQ_IMAGES: usize = 4096;

/// Protocol v2 magic word ("AQSV"). As a v1 little-endian u32 this
/// reads 0x5653_5141 — far above [`MAX_REQ_IMAGES`] — so byte-sniffing
/// can never misroute a valid v1 request.
pub const MAGIC: [u8; 4] = *b"AQSV";

/// Describe-request magic word ("AQSD"): ask a serving process for its
/// model dimension table (u32 count + count × u32 `img_elems`, indexed
/// by model id). As a v1 u32 this reads 0x4453_5141 — also far above
/// [`MAX_REQ_IMAGES`] and distinct from [`MAGIC`] — so the same byte
/// sniff stays unambiguous. The router tier handshakes with it.
pub const MAGIC_DESC: [u8; 4] = *b"AQSD";

/// Protocol version this server speaks (and the only one it accepts).
pub const PROTO_VERSION: u16 = 2;

/// Bytes of a v2 request header (magic + version + model id + n).
pub const V2_HEADER_LEN: usize = 12;

/// Bytes of a describe request (magic + version + reserved u16).
pub const DESC_HEADER_LEN: usize = 8;

/// Batch-size histogram buckets: bucket i counts executed batches with
/// 2^i ..= 2^(i+1)−1 images (last bucket is open-ended at 4096).
pub const BATCH_BUCKETS: usize = 13;

// ---- Admin (control-plane) protocol -------------------------------
//
// Line-oriented text on the optional `--admin-addr` listener (served
// by the SAME event loop as client traffic, own token space, never
// counted against `--max-conns`). One command per '\n'-terminated
// line; one reply line per command:
//
//   add NAME=SPEC              register a model at a fresh id
//   remove NAME                tombstone a model (id never reused)
//   policy NAME key=value...   retune serving-policy keys in place
//   reload                     bump the registry epoch (no-op swap)
//
// Replies: `ok epoch=N models=M` or `err <reason>` (always one line).
// See [`reload`] for swap semantics and the README "Control plane"
// section for the operator view.

/// Admin command: `add NAME=SPEC` (synth specs only — manifest models
/// need calibration artifacts resolved at startup).
pub const ADMIN_CMD_ADD: &str = "add";
/// Admin command: `remove NAME`.
pub const ADMIN_CMD_REMOVE: &str = "remove";
/// Admin command: `policy NAME key=value [key=value ...]`.
pub const ADMIN_CMD_POLICY: &str = "policy";
/// Admin command: `reload` (epoch bump without a content change).
pub const ADMIN_CMD_RELOAD: &str = "reload";
/// First token of every successful admin reply.
pub const ADMIN_OK: &str = "ok";
/// First token of every failed admin reply.
pub const ADMIN_ERR: &str = "err";
/// Longest accepted admin command line, in bytes (excluding the
/// newline). A connection that exceeds it gets an `err` reply and is
/// closed — admin lines are operator-typed, not bulk data.
pub const MAX_ADMIN_LINE: usize = 4096;

/// One parsed request header, either framing. Framing only — range
/// checks on `n`, version, and model id are the server's job (their
/// rejection stats differ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestHeader {
    V1 { n: u32 },
    V2 { version: u16, model_id: u16, n: u32 },
    /// Describe request ("AQSD"): no payload, answered with the model
    /// dimension table. Carries no model id and no image count.
    Describe { version: u16 },
}

impl RequestHeader {
    /// Images promised by the header (0 for describe — it has no
    /// payload).
    pub fn n(&self) -> u32 {
        match *self {
            RequestHeader::V1 { n } | RequestHeader::V2 { n, .. } => n,
            RequestHeader::Describe { .. } => 0,
        }
    }

    /// Model routing: v1 clients always hit the default model (id 0).
    /// Describe is model-less; it reports 0 so callers that only log
    /// never branch on it.
    pub fn model_id(&self) -> u16 {
        match *self {
            RequestHeader::V1 { .. } => 0,
            RequestHeader::V2 { model_id, .. } => model_id,
            RequestHeader::Describe { .. } => 0,
        }
    }

    /// Wire bytes for this header (v1: 4 bytes; v2: 12; describe: 8).
    /// Encoding preserves an arbitrary `version` so tests can
    /// round-trip unsupported versions too.
    pub fn encode(&self) -> Vec<u8> {
        match *self {
            RequestHeader::V1 { n } => n.to_le_bytes().to_vec(),
            RequestHeader::V2 {
                version,
                model_id,
                n,
            } => {
                let mut out = Vec::with_capacity(V2_HEADER_LEN);
                out.extend_from_slice(&MAGIC);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&model_id.to_le_bytes());
                out.extend_from_slice(&n.to_le_bytes());
                out
            }
            RequestHeader::Describe { version } => {
                let mut out = Vec::with_capacity(DESC_HEADER_LEN);
                out.extend_from_slice(&MAGIC_DESC);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&0u16.to_le_bytes());
                out
            }
        }
    }
}

/// Encode a describe response: the model dimension table (`img_elems`
/// per model id).
pub fn encode_describe_response(elems: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + elems.len() * 4);
    out.extend_from_slice(&(elems.len() as u32).to_le_bytes());
    for e in elems {
        out.extend_from_slice(&e.to_le_bytes());
    }
    out
}

/// Encode a v2 header with the current [`PROTO_VERSION`].
pub fn encode_header_v2(model_id: u16, n: u32) -> [u8; V2_HEADER_LEN] {
    let mut out = [0u8; V2_HEADER_LEN];
    out[..4].copy_from_slice(&MAGIC);
    out[4..6].copy_from_slice(&PROTO_VERSION.to_le_bytes());
    out[6..8].copy_from_slice(&model_id.to_le_bytes());
    out[8..12].copy_from_slice(&n.to_le_bytes());
    out
}

/// Read one request header, sniffing v1 vs v2 from the first 4 bytes.
/// `Ok(None)` = clean EOF before a request started (pipelined
/// connection done). EOF *inside* a v2 header is a truncated frame and
/// surfaces as `Err(UnexpectedEof)`.
pub fn read_request_header(stream: &mut impl Read) -> std::io::Result<Option<RequestHeader>> {
    let mut first = [0u8; 4];
    match stream.read_exact(&mut first) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    if first == MAGIC {
        let mut rest = [0u8; V2_HEADER_LEN - 4];
        stream.read_exact(&mut rest)?;
        Ok(Some(RequestHeader::V2 {
            version: u16::from_le_bytes([rest[0], rest[1]]),
            model_id: u16::from_le_bytes([rest[2], rest[3]]),
            n: u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]),
        }))
    } else if first == MAGIC_DESC {
        let mut rest = [0u8; DESC_HEADER_LEN - 4];
        stream.read_exact(&mut rest)?;
        Ok(Some(RequestHeader::Describe {
            version: u16::from_le_bytes([rest[0], rest[1]]),
        }))
    } else {
        Ok(Some(RequestHeader::V1 {
            n: u32::from_le_bytes(first),
        }))
    }
}

/// Per-model server statistics, shared up front via `Arc` so a
/// long-lived server can be observed while running.
#[derive(Debug, Default)]
pub struct Stats {
    /// Completed (answered) requests.
    pub requests: AtomicU64,
    /// Images executed through the engine (counted at batch completion,
    /// so live reads and `mean_batch` stay coherent).
    pub images: AtomicU64,
    /// Batch service time (µs; scheduler admission → pool completion)
    /// summed over executed batches.
    pub total_us: AtomicU64,
    /// Successfully executed engine batches (after coalescing); failed
    /// batches are counted separately so images/batches/total_us stay
    /// coherent with answered predictions.
    pub batches: AtomicU64,
    /// Batches whose pool execution failed (every coalesced request in
    /// them got an error reply).
    pub failed_batches: AtomicU64,
    /// Requests rejected for a malformed header (bad `n`) after this
    /// model was resolved.
    pub rejected: AtomicU64,
    /// Images currently waiting in this model's batch queue (gauge).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub queue_peak: AtomicU64,
    /// Batches admitted into the pool by the fair scheduler.
    pub admitted: AtomicU64,
    /// Admission attempts that hit pool backpressure (the in-flight
    /// cap) while this model had an admissible batch — one count per
    /// blocked attempt, not per wakeup.
    pub deferred: AtomicU64,
    /// Current deficit-round-robin credit, in images (gauge; negative
    /// after an oversized admission).
    pub deficit: AtomicI64,
    /// Histogram of executed batch sizes (log2 buckets).
    pub batch_hist: [AtomicU64; BATCH_BUCKETS],
    /// Per-request end-to-end latency (payload decoded → reply staged
    /// into the connection's write buffer), µs. What `slo_us=` targets.
    pub e2e_hist: LatencyHist,
    /// Per-request queue wait (enqueue → scheduler pop), µs. High here
    /// with a low service time means weight-starved, not slow.
    pub queue_wait_hist: LatencyHist,
    /// Per-batch service time (admission → pool completion), µs — the
    /// distribution behind `total_us`.
    pub service_hist: LatencyHist,
    /// Static configured fair-share weight (gauge, set at bind).
    pub weight: AtomicU64,
    /// Configured p99 end-to-end SLO in µs (gauge; 0 = no SLO).
    pub slo_us: AtomicU64,
    /// Adaptive effective weight ×1000 (gauge, written by the
    /// scheduler's SLO adapter; == weight×1000 without SLO pressure).
    pub effective_weight_milli: AtomicU64,
}

impl Stats {
    /// Histogram bucket for a batch of `n` images: floor(log2 n),
    /// clamped to the last bucket.
    pub fn batch_bucket(n: usize) -> usize {
        let n = n.max(1);
        ((usize::BITS - 1 - n.leading_zeros()) as usize).min(BATCH_BUCKETS - 1)
    }

    /// Record one executed engine batch.
    pub fn observe_batch(&self, n: usize, us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(n as u64, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.batch_hist[Self::batch_bucket(n)].fetch_add(1, Ordering::Relaxed);
        self.service_hist.observe(us);
    }

    /// Mean batch service time in µs (0 when nothing ran yet).
    pub fn mean_service_us(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.total_us.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Mean images per executed batch (coalescing effectiveness).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.images.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human summary for this model.
    pub fn report(&self) -> String {
        let hist: Vec<String> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then(|| format!("{}:{c}", 1usize << i))
            })
            .collect();
        // quantile rendering: "-" while a histogram is empty, never a
        // fake 0 (a raw summed service total was unreadable at a glance)
        let q = |h: &LatencyHist, q: f64| match h.quantile(q) {
            Some(v) => format!("{v:.0}"),
            None => "-".into(),
        };
        format!(
            "requests {}  images {}  batches {} (mean {:.1} img/batch)  \
             service mean {:.0}us p50/p99 {}/{}us  e2e p50/p99 {}/{}us  \
             failed {}  rejected {}  queue peak {}  admitted {}  deferred {}  \
             deficit {}  batch-size hist [{}]",
            self.requests.load(Ordering::Relaxed),
            self.images.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.mean_service_us(),
            q(&self.service_hist, 0.50),
            q(&self.service_hist, 0.99),
            q(&self.e2e_hist, 0.50),
            q(&self.e2e_hist, 0.99),
            self.failed_batches.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.queue_peak.load(Ordering::Relaxed),
            self.admitted.load(Ordering::Relaxed),
            self.deferred.load(Ordering::Relaxed),
            self.deficit.load(Ordering::Relaxed),
            hist.join(" "),
        )
    }
}

/// One model slot's statistics row: name + counters + the registry
/// epoch the slot first appeared in. Rows are append-only — a removed
/// model's row stays (counters frozen once its queue drains) so wire
/// ids keep meaning in snapshots across control-plane swaps.
#[derive(Debug)]
struct ModelRow {
    name: String,
    stats: Arc<Stats>,
    added_at_epoch: u64,
}

/// All of a server's statistics: one [`Stats`] per model slot ever
/// assigned (indexed by model id) plus server-level counters for
/// requests that failed before any model was resolved. The row list
/// grows under a mutex when the control plane hot-adds a model; hot
/// paths never take it — they hold per-slot `Arc<Stats>` clones.
#[derive(Debug)]
pub struct ServerStats {
    rows: Mutex<Vec<ModelRow>>,
    /// Current registry epoch (0 until the first control-plane swap).
    pub registry_epoch: AtomicU64,
    /// Control-plane swaps applied since bind (add/remove/policy/reload).
    pub reloads: AtomicU64,
    /// v2 requests naming a model id outside the registry.
    pub unknown_model: AtomicU64,
    /// v2 requests with a version this server doesn't speak.
    pub bad_version: AtomicU64,
    /// Completed fair-scheduler rounds that admitted at least one
    /// batch (starvation bounds are stated in rounds — see
    /// `rust/tests/multi_model.rs`).
    pub rounds: AtomicU64,
    /// Client connections currently open in the event loop (gauge).
    pub conns_open: AtomicU64,
    /// Connections accepted since startup (including rejected ones —
    /// the handshake completed either way).
    pub conns_accepted: AtomicU64,
    /// Connections closed straight after accept because `--max-conns`
    /// concurrent connections were already open.
    pub conns_rejected: AtomicU64,
    /// Connections closed by the idle/read timeout
    /// (`--conn-timeout-ms`); slow-loris and dead peers land here.
    pub conns_timed_out: AtomicU64,
    /// Router mode only: per-backend forward/reply counters (`None`
    /// when this process hosts models itself). Snapshots surface it
    /// under the `"router"` key.
    router: Option<Arc<route::RouterStats>>,
    /// When these stats were created (≈ bind time), for uptime.
    started: Instant,
}

impl ServerStats {
    fn with_names(names: Vec<String>) -> Self {
        ServerStats {
            rows: Mutex::new(
                names
                    .into_iter()
                    .map(|name| ModelRow {
                        name,
                        stats: Arc::new(Stats::default()),
                        added_at_epoch: 0,
                    })
                    .collect(),
            ),
            registry_epoch: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            started: Instant::now(),
            unknown_model: AtomicU64::new(0),
            bad_version: AtomicU64::new(0),
            rounds: AtomicU64::new(0),
            conns_open: AtomicU64::new(0),
            conns_accepted: AtomicU64::new(0),
            conns_rejected: AtomicU64::new(0),
            conns_timed_out: AtomicU64::new(0),
            router: None,
        }
    }

    fn new(registry: &ModelRegistry) -> Self {
        Self::with_names(registry.iter().map(|(_, e)| e.name.clone()).collect())
    }

    /// Append a stats row for a hot-added model slot and return its
    /// counters. Called by the control plane only after the whole swap
    /// validated — a rejected command must not leak rows.
    pub(crate) fn register_row(&self, name: &str, added_at_epoch: u64) -> Arc<Stats> {
        let stats = Arc::new(Stats::default());
        self.rows.lock().unwrap().push(ModelRow {
            name: name.to_string(),
            stats: stats.clone(),
            added_at_epoch,
        });
        stats
    }

    /// Record an applied control-plane swap (epoch gauge + reload count).
    pub(crate) fn note_swap(&self, epoch: u64) {
        self.registry_epoch.store(epoch, Ordering::Relaxed);
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of every model row: `(name, stats,
    /// added_at_epoch)` in wire-id order (snapshots and reports walk it).
    pub(crate) fn rows_snapshot(&self) -> Vec<(String, Arc<Stats>, u64)> {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .map(|r| (r.name.clone(), r.stats.clone(), r.added_at_epoch))
            .collect()
    }

    /// Stats for a router-mode process: one per-route [`Stats`] entry
    /// (so request counts and e2e latency work unchanged — "model" id
    /// means route id there) plus the per-backend [`route::RouterStats`].
    /// Queue/batch/weight gauges stay zero except the weight gauges,
    /// which are pinned to 1 so snapshots render sanely.
    pub(crate) fn for_router(names: Vec<String>, router: Arc<route::RouterStats>) -> Self {
        let mut stats = Self::with_names(names);
        stats.router = Some(router);
        for row in stats.rows.lock().unwrap().iter() {
            row.stats.weight.store(1, Ordering::Relaxed);
            row.stats
                .effective_weight_milli
                .store(1000, Ordering::Relaxed);
        }
        stats
    }

    /// Per-backend router counters (router mode only).
    pub fn router(&self) -> Option<&Arc<route::RouterStats>> {
        self.router.as_ref()
    }

    /// Stats for one model id (an owned handle — rows live behind a
    /// mutex since the control plane can append while serving).
    pub fn model(&self, id: u16) -> Option<Arc<Stats>> {
        self.rows
            .lock()
            .unwrap()
            .get(id as usize)
            .map(|r| r.stats.clone())
    }

    /// Stats for the default (v1-compat) model.
    pub fn default_model(&self) -> Arc<Stats> {
        self.rows.lock().unwrap()[0].stats.clone()
    }

    /// Model slots ever assigned (live + tombstoned).
    pub fn n_models(&self) -> usize {
        self.rows.lock().unwrap().len()
    }

    /// Model name for a wire id (snapshots and reports use it).
    pub fn model_name(&self, id: u16) -> Option<String> {
        self.rows
            .lock()
            .unwrap()
            .get(id as usize)
            .map(|r| r.name.clone())
    }

    /// Time since these stats were created (≈ process serving uptime).
    pub fn uptime(&self) -> Duration {
        self.started.elapsed()
    }

    /// Freeze every counter/histogram into a point-in-time
    /// [`Snapshot`] (what `GET /stats` and the history file serve).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot::collect(self)
    }

    /// Sum of answered requests across models.
    pub fn total_requests(&self) -> u64 {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.stats.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Sum of executed images across models.
    pub fn total_images(&self) -> u64 {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.stats.images.load(Ordering::Relaxed))
            .sum()
    }

    /// Sum of rejected requests: per-model bad-`n` rejections plus the
    /// server-level unknown-model / bad-version rejections.
    pub fn total_rejected(&self) -> u64 {
        self.rows
            .lock()
            .unwrap()
            .iter()
            .map(|r| r.stats.rejected.load(Ordering::Relaxed))
            .sum::<u64>()
            + self.unknown_model.load(Ordering::Relaxed)
            + self.bad_version.load(Ordering::Relaxed)
    }

    /// Multi-line human summary: one line per model + server counters.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (i, (name, s, _)) in self.rows_snapshot().into_iter().enumerate() {
            out.push_str(&format!("model {i} {name}: {}\n", s.report()));
        }
        out.push_str(&format!(
            "server: unknown-model {}  bad-version {}  sched-rounds {}  \
             reloads {} (epoch {})  \
             conns open {} / accepted {} / rejected {} / timed-out {}  uptime {:.1}s",
            self.unknown_model.load(Ordering::Relaxed),
            self.bad_version.load(Ordering::Relaxed),
            self.rounds.load(Ordering::Relaxed),
            self.reloads.load(Ordering::Relaxed),
            self.registry_epoch.load(Ordering::Relaxed),
            self.conns_open.load(Ordering::Relaxed),
            self.conns_accepted.load(Ordering::Relaxed),
            self.conns_rejected.load(Ordering::Relaxed),
            self.conns_timed_out.load(Ordering::Relaxed),
            self.uptime().as_secs_f64(),
        ));
        out
    }
}

/// A bound server: listener + model registry + knobs + resolved
/// per-model policies. Splitting bind from run lets callers learn the
/// ephemeral port and grab the stats handle before the (blocking)
/// accept loop starts.
pub struct Server {
    listener: TcpListener,
    /// Optional `--stats-addr` listener, bound up front so callers can
    /// learn its ephemeral port before `run` (mirrors `local_addr`).
    /// Served by the same event loop as client traffic.
    stats_listener: Option<TcpListener>,
    /// Optional `--admin-addr` control-plane listener (same event
    /// loop, own token space). Bind it to localhost: the admin
    /// protocol is unauthenticated by design, like `--stats-addr`.
    admin_listener: Option<TcpListener>,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    stats: Arc<ServerStats>,
    /// Per-model serving policies: each registry entry's overrides
    /// resolved over the server-level defaults, validated at bind.
    policies: Vec<Policy>,
}

impl Server {
    /// Bind a multi-model server. Registry id 0 is the default model
    /// serving protocol-v1 clients. Each entry's policy overrides are
    /// resolved against `cfg`'s global knobs here, so a bad per-model
    /// policy fails bind — not the first request.
    pub fn bind(registry: Arc<ModelRegistry>, addr: &str, cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let defaults = Policy::from_serve_cfg(&cfg);
        let policies = registry
            .iter()
            .map(|(id, e)| {
                Policy::resolve(&defaults, &e.policy)
                    .with_context(|| format!("model {id} ({:?}) serving policy", e.name))
            })
            .collect::<Result<Vec<_>>>()?;
        // Fails fast on anything the per-policy checks can't see (e.g.
        // an empty registry — already impossible, but cheap to pin).
        FairScheduler::new(&policies)?;
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let stats_listener = match cfg.stats_addr.as_deref() {
            Some(a) => Some(
                TcpListener::bind(a).with_context(|| format!("binding stats endpoint {a}"))?,
            ),
            None => None,
        };
        let admin_listener = match cfg.admin_addr.as_deref() {
            Some(a) => Some(
                TcpListener::bind(a).with_context(|| format!("binding admin endpoint {a}"))?,
            ),
            None => None,
        };
        let stats = Arc::new(ServerStats::new(&registry));
        // Policy gauges: static weight / SLO are fixed from here on;
        // the effective weight starts at the static value and is only
        // rewritten by the scheduler's SLO adapter.
        for (id, _) in registry.iter() {
            let p = &policies[id as usize];
            let s = stats.model(id).expect("stats per model");
            s.weight.store(p.weight as u64, Ordering::Relaxed);
            s.slo_us.store(p.slo_us.unwrap_or(0), Ordering::Relaxed);
            s.effective_weight_milli
                .store(p.weight as u64 * 1000, Ordering::Relaxed);
        }
        Ok(Server {
            listener,
            stats_listener,
            admin_listener,
            registry,
            cfg,
            stats,
            policies,
        })
    }

    /// Bind a single-model server (the pre-v2 shape): wraps the engine
    /// in a one-entry registry named after its topology.
    pub fn bind_single(engine: Arc<Engine>, addr: &str, cfg: ServeConfig) -> Result<Server> {
        Server::bind(Arc::new(ModelRegistry::single(engine)?), addr, cfg)
    }

    /// Actual bound address (use after binding port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Bound stats-endpoint address when `--stats-addr` is configured
    /// (use after binding port 0).
    pub fn stats_local_addr(&self) -> Option<SocketAddr> {
        self.stats_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    /// Bound admin-endpoint address when `--admin-addr` is configured
    /// (use after binding port 0).
    pub fn admin_local_addr(&self) -> Option<SocketAddr> {
        self.admin_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    /// Live statistics handle, valid before/during/after `run`.
    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    /// The hosted models.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Resolved per-model serving policies, in model-id order.
    pub fn policies(&self) -> &[Policy] {
        &self.policies
    }

    /// Run the server: ONE readiness event loop (this thread) owning
    /// every client socket, next to the scheduler thread and the worker
    /// pool. Blocks until `cfg.max_accepts` connections have been
    /// accepted and completed (or forever when None). All queued work
    /// is drained before returning.
    pub fn run(self) -> Result<()> {
        if self.cfg.fast_kernels {
            crate::nn::kernels::request_fast_kernels();
        }
        let workers = self.cfg.resolved_workers();
        // --intra-split 1 (or "off") disables intra-image sharding; 0
        // ("auto") lets the pool pick one chunk per worker.
        let intra = (self.cfg.intra_split != 1).then(|| IntraCfg {
            split: self.cfg.intra_split,
            min_elems: crate::nn::pool::INTRA_MIN_ELEMS,
        });
        // Per-model execution counters are sized for every slot the
        // control plane could ever assign (MAX_MODELS), not just the
        // bind-time registry: hot-added models reuse the same pool.
        // Worker scratch is pre-sized to the bind-time dims and grows
        // lazily when a hot-added engine needs more (grow-only).
        let pool = Arc::new(InferencePool::with_intra(
            workers,
            self.registry.scratch_dims(),
            crate::nn::registry::MAX_MODELS,
            intra,
        ));
        let addr = self
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        let intra_desc = match intra {
            None => "off".to_string(),
            Some(c) if c.split == 0 => format!("auto ({workers})"),
            Some(c) => c.split.to_string(),
        };
        println!(
            "aquant-serve: {} model(s) on {addr} ({} workers, intra-split {intra_desc}; \
             defaults: max-batch {}, wait {}us, queue {})",
            self.registry.len(),
            workers,
            self.cfg.max_batch,
            self.cfg.batch_wait_us,
            self.cfg.queue_images,
        );
        println!(
            "aquant-serve: kernels {} (fast mode: {})",
            crate::nn::kernels::active().name(),
            crate::nn::kernels::fast_mode().name(),
        );
        if let Some(a) = self.stats_local_addr() {
            println!(
                "aquant-serve: stats endpoint on http://{a}/stats (?fmt=text for plaintext)"
            );
        }
        if let Some(a) = self.admin_local_addr() {
            println!(
                "aquant-serve: admin endpoint on {a} \
                 (line protocol: add/remove/policy/reload — keep it on localhost)"
            );
        }
        let history = self.cfg.stats_history.clone().map(|path| {
            println!(
                "aquant-serve: appending stats history to {path} every {}s",
                self.cfg.stats_history_every_s
            );
            metrics::HistoryWriter::spawn(
                path,
                Duration::from_secs(self.cfg.stats_history_every_s.max(1)),
                self.stats.clone(),
            )
        });
        // ONE scheduler thread next to ONE event-loop thread (this
        // one); per-slot bounded queues live inside the control
        // plane's epoch state so admin swaps can grow them. The
        // scheduler is a plain (non-scoped) thread over Arc'd state:
        // it must outlive the event loop, which drains all connections
        // before we signal shutdown.
        let doorbell = Arc::new(Doorbell::new());
        for (id, entry) in self.registry.iter() {
            let policy = &self.policies[id as usize];
            println!(
                "aquant-serve:   id {id} = {} ({} f32/img, {} classes; {})",
                entry.name,
                entry.engine.img_elems(),
                entry.engine.topo.n_classes,
                policy.describe(),
            );
        }
        let control = Arc::new(reload::ControlPlane::new(
            self.registry.clone(),
            &self.policies,
            Policy::from_serve_cfg(&self.cfg),
            self.stats.clone(),
            doorbell.clone(),
        ));
        let ctx = SchedCtx {
            control: control.clone(),
            stats: self.stats.clone(),
            pool: pool.clone(),
            doorbell: doorbell.clone(),
            in_flight: Arc::new(AtomicU64::new(0)),
        };
        let scheduler = std::thread::spawn(move || sched::run_scheduler(ctx));
        let loop_ctx = conn::LoopCtx {
            control: Some(control.clone()),
            stats: self.stats.clone(),
            doorbell: doorbell.clone(),
            max_conns: self.cfg.max_conns,
            max_accepts: self.cfg.max_accepts,
            conn_timeout: (self.cfg.conn_timeout_ms > 0)
                .then(|| Duration::from_millis(self.cfg.conn_timeout_ms)),
            poll_fallback: self.cfg.poll_fallback,
            stats_listener: self.stats_listener,
            admin_listener: self.admin_listener,
            router: None,
        };
        let served = conn::run_event_loop(self.listener, loop_ctx);
        // Every connection is drained (each reply already staged and
        // flushed or its connection gone); tell the scheduler to drain
        // whatever is left — the LATEST epoch's queue set, which
        // includes every tombstoned slot's still-draining queue — and
        // stop. The pool is dropped after the join, which completes
        // any batches still in flight before its workers exit.
        control.shutdown();
        scheduler
            .join()
            .map_err(|_| anyhow!("scheduler thread panicked"))?;
        // Final history flush after the scheduler drained: the last
        // line on disk carries the run's terminal counters.
        if let Some(w) = history {
            w.stop();
        }
        served
    }
}

/// Build a [`ModelRegistry`] from parsed `--model` specs with the
/// build-appropriate manifest path: quantized engines via PJRT
/// calibration when the `pjrt` feature is on, full-precision
/// `nearest:W32A32` loading otherwise (synthetic specs are pure Rust in
/// both). This is the single entry point `aquant serve` and
/// `examples/serve.rs` share — `iters`/`verbose` only affect
/// calibration and are ignored in non-pjrt builds.
#[cfg(feature = "pjrt")]
pub fn registry_from_specs(
    specs: &[ModelSpec],
    artifacts_dir: &str,
    iters: Option<u32>,
    verbose: bool,
) -> Result<ModelRegistry> {
    let mut qb = crate::exp::cell::QuantManifestBuilder::new(artifacts_dir, iters, verbose);
    ModelRegistry::from_specs(specs, |spec| qb.build(spec))
}

/// See the `pjrt` variant above; without the feature, manifest specs
/// are served full-precision via [`crate::nn::loader::FpManifestBuilder`].
#[cfg(not(feature = "pjrt"))]
pub fn registry_from_specs(
    specs: &[ModelSpec],
    artifacts_dir: &str,
    _iters: Option<u32>,
    _verbose: bool,
) -> Result<ModelRegistry> {
    let mut fp = crate::nn::loader::FpManifestBuilder::new(artifacts_dir);
    ModelRegistry::from_specs(specs, |spec| fp.build(spec))
}

/// Client helper (used by the serve example and tests): one v1 request
/// over a fresh connection (answered by the default model).
pub fn classify_remote(addr: &str, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    classify_on(&mut stream, images, n)
}

/// One v2 request over a fresh connection, routed to `model_id`.
pub fn classify_remote_v2(addr: &str, model_id: u16, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    classify_on_v2(&mut stream, model_id, images, n)
}

/// Describe a serving process: its per-model `img_elems` table,
/// indexed by model id (what the router handshakes with on connect).
pub fn describe_remote(addr: &str) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(
        &RequestHeader::Describe {
            version: PROTO_VERSION,
        }
        .encode(),
    )?;
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let count = u32::from_le_bytes(hdr) as usize;
    if count > u16::MAX as usize + 1 {
        return Err(anyhow!("describe response names {count} models (max 65536)"));
    }
    let mut buf = vec![0u8; count * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// One v1 request/response exchange on an existing connection (clients
/// that pipeline requests reuse the stream).
pub fn classify_on(stream: &mut TcpStream, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let hdr = (n as u32).to_le_bytes();
    exchange(stream, &hdr, images)
}

/// One v2 request/response exchange on an existing connection. v1 and
/// v2 requests may be interleaved freely on one stream.
pub fn classify_on_v2(
    stream: &mut TcpStream,
    model_id: u16,
    images: &[f32],
    n: usize,
) -> Result<Vec<u32>> {
    let hdr = encode_header_v2(model_id, n as u32);
    exchange(stream, &hdr, images)
}

fn exchange(stream: &mut TcpStream, hdr: &[u8], images: &[f32]) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(hdr.len() + images.len() * 4);
    out.extend_from_slice(hdr);
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&out)?;
    let mut rhdr = [0u8; 4];
    stream.read_exact(&mut rhdr)?;
    let m = u32::from_le_bytes(rhdr) as usize;
    let mut buf = vec![0u8; m * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_bucket_is_floor_log2() {
        assert_eq!(Stats::batch_bucket(1), 0);
        assert_eq!(Stats::batch_bucket(2), 1);
        assert_eq!(Stats::batch_bucket(3), 1);
        assert_eq!(Stats::batch_bucket(4), 2);
        assert_eq!(Stats::batch_bucket(64), 6);
        assert_eq!(Stats::batch_bucket(4096), 12);
        assert_eq!(Stats::batch_bucket(100_000), BATCH_BUCKETS - 1);
        assert_eq!(Stats::batch_bucket(0), 0); // defensive clamp
    }

    #[test]
    fn stats_observe_and_report() {
        let s = Stats::default();
        s.observe_batch(8, 100);
        s.observe_batch(16, 300);
        assert_eq!(s.images.load(Ordering::Relaxed), 24);
        assert_eq!(s.batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.total_us.load(Ordering::Relaxed), 400);
        assert_eq!(s.batch_hist[3].load(Ordering::Relaxed), 1);
        assert_eq!(s.batch_hist[4].load(Ordering::Relaxed), 1);
        assert_eq!(s.mean_batch(), 12.0);
        assert_eq!(s.mean_service_us(), 200.0);
        assert_eq!(s.service_hist.count(), 2);
        let r = s.report();
        assert!(r.contains("batches 2"), "{r}");
        assert!(r.contains("8:1"), "{r}");
        assert!(r.contains("16:1"), "{r}");
        // the satellite fix: mean service time, not a raw sum
        assert!(r.contains("service mean 200us"), "{r}");
        // e2e histogram is untouched here -> quantiles render as "-"
        assert!(r.contains("e2e p50/p99 -/-us"), "{r}");
    }

    #[test]
    fn header_v1_roundtrip() {
        let h = RequestHeader::V1 { n: 77 };
        let bytes = h.encode();
        assert_eq!(bytes.len(), 4);
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 0);
        assert_eq!(got.n(), 77);
    }

    #[test]
    fn header_v2_roundtrip() {
        let h = RequestHeader::V2 {
            version: PROTO_VERSION,
            model_id: 3,
            n: 4096,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len(), V2_HEADER_LEN);
        assert_eq!(&bytes[..4], &MAGIC);
        assert_eq!(bytes[..], encode_header_v2(3, 4096)[..]);
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 3);
    }

    #[test]
    fn header_eof_and_truncation() {
        // empty stream = clean end of connection
        assert_eq!(read_request_header(&mut std::io::empty()).unwrap(), None);
        // EOF inside the 4-byte sniff window also reads as clean end
        // (matches the pre-v2 server's header handling)
        assert_eq!(read_request_header(&mut &MAGIC[..2]).unwrap(), None);
        // but EOF after a complete magic word is a truncated v2 frame
        let full = encode_header_v2(1, 5);
        for cut in 4..V2_HEADER_LEN {
            let err = read_request_header(&mut &full[..cut]).unwrap_err();
            assert_eq!(err.kind(), ErrorKind::UnexpectedEof, "cut={cut}");
        }
    }

    #[test]
    fn magic_cannot_be_a_valid_v1_header() {
        let as_v1 = u32::from_le_bytes(MAGIC) as usize;
        assert!(as_v1 > MAX_REQ_IMAGES, "sniffing would be ambiguous");
    }

    #[test]
    fn describe_magic_is_sniff_disjoint() {
        // "AQSD" must be impossible as a valid v1 count AND distinct
        // from the v2 magic, or the 4-byte sniff would misroute
        let as_v1 = u32::from_le_bytes(MAGIC_DESC) as usize;
        assert!(as_v1 > MAX_REQ_IMAGES, "sniffing would be ambiguous");
        assert_ne!(MAGIC_DESC, MAGIC);
    }

    #[test]
    fn header_describe_roundtrip() {
        let h = RequestHeader::Describe {
            version: PROTO_VERSION,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len(), DESC_HEADER_LEN);
        assert_eq!(&bytes[..4], &MAGIC_DESC);
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, h);
        // describe has no payload and no model
        assert_eq!(got.n(), 0);
        assert_eq!(got.model_id(), 0);
        // truncation inside the describe header is an error, like v2
        for cut in 4..DESC_HEADER_LEN {
            let err = read_request_header(&mut &bytes[..cut]).unwrap_err();
            assert_eq!(err.kind(), ErrorKind::UnexpectedEof, "cut={cut}");
        }
    }

    #[test]
    fn describe_response_encoding() {
        let bytes = encode_describe_response(&[3072, 12288]);
        assert_eq!(bytes.len(), 12);
        assert_eq!(u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]), 2);
        assert_eq!(u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]), 3072);
        assert_eq!(
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]),
            12288
        );
        assert_eq!(encode_describe_response(&[]), 0u32.to_le_bytes().to_vec());
    }
}
