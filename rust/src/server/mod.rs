//! Quantized-inference server: dynamic batching over a fixed worker
//! pool (Python never on the request path — the engine runs quantized
//! weights + the border function natively).
//!
//! # Wire protocol (little-endian, unchanged since the seed)
//!
//! ```text
//!   request:  u32 n_images (1..=4096), then n·(C·H·W) f32 pixels
//!   response: u32 n_images, then n u32 class ids
//! ```
//!
//! A connection may pipeline any number of requests; the server answers
//! in order. A request with `n = 0` or `n > 4096` is rejected by
//! closing the connection (counted in [`Stats::rejected`]); a
//! mid-stream EOF drops only that connection. Either way the accept
//! loop and batcher keep serving other connections.
//!
//! # Architecture
//!
//! ```text
//!   conns (1 thread each, blocking I/O; tokio unavailable offline)
//!     └─ push(Pending{images, reply}) ──► BatchQueue (bounded, images-
//!        blocks when full (backpressure)     counted, Mutex+Condvar)
//!                                              │ pop_batch(max_batch,
//!                                              │           batch_wait)
//!                                              ▼
//!                                         batcher thread
//!                  coalesces queued requests — possibly from many
//!                  connections — into one engine-sized batch, then
//!                                              │ classify_flat
//!                                              ▼
//!                                       InferencePool (N workers,
//!                                       per-worker reusable scratch)
//! ```
//!
//! The batcher takes whatever is queued the moment work is available;
//! if the batch is still under `max_batch` images it waits up to
//! `batch_wait_us` for stragglers before dispatching. Each pending
//! request gets its slice of the batch's predictions back over its own
//! reply channel.
//!
//! Batching cannot change results: every image's forward pass is
//! independent and pooled execution is bit-identical to the sequential
//! engine (see `rust/tests/serve_roundtrip.rs` and `pool_props.rs`).
//!
//! # Knobs ([`ServeConfig`])
//!
//! * `workers` — inference threads (0 = cores − 1)
//! * `max_batch` — images per engine batch; larger amortizes dispatch,
//!   smaller bounds latency
//! * `batch_wait_us` — straggler deadline; 0 = dispatch immediately
//! * `queue_images` — queue bound; full queue blocks connection pushes
//!   FIFO (TCP backpressure) instead of growing without limit. Note the
//!   bound covers *queued* work: payloads still being received are held
//!   per-connection (streamed in, so allocation tracks bytes actually
//!   read, capped by the 4096-image protocol limit); bounding total
//!   connection memory is `--max-conns` / OS limits territory.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ServeConfig;
use crate::nn::engine::Engine;
use crate::nn::pool::InferencePool;

/// Hard protocol cap on images per request.
pub const MAX_REQ_IMAGES: usize = 4096;

/// Batch-size histogram buckets: bucket i counts executed batches with
/// 2^i ..= 2^(i+1)−1 images (last bucket is open-ended at 4096).
pub const BATCH_BUCKETS: usize = 13;

/// Server statistics, shared up front via `Arc` so a long-lived server
/// can be observed while running (the seed only returned stats after
/// the accept loop exited — useless for a real deployment).
#[derive(Debug, Default)]
pub struct Stats {
    /// Completed (answered) requests.
    pub requests: AtomicU64,
    /// Images executed through the engine (counted at batch execution,
    /// so live reads and `mean_batch` stay coherent).
    pub images: AtomicU64,
    /// Engine time (µs) summed over executed batches.
    pub total_us: AtomicU64,
    /// Successfully executed engine batches (after coalescing); failed
    /// batches are counted separately so images/batches/total_us stay
    /// coherent with answered predictions.
    pub batches: AtomicU64,
    /// Batches whose pool execution failed (every coalesced request in
    /// them got an error reply).
    pub failed_batches: AtomicU64,
    /// Requests rejected for a malformed header.
    pub rejected: AtomicU64,
    /// Images currently waiting in the batch queue (gauge).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub queue_peak: AtomicU64,
    /// Histogram of executed batch sizes (log2 buckets).
    pub batch_hist: [AtomicU64; BATCH_BUCKETS],
}

impl Stats {
    /// Histogram bucket for a batch of `n` images: floor(log2 n),
    /// clamped to the last bucket.
    pub fn batch_bucket(n: usize) -> usize {
        let n = n.max(1);
        ((usize::BITS - 1 - n.leading_zeros()) as usize).min(BATCH_BUCKETS - 1)
    }

    /// Record one executed engine batch.
    pub fn observe_batch(&self, n: usize, us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(n as u64, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.batch_hist[Self::batch_bucket(n)].fetch_add(1, Ordering::Relaxed);
    }

    /// Mean images per executed batch (coalescing effectiveness).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.images.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human summary (printed by `aquant serve` and examples).
    pub fn report(&self) -> String {
        let hist: Vec<String> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then(|| format!("{}:{c}", 1usize << i))
            })
            .collect();
        format!(
            "requests {}  images {}  batches {} (mean {:.1} img/batch)  engine {}us  \
             failed {}  rejected {}  queue peak {}  batch-size hist [{}]",
            self.requests.load(Ordering::Relaxed),
            self.images.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.total_us.load(Ordering::Relaxed),
            self.failed_batches.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.queue_peak.load(Ordering::Relaxed),
            hist.join(" "),
        )
    }
}

/// One parsed request waiting to be batched.
struct Pending {
    images: Vec<f32>,
    n: usize,
    reply: mpsc::Sender<Result<Vec<u32>, String>>,
}

#[derive(Default)]
struct QueueState {
    items: VecDeque<Pending>,
    queued_images: usize,
    shutdown: bool,
    /// FIFO admission tickets: `next_ticket` is taken on push arrival,
    /// `serving` is the ticket currently allowed to admit. Without
    /// this, a large request could starve forever behind a stream of
    /// small ones that always win the condvar race.
    next_ticket: u64,
    serving: u64,
}

/// Bounded request queue: connection threads push, the batcher pops
/// coalesced batches. Bounded by *image count*, not request count, so
/// backpressure tracks actual work.
struct BatchQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap_images: usize,
}

impl BatchQueue {
    fn new(cap_images: usize) -> Self {
        BatchQueue {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            // The configured bound is honored as-is: push admits a
            // request larger than the cap only when the queue is empty,
            // so a tight bound can't deadlock a max-size request.
            cap_images,
        }
    }

    /// Block until there is room, then enqueue (FIFO across blocked
    /// pushers — see `QueueState` tickets; while a large request waits,
    /// later arrivals wait behind it, so the queue drains and even an
    /// over-cap request is eventually admitted alone). Returns false if
    /// the server is shutting down (request is dropped).
    fn push(&self, p: Pending, stats: &Stats) -> bool {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        while !st.shutdown
            && (ticket != st.serving
                || (!st.items.is_empty() && st.queued_images + p.n > self.cap_images))
        {
            st = self.not_full.wait(st).unwrap();
        }
        if st.shutdown {
            // Terminal: every other waiter also exits via this branch,
            // so the unconsumed ticket cannot wedge the line.
            return false;
        }
        st.serving += 1;
        st.queued_images += p.n;
        let depth = st.queued_images as u64;
        st.items.push_back(p);
        stats.queue_depth.store(depth, Ordering::Relaxed);
        stats.queue_peak.fetch_max(depth, Ordering::Relaxed);
        drop(st);
        self.not_empty.notify_one();
        // wake the next ticket in line
        self.not_full.notify_all();
        true
    }

    /// Pop a coalesced batch: blocks until at least one request is
    /// queued, then keeps gathering until `max_batch` images are in hand
    /// or `wait` has elapsed. Returns None only when shut down *and*
    /// drained, so no accepted request is ever dropped on the floor.
    fn pop_batch(&self, max_batch: usize, wait: Duration, stats: &Stats) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.items.is_empty() {
                break;
            }
            if st.shutdown {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let mut batch = Vec::new();
        let mut images = 0usize;
        let deadline = Instant::now() + wait;
        loop {
            while let Some(front) = st.items.front() {
                // Always admit the first request, even oversized ones
                // (the pool shards them across workers anyway).
                if !batch.is_empty() && images + front.n > max_batch {
                    break;
                }
                let p = st.items.pop_front().unwrap();
                images += p.n;
                st.queued_images -= p.n;
                batch.push(p);
            }
            // Wake pushers blocked on a full queue *before* the
            // straggler wait: the space just freed lets them enqueue in
            // time to join this very batch (they contend on the mutex
            // released by wait_timeout below).
            self.not_full.notify_all();
            // Items still queued after the drain mean the front didn't
            // fit — the batch can't grow any further, so waiting out the
            // straggler deadline would only add latency.
            if images >= max_batch || st.shutdown || !st.items.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if timeout.timed_out() && st.items.is_empty() {
                break;
            }
        }
        stats
            .queue_depth
            .store(st.queued_images as u64, Ordering::Relaxed);
        drop(st);
        self.not_full.notify_all();
        Some(batch)
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// A bound server: listener + engine + knobs. Splitting bind from run
/// lets callers learn the ephemeral port and grab the stats handle
/// before the (blocking) accept loop starts.
pub struct Server {
    listener: TcpListener,
    engine: Arc<Engine>,
    cfg: ServeConfig,
    stats: Arc<Stats>,
}

impl Server {
    pub fn bind(engine: Arc<Engine>, addr: &str, cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Ok(Server {
            listener,
            engine,
            cfg,
            stats: Arc::new(Stats::default()),
        })
    }

    /// Actual bound address (use after binding port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Live statistics handle, valid before/during/after `run`.
    pub fn stats(&self) -> Arc<Stats> {
        self.stats.clone()
    }

    /// Run the accept loop. Blocks until `cfg.max_conns` connections
    /// have been accepted and completed (or forever when None). All
    /// queued work is drained before returning.
    pub fn run(self) -> Result<()> {
        let workers = self.cfg.resolved_workers();
        let pool = Arc::new(InferencePool::new(self.engine.clone(), workers));
        let queue = Arc::new(BatchQueue::new(self.cfg.queue_images));
        let stats = self.stats.clone();
        println!(
            "aquant-serve: model {} on {} ({} classes, {} workers, max-batch {}, wait {}us)",
            self.engine.topo.name,
            self.local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".into()),
            self.engine.topo.n_classes,
            workers,
            self.cfg.max_batch,
            self.cfg.batch_wait_us,
        );
        // The batcher is a plain (non-scoped) thread over Arc'd state:
        // it must outlive the connection scope below, which joins all
        // handlers before we signal shutdown.
        let batcher = {
            let (q, p, s) = (queue.clone(), pool.clone(), stats.clone());
            let max_batch = self.cfg.max_batch;
            let wait = Duration::from_micros(self.cfg.batch_wait_us);
            std::thread::spawn(move || run_batcher(&q, &p, &s, max_batch, wait))
        };
        let img_elems = self.engine.img_elems();
        let listener_dead = std::thread::scope(|scope| {
            let mut seen = 0usize;
            let mut accept_errs = 0u32;
            if self.cfg.max_conns == Some(0) {
                return false; // "at most 0 connections" means accept none
            }
            for conn in self.listener.incoming() {
                let stream = match conn {
                    Ok(s) => s,
                    Err(e) => {
                        // Transient accept failures (e.g. fd exhaustion
                        // under load) must not kill a long-lived server;
                        // back off briefly and keep accepting. A long
                        // unbroken error streak means the listener is
                        // gone for good — stop (and report it) instead
                        // of spinning.
                        accept_errs += 1;
                        eprintln!("aquant-serve: accept error ({accept_errs} in a row): {e}");
                        if accept_errs >= 1000 {
                            eprintln!("aquant-serve: giving up on accept loop");
                            return true;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                accept_errs = 0;
                let q = queue.clone();
                let s = stats.clone();
                scope.spawn(move || {
                    if let Err(e) = handle(stream, img_elems, &q, &s) {
                        eprintln!("aquant-serve: connection error: {e:#}");
                    }
                });
                seen += 1;
                if let Some(m) = self.cfg.max_conns {
                    if seen >= m {
                        break;
                    }
                }
            }
            false
        });
        // All handlers have returned; drain the queue and stop.
        queue.shutdown();
        batcher
            .join()
            .map_err(|_| anyhow!("batcher thread panicked"))?;
        if listener_dead {
            bail!("accept loop abandoned after repeated listener errors");
        }
        Ok(())
    }
}

fn run_batcher(
    queue: &BatchQueue,
    pool: &InferencePool,
    stats: &Stats,
    max_batch: usize,
    wait: Duration,
) {
    while let Some(mut batch) = queue.pop_batch(max_batch, wait, stats) {
        if batch.is_empty() {
            continue;
        }
        let n: usize = batch.iter().map(|p| p.n).sum();
        let flat = if batch.len() == 1 {
            // Common un-coalesced case: the request's buffer is already
            // flat — move it instead of re-copying the payload.
            std::mem::take(&mut batch[0].images)
        } else {
            let mut flat = Vec::with_capacity(batch.iter().map(|p| p.images.len()).sum());
            for p in &batch {
                flat.extend_from_slice(&p.images);
            }
            flat
        };
        let t0 = Instant::now();
        let result = pool.classify_flat(Arc::new(flat), n);
        match result {
            Ok(preds) => {
                stats.observe_batch(n, t0.elapsed().as_micros() as u64);
                let mut off = 0usize;
                for p in batch {
                    let out: Vec<u32> = preds[off..off + p.n].iter().map(|&c| c as u32).collect();
                    off += p.n;
                    // Receiver gone = connection already died; fine.
                    let _ = p.reply.send(Ok(out));
                }
            }
            Err(e) => {
                stats.failed_batches.fetch_add(1, Ordering::Relaxed);
                let msg = format!("{e:#}");
                for p in batch {
                    let _ = p.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Per-connection loop: parse requests, enqueue, await the batcher's
/// reply, answer. Any protocol error closes just this connection.
fn handle(mut stream: TcpStream, img_elems: usize, queue: &BatchQueue, stats: &Stats) -> Result<()> {
    loop {
        let mut hdr = [0u8; 4];
        match stream.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e.into()),
        }
        let n = u32::from_le_bytes(hdr) as usize;
        if n == 0 || n > MAX_REQ_IMAGES {
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            bail!("bad batch size {n}");
        }
        // Stream the payload in, decoding each chunk straight to f32:
        // allocation tracks bytes actually received (a bare header costs
        // ~64KB here, not the full payload up front), and there is never
        // a second full-size byte buffer alive alongside the floats.
        let total = n * img_elems * 4;
        let mut images: Vec<f32> = Vec::new();
        // chunk size is a multiple of 4, so every slice below is too
        let mut chunk = [0u8; 65536];
        let mut remaining = total;
        while remaining > 0 {
            let want = remaining.min(chunk.len());
            stream.read_exact(&mut chunk[..want])?; // mid-stream EOF lands here
            images.extend(
                chunk[..want]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
            remaining -= want;
        }
        let (rtx, rrx) = mpsc::channel();
        let queued = queue.push(
            Pending {
                images,
                n,
                reply: rtx,
            },
            stats,
        );
        if !queued {
            bail!("server shutting down");
        }
        let preds = match rrx.recv() {
            Ok(Ok(p)) => p,
            Ok(Err(e)) => bail!("inference failed: {e}"),
            Err(_) => bail!("batcher dropped the request"),
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::with_capacity(4 + n * 4);
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for p in preds {
            out.extend_from_slice(&p.to_le_bytes());
        }
        stream.write_all(&out)?;
    }
}

/// Client helper (used by the serve example and tests): one request over
/// a fresh connection.
pub fn classify_remote(addr: &str, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    classify_on(&mut stream, images, n)
}

/// One request/response exchange on an existing connection (clients
/// that pipeline requests reuse the stream).
pub fn classify_on(stream: &mut TcpStream, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(4 + images.len() * 4);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&out)?;
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let m = u32::from_le_bytes(hdr) as usize;
    let mut buf = vec![0u8; m * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(n: usize) -> (Pending, mpsc::Receiver<Result<Vec<u32>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                images: vec![0.0; n],
                n,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn batch_bucket_is_floor_log2() {
        assert_eq!(Stats::batch_bucket(1), 0);
        assert_eq!(Stats::batch_bucket(2), 1);
        assert_eq!(Stats::batch_bucket(3), 1);
        assert_eq!(Stats::batch_bucket(4), 2);
        assert_eq!(Stats::batch_bucket(64), 6);
        assert_eq!(Stats::batch_bucket(4096), 12);
        assert_eq!(Stats::batch_bucket(100_000), BATCH_BUCKETS - 1);
        assert_eq!(Stats::batch_bucket(0), 0); // defensive clamp
    }

    #[test]
    fn stats_observe_and_report() {
        let s = Stats::default();
        s.observe_batch(8, 100);
        s.observe_batch(16, 300);
        assert_eq!(s.images.load(Ordering::Relaxed), 24);
        assert_eq!(s.batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.total_us.load(Ordering::Relaxed), 400);
        assert_eq!(s.batch_hist[3].load(Ordering::Relaxed), 1);
        assert_eq!(s.batch_hist[4].load(Ordering::Relaxed), 1);
        assert_eq!(s.mean_batch(), 12.0);
        let r = s.report();
        assert!(r.contains("batches 2"), "{r}");
        assert!(r.contains("8:1"), "{r}");
        assert!(r.contains("16:1"), "{r}");
    }

    #[test]
    fn queue_coalesces_up_to_max_batch() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (p, rx) = pending(2);
            assert!(q.push(p, &stats));
            rxs.push(rx);
        }
        assert_eq!(stats.queue_peak.load(Ordering::Relaxed), 6);
        // max_batch 4 takes the first two requests (2+2), leaves one
        let batch = q.pop_batch(4, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.iter().map(|p| p.n).sum::<usize>(), 4);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 2);
        let batch = q.pop_batch(4, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queue_admits_oversized_request_alone() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let (p, _rx) = pending(100);
        assert!(q.push(p, &stats));
        let (p2, _rx2) = pending(1);
        assert!(q.push(p2, &stats));
        let batch = q.pop_batch(8, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 1, "oversized request dispatched alone");
        assert_eq!(batch[0].n, 100);
    }

    #[test]
    fn full_queue_blocks_push_until_pop_frees_space() {
        let q = Arc::new(BatchQueue::new(4));
        let stats = Arc::new(Stats::default());
        let (p, _rx1) = pending(4);
        assert!(q.push(p, &stats));
        // the queue is at its image cap: a second push must block on
        // not_full until the batcher drains, then admit via its ticket
        let (p2, _rx2) = pending(3);
        let pusher = {
            let (q, s) = (q.clone(), stats.clone());
            std::thread::spawn(move || q.push(p2, &s))
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push admitted past the image cap");
        // max_batch 4: pop returns right after draining the first item,
        // having woken the blocked pusher mid-loop
        let batch = q.pop_batch(4, Duration::from_millis(500), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 4);
        assert!(pusher.join().unwrap(), "blocked push must admit after the drain");
        let batch = q.pop_batch(4, Duration::from_millis(500), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 3);
    }

    #[test]
    fn queue_drains_after_shutdown_then_ends() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let (p, _rx) = pending(3);
        assert!(q.push(p, &stats));
        q.shutdown();
        // queued work is still delivered...
        let batch = q.pop_batch(64, Duration::from_millis(50), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        // ...then the batcher is told to exit, and pushes are refused
        assert!(q.pop_batch(64, Duration::from_millis(50), &stats).is_none());
        let (p2, _rx2) = pending(1);
        assert!(!q.push(p2, &stats));
    }
}
