//! Quantized-inference server: a small TCP service over the pure-Rust
//! engine (Python never on the request path — the engine runs quantized
//! weights + the border function natively).
//!
//! Wire protocol (little-endian):
//!   request:  u32 n_images, then n·(C·H·W) f32 pixels
//!   response: u32 n_images, then n u32 class ids
//!
//! One thread per connection (std::thread; tokio is unavailable offline).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::nn::engine::Engine;

/// Server statistics.
#[derive(Debug, Default)]
pub struct Stats {
    pub requests: AtomicU64,
    pub images: AtomicU64,
    pub total_us: AtomicU64,
}

/// Serve until the process is killed. `max_conns` bounds accepted
/// connections when Some (used by tests/examples for bounded runs).
pub fn serve(engine: Arc<Engine>, addr: &str, max_conns: Option<usize>) -> Result<Stats> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "aquant-serve: model {} on {addr} ({} classes)",
        engine.topo.name, engine.topo.n_classes
    );
    let stats = Stats::default();
    let stats_ref = &stats;
    std::thread::scope(|scope| -> Result<()> {
        let mut seen = 0usize;
        for conn in listener.incoming() {
            let stream = conn?;
            let eng = engine.clone();
            scope.spawn(move || {
                if let Err(e) = handle(eng, stream, stats_ref) {
                    eprintln!("aquant-serve: connection error: {e:#}");
                }
            });
            seen += 1;
            if let Some(m) = max_conns {
                if seen >= m {
                    break;
                }
            }
        }
        Ok(())
    })?;
    Ok(stats)
}

fn handle(engine: Arc<Engine>, mut stream: TcpStream, stats: &Stats) -> Result<()> {
    let img_elems = {
        let (h, w) = engine.topo.in_hw;
        engine.topo.in_c * h * w
    };
    loop {
        let mut hdr = [0u8; 4];
        match stream.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e.into()),
        }
        let n = u32::from_le_bytes(hdr) as usize;
        if n == 0 || n > 4096 {
            bail!("bad batch size {n}");
        }
        let mut buf = vec![0u8; n * img_elems * 4];
        stream.read_exact(&mut buf)?;
        let images: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let t0 = Instant::now();
        let refs: Vec<&[f32]> = (0..n)
            .map(|i| &images[i * img_elems..(i + 1) * img_elems])
            .collect();
        let preds = engine.classify_batch(&refs)?;
        let us = t0.elapsed().as_micros() as u64;
        stats.requests.fetch_add(1, Ordering::Relaxed);
        stats.images.fetch_add(n as u64, Ordering::Relaxed);
        stats.total_us.fetch_add(us, Ordering::Relaxed);
        let mut out = Vec::with_capacity(4 + n * 4);
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for p in preds {
            out.extend_from_slice(&(p as u32).to_le_bytes());
        }
        stream.write_all(&out)?;
    }
}

/// Client helper (used by the serve example and tests).
pub fn classify_remote(addr: &str, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    let mut out = Vec::with_capacity(4 + images.len() * 4);
    out.extend_from_slice(&(n as u32).to_le_bytes());
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&out)?;
    let mut hdr = [0u8; 4];
    stream.read_exact(&mut hdr)?;
    let m = u32::from_le_bytes(hdr) as usize;
    let mut buf = vec![0u8; m * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}
