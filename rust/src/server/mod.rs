//! Quantized-inference server: multi-model dynamic batching over one
//! shared worker pool (Python never on the request path — engines run
//! quantized weights + the border function natively).
//!
//! # Wire protocol (little-endian)
//!
//! Two request framings share one port; the server byte-sniffs the
//! first 4 bytes of each request:
//!
//! ```text
//!   v1 request:  u32 n_images (1..=4096), then n·(C·H·W) f32 pixels
//!                (routed to model id 0, the default model)
//!   v2 request:  magic "AQSV" | u16 version (=2) | u16 model_id |
//!                u32 n_images (1..=4096), then n·(C·H·W) f32 pixels
//!   response:    u32 n_images, then n u32 class ids   (both versions)
//! ```
//!
//! Sniffing is unambiguous: a v1 header reading "AQSV" would mean
//! n = 0x5653_5141 (≈1.4e9), far beyond the 4096-image protocol cap, so
//! no *valid* v1 request can be mistaken for v2 (pinned by the protocol
//! property tests). A connection may pipeline any number of requests —
//! mixing v1 and v2 freely — and the server answers in order. A request
//! with a bad `n`, an unknown model id, or an unsupported version is
//! rejected by closing the connection (counted in stats); a mid-stream
//! EOF drops only that connection. Either way the accept loop and
//! batchers keep serving other connections.
//!
//! # Architecture
//!
//! ```text
//!   conns (1 thread each, blocking I/O; tokio unavailable offline)
//!     └─ sniff v1/v2 header, resolve model id ──► per-model BatchQueue
//!        push(Pending{images, reply})              (bounded, images-
//!        blocks when full (backpressure)            counted, Mutex+Condvar)
//!                                                    │ pop_batch(max_batch,
//!                                                    │           batch_wait)
//!                                                    ▼
//!                                         one batcher thread per model
//!                  coalesces queued same-model requests — possibly from
//!                  many connections — into one engine-sized batch, then
//!                                                    │ classify_flat(engine)
//!                                                    ▼
//!                                       shared InferencePool (N workers,
//!                                       model-agnostic per-worker scratch)
//! ```
//!
//! Queues and batchers are **per model** so one model's straggler wait
//! never delays another model's traffic; only the worker pool (the
//! actual CPU) is shared. Jobs carry their `Arc<Engine>`, and worker
//! scratch is pre-sized to the registry's max dims, so heterogeneous
//! models reuse the same threads and buffers.
//!
//! Batching cannot change results: every image's forward pass is
//! independent and pooled execution is bit-identical to the sequential
//! engine (see `rust/tests/serve_roundtrip.rs`, `rust/tests/multi_model.rs`
//! and `pool_props.rs`).
//!
//! # Knobs ([`ServeConfig`])
//!
//! * `workers` — inference threads shared by all models (0 = cores − 1)
//! * `max_batch` — images per engine batch; larger amortizes dispatch,
//!   smaller bounds latency
//! * `batch_wait_us` — straggler deadline; 0 = dispatch immediately
//! * `queue_images` — per-model queue bound; a full queue blocks that
//!   model's connection pushes FIFO (TCP backpressure) instead of
//!   growing without limit. Payloads still being received are held
//!   per-connection (streamed in, so allocation tracks bytes actually
//!   read, capped by the 4096-image protocol limit).

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ModelSpec, ServeConfig};
use crate::nn::engine::Engine;
use crate::nn::pool::InferencePool;
use crate::nn::registry::ModelRegistry;

/// Hard protocol cap on images per request.
pub const MAX_REQ_IMAGES: usize = 4096;

/// Protocol v2 magic word ("AQSV"). As a v1 little-endian u32 this
/// reads 0x5653_5141 — far above [`MAX_REQ_IMAGES`] — so byte-sniffing
/// can never misroute a valid v1 request.
pub const MAGIC: [u8; 4] = *b"AQSV";

/// Protocol version this server speaks (and the only one it accepts).
pub const PROTO_VERSION: u16 = 2;

/// Bytes of a v2 request header (magic + version + model id + n).
pub const V2_HEADER_LEN: usize = 12;

/// Batch-size histogram buckets: bucket i counts executed batches with
/// 2^i ..= 2^(i+1)−1 images (last bucket is open-ended at 4096).
pub const BATCH_BUCKETS: usize = 13;

/// One parsed request header, either framing. Framing only — range
/// checks on `n`, version, and model id are the server's job (their
/// rejection stats differ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestHeader {
    V1 { n: u32 },
    V2 { version: u16, model_id: u16, n: u32 },
}

impl RequestHeader {
    /// Images promised by the header.
    pub fn n(&self) -> u32 {
        match *self {
            RequestHeader::V1 { n } | RequestHeader::V2 { n, .. } => n,
        }
    }

    /// Model routing: v1 clients always hit the default model (id 0).
    pub fn model_id(&self) -> u16 {
        match *self {
            RequestHeader::V1 { .. } => 0,
            RequestHeader::V2 { model_id, .. } => model_id,
        }
    }

    /// Wire bytes for this header (v1: 4 bytes; v2: 12 bytes). Encoding
    /// preserves an arbitrary `version` so tests can round-trip
    /// unsupported versions too.
    pub fn encode(&self) -> Vec<u8> {
        match *self {
            RequestHeader::V1 { n } => n.to_le_bytes().to_vec(),
            RequestHeader::V2 {
                version,
                model_id,
                n,
            } => {
                let mut out = Vec::with_capacity(V2_HEADER_LEN);
                out.extend_from_slice(&MAGIC);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&model_id.to_le_bytes());
                out.extend_from_slice(&n.to_le_bytes());
                out
            }
        }
    }
}

/// Encode a v2 header with the current [`PROTO_VERSION`].
pub fn encode_header_v2(model_id: u16, n: u32) -> [u8; V2_HEADER_LEN] {
    let mut out = [0u8; V2_HEADER_LEN];
    out[..4].copy_from_slice(&MAGIC);
    out[4..6].copy_from_slice(&PROTO_VERSION.to_le_bytes());
    out[6..8].copy_from_slice(&model_id.to_le_bytes());
    out[8..12].copy_from_slice(&n.to_le_bytes());
    out
}

/// Read one request header, sniffing v1 vs v2 from the first 4 bytes.
/// `Ok(None)` = clean EOF before a request started (pipelined
/// connection done). EOF *inside* a v2 header is a truncated frame and
/// surfaces as `Err(UnexpectedEof)`.
pub fn read_request_header(stream: &mut impl Read) -> std::io::Result<Option<RequestHeader>> {
    let mut first = [0u8; 4];
    match stream.read_exact(&mut first) {
        Ok(()) => {}
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    if first == MAGIC {
        let mut rest = [0u8; V2_HEADER_LEN - 4];
        stream.read_exact(&mut rest)?;
        Ok(Some(RequestHeader::V2 {
            version: u16::from_le_bytes([rest[0], rest[1]]),
            model_id: u16::from_le_bytes([rest[2], rest[3]]),
            n: u32::from_le_bytes([rest[4], rest[5], rest[6], rest[7]]),
        }))
    } else {
        Ok(Some(RequestHeader::V1 {
            n: u32::from_le_bytes(first),
        }))
    }
}

/// Per-model server statistics, shared up front via `Arc` so a
/// long-lived server can be observed while running.
#[derive(Debug, Default)]
pub struct Stats {
    /// Completed (answered) requests.
    pub requests: AtomicU64,
    /// Images executed through the engine (counted at batch execution,
    /// so live reads and `mean_batch` stay coherent).
    pub images: AtomicU64,
    /// Engine time (µs) summed over executed batches.
    pub total_us: AtomicU64,
    /// Successfully executed engine batches (after coalescing); failed
    /// batches are counted separately so images/batches/total_us stay
    /// coherent with answered predictions.
    pub batches: AtomicU64,
    /// Batches whose pool execution failed (every coalesced request in
    /// them got an error reply).
    pub failed_batches: AtomicU64,
    /// Requests rejected for a malformed header (bad `n`) after this
    /// model was resolved.
    pub rejected: AtomicU64,
    /// Images currently waiting in this model's batch queue (gauge).
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub queue_peak: AtomicU64,
    /// Histogram of executed batch sizes (log2 buckets).
    pub batch_hist: [AtomicU64; BATCH_BUCKETS],
}

impl Stats {
    /// Histogram bucket for a batch of `n` images: floor(log2 n),
    /// clamped to the last bucket.
    pub fn batch_bucket(n: usize) -> usize {
        let n = n.max(1);
        ((usize::BITS - 1 - n.leading_zeros()) as usize).min(BATCH_BUCKETS - 1)
    }

    /// Record one executed engine batch.
    pub fn observe_batch(&self, n: usize, us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.images.fetch_add(n as u64, Ordering::Relaxed);
        self.total_us.fetch_add(us, Ordering::Relaxed);
        self.batch_hist[Self::batch_bucket(n)].fetch_add(1, Ordering::Relaxed);
    }

    /// Mean images per executed batch (coalescing effectiveness).
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.images.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// One-line human summary for this model.
    pub fn report(&self) -> String {
        let hist: Vec<String> = self
            .batch_hist
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let c = c.load(Ordering::Relaxed);
                (c > 0).then(|| format!("{}:{c}", 1usize << i))
            })
            .collect();
        format!(
            "requests {}  images {}  batches {} (mean {:.1} img/batch)  engine {}us  \
             failed {}  rejected {}  queue peak {}  batch-size hist [{}]",
            self.requests.load(Ordering::Relaxed),
            self.images.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.total_us.load(Ordering::Relaxed),
            self.failed_batches.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.queue_peak.load(Ordering::Relaxed),
            hist.join(" "),
        )
    }
}

/// All of a server's statistics: one [`Stats`] per hosted model
/// (indexed by model id) plus server-level counters for requests that
/// failed before any model was resolved.
#[derive(Debug)]
pub struct ServerStats {
    names: Vec<String>,
    models: Vec<Arc<Stats>>,
    /// v2 requests naming a model id outside the registry.
    pub unknown_model: AtomicU64,
    /// v2 requests with a version this server doesn't speak.
    pub bad_version: AtomicU64,
}

impl ServerStats {
    fn new(registry: &ModelRegistry) -> Self {
        ServerStats {
            names: registry.iter().map(|(_, e)| e.name.clone()).collect(),
            models: registry.iter().map(|_| Arc::new(Stats::default())).collect(),
            unknown_model: AtomicU64::new(0),
            bad_version: AtomicU64::new(0),
        }
    }

    /// Stats for one model id.
    pub fn model(&self, id: u16) -> Option<&Arc<Stats>> {
        self.models.get(id as usize)
    }

    /// Stats for the default (v1-compat) model.
    pub fn default_model(&self) -> &Arc<Stats> {
        &self.models[0]
    }

    /// Hosted model count.
    pub fn n_models(&self) -> usize {
        self.models.len()
    }

    /// Sum of answered requests across models.
    pub fn total_requests(&self) -> u64 {
        self.models
            .iter()
            .map(|s| s.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Sum of executed images across models.
    pub fn total_images(&self) -> u64 {
        self.models
            .iter()
            .map(|s| s.images.load(Ordering::Relaxed))
            .sum()
    }

    /// Sum of rejected requests: per-model bad-`n` rejections plus the
    /// server-level unknown-model / bad-version rejections.
    pub fn total_rejected(&self) -> u64 {
        self.models
            .iter()
            .map(|s| s.rejected.load(Ordering::Relaxed))
            .sum::<u64>()
            + self.unknown_model.load(Ordering::Relaxed)
            + self.bad_version.load(Ordering::Relaxed)
    }

    /// Multi-line human summary: one line per model + server counters.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (i, (name, s)) in self.names.iter().zip(&self.models).enumerate() {
            out.push_str(&format!("model {i} {name}: {}\n", s.report()));
        }
        out.push_str(&format!(
            "server: unknown-model {}  bad-version {}",
            self.unknown_model.load(Ordering::Relaxed),
            self.bad_version.load(Ordering::Relaxed),
        ));
        out
    }
}

/// One parsed request waiting to be batched.
struct Pending {
    images: Vec<f32>,
    n: usize,
    reply: mpsc::Sender<Result<Vec<u32>, String>>,
}

#[derive(Default)]
struct QueueState {
    items: VecDeque<Pending>,
    queued_images: usize,
    shutdown: bool,
    /// FIFO admission tickets: `next_ticket` is taken on push arrival,
    /// `serving` is the ticket currently allowed to admit. Without
    /// this, a large request could starve forever behind a stream of
    /// small ones that always win the condvar race.
    next_ticket: u64,
    serving: u64,
}

/// Bounded request queue: connection threads push, the model's batcher
/// pops coalesced batches. Bounded by *image count*, not request count,
/// so backpressure tracks actual work. One queue per hosted model —
/// straggler waits are per model, never cross-model.
struct BatchQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap_images: usize,
}

impl BatchQueue {
    fn new(cap_images: usize) -> Self {
        BatchQueue {
            state: Mutex::new(QueueState::default()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            // The configured bound is honored as-is: push admits a
            // request larger than the cap only when the queue is empty,
            // so a tight bound can't deadlock a max-size request.
            cap_images,
        }
    }

    /// Block until there is room, then enqueue (FIFO across blocked
    /// pushers — see `QueueState` tickets; while a large request waits,
    /// later arrivals wait behind it, so the queue drains and even an
    /// over-cap request is eventually admitted alone). Returns false if
    /// the server is shutting down (request is dropped).
    fn push(&self, p: Pending, stats: &Stats) -> bool {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        while !st.shutdown
            && (ticket != st.serving
                || (!st.items.is_empty() && st.queued_images + p.n > self.cap_images))
        {
            st = self.not_full.wait(st).unwrap();
        }
        if st.shutdown {
            // Terminal: every other waiter also exits via this branch,
            // so the unconsumed ticket cannot wedge the line.
            return false;
        }
        st.serving += 1;
        st.queued_images += p.n;
        let depth = st.queued_images as u64;
        st.items.push_back(p);
        stats.queue_depth.store(depth, Ordering::Relaxed);
        stats.queue_peak.fetch_max(depth, Ordering::Relaxed);
        drop(st);
        self.not_empty.notify_one();
        // wake the next ticket in line
        self.not_full.notify_all();
        true
    }

    /// Pop a coalesced batch: blocks until at least one request is
    /// queued, then keeps gathering until `max_batch` images are in hand
    /// or `wait` has elapsed. Returns None only when shut down *and*
    /// drained, so no accepted request is ever dropped on the floor.
    fn pop_batch(&self, max_batch: usize, wait: Duration, stats: &Stats) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.items.is_empty() {
                break;
            }
            if st.shutdown {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
        let mut batch = Vec::new();
        let mut images = 0usize;
        let deadline = Instant::now() + wait;
        loop {
            while let Some(front) = st.items.front() {
                // Always admit the first request, even oversized ones
                // (the pool shards them across workers anyway).
                if !batch.is_empty() && images + front.n > max_batch {
                    break;
                }
                let p = st.items.pop_front().unwrap();
                images += p.n;
                st.queued_images -= p.n;
                batch.push(p);
            }
            // Wake pushers blocked on a full queue *before* the
            // straggler wait: the space just freed lets them enqueue in
            // time to join this very batch (they contend on the mutex
            // released by wait_timeout below).
            self.not_full.notify_all();
            // Items still queued after the drain mean the front didn't
            // fit — the batch can't grow any further, so waiting out the
            // straggler deadline would only add latency.
            if images >= max_batch || st.shutdown || !st.items.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timeout) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
            if timeout.timed_out() && st.items.is_empty() {
                break;
            }
        }
        stats
            .queue_depth
            .store(st.queued_images as u64, Ordering::Relaxed);
        drop(st);
        self.not_full.notify_all();
        Some(batch)
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Everything a connection handler needs to route one request.
struct Router {
    registry: Arc<ModelRegistry>,
    /// One queue per model, indexed by model id.
    queues: Vec<Arc<BatchQueue>>,
    stats: Arc<ServerStats>,
}

/// A bound server: listener + model registry + knobs. Splitting bind
/// from run lets callers learn the ephemeral port and grab the stats
/// handle before the (blocking) accept loop starts.
pub struct Server {
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    stats: Arc<ServerStats>,
}

impl Server {
    /// Bind a multi-model server. Registry id 0 is the default model
    /// serving protocol-v1 clients.
    pub fn bind(registry: Arc<ModelRegistry>, addr: &str, cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let stats = Arc::new(ServerStats::new(&registry));
        Ok(Server {
            listener,
            registry,
            cfg,
            stats,
        })
    }

    /// Bind a single-model server (the pre-v2 shape): wraps the engine
    /// in a one-entry registry named after its topology.
    pub fn bind_single(engine: Arc<Engine>, addr: &str, cfg: ServeConfig) -> Result<Server> {
        Server::bind(Arc::new(ModelRegistry::single(engine)?), addr, cfg)
    }

    /// Actual bound address (use after binding port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Live statistics handle, valid before/during/after `run`.
    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    /// The hosted models.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Run the accept loop. Blocks until `cfg.max_conns` connections
    /// have been accepted and completed (or forever when None). All
    /// queued work is drained before returning.
    pub fn run(self) -> Result<()> {
        let workers = self.cfg.resolved_workers();
        let pool = Arc::new(InferencePool::with_scratch_dims(
            workers,
            self.registry.scratch_dims(),
        ));
        let addr = self
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        println!(
            "aquant-serve: {} model(s) on {addr} ({} workers, max-batch {}, wait {}us)",
            self.registry.len(),
            workers,
            self.cfg.max_batch,
            self.cfg.batch_wait_us,
        );
        // Per-model queue + batcher. Batchers are plain (non-scoped)
        // threads over Arc'd state: they must outlive the connection
        // scope below, which joins all handlers before we signal
        // shutdown.
        let mut queues = Vec::with_capacity(self.registry.len());
        let mut batchers = Vec::with_capacity(self.registry.len());
        for (id, entry) in self.registry.iter() {
            println!(
                "aquant-serve:   id {id} = {} ({} f32/img, {} classes)",
                entry.name,
                entry.engine.img_elems(),
                entry.engine.topo.n_classes,
            );
            let queue = Arc::new(BatchQueue::new(self.cfg.queue_images));
            let (q, p, e) = (queue.clone(), pool.clone(), entry.engine.clone());
            let s = self.stats.model(id).expect("stats per model").clone();
            let max_batch = self.cfg.max_batch;
            let wait = Duration::from_micros(self.cfg.batch_wait_us);
            batchers.push(std::thread::spawn(move || {
                run_batcher(&q, &p, &e, &s, max_batch, wait)
            }));
            queues.push(queue);
        }
        let router = Router {
            registry: self.registry.clone(),
            queues,
            stats: self.stats.clone(),
        };
        let listener_dead = std::thread::scope(|scope| {
            let mut seen = 0usize;
            let mut accept_errs = 0u32;
            if self.cfg.max_conns == Some(0) {
                return false; // "at most 0 connections" means accept none
            }
            for conn in self.listener.incoming() {
                let stream = match conn {
                    Ok(s) => s,
                    Err(e) => {
                        // Transient accept failures (e.g. fd exhaustion
                        // under load) must not kill a long-lived server;
                        // back off briefly and keep accepting. A long
                        // unbroken error streak means the listener is
                        // gone for good — stop (and report it) instead
                        // of spinning.
                        accept_errs += 1;
                        eprintln!("aquant-serve: accept error ({accept_errs} in a row): {e}");
                        if accept_errs >= 1000 {
                            eprintln!("aquant-serve: giving up on accept loop");
                            return true;
                        }
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    }
                };
                accept_errs = 0;
                let r = &router;
                scope.spawn(move || {
                    if let Err(e) = handle(stream, r) {
                        eprintln!("aquant-serve: connection error: {e:#}");
                    }
                });
                seen += 1;
                if let Some(m) = self.cfg.max_conns {
                    if seen >= m {
                        break;
                    }
                }
            }
            false
        });
        // All handlers have returned; drain every queue and stop.
        for q in &router.queues {
            q.shutdown();
        }
        for b in batchers {
            b.join().map_err(|_| anyhow!("batcher thread panicked"))?;
        }
        if listener_dead {
            bail!("accept loop abandoned after repeated listener errors");
        }
        Ok(())
    }
}

/// Build a [`ModelRegistry`] from parsed `--model` specs with the
/// build-appropriate manifest path: quantized engines via PJRT
/// calibration when the `pjrt` feature is on, full-precision
/// `nearest:W32A32` loading otherwise (synthetic specs are pure Rust in
/// both). This is the single entry point `aquant serve` and
/// `examples/serve.rs` share — `iters`/`verbose` only affect
/// calibration and are ignored in non-pjrt builds.
#[cfg(feature = "pjrt")]
pub fn registry_from_specs(
    specs: &[ModelSpec],
    artifacts_dir: &str,
    iters: Option<u32>,
    verbose: bool,
) -> Result<ModelRegistry> {
    let mut qb = crate::exp::cell::QuantManifestBuilder::new(artifacts_dir, iters, verbose);
    ModelRegistry::from_specs(specs, |spec| qb.build(spec))
}

/// See the `pjrt` variant above; without the feature, manifest specs
/// are served full-precision via [`crate::nn::loader::FpManifestBuilder`].
#[cfg(not(feature = "pjrt"))]
pub fn registry_from_specs(
    specs: &[ModelSpec],
    artifacts_dir: &str,
    _iters: Option<u32>,
    _verbose: bool,
) -> Result<ModelRegistry> {
    let mut fp = crate::nn::loader::FpManifestBuilder::new(artifacts_dir);
    ModelRegistry::from_specs(specs, |spec| fp.build(spec))
}

fn run_batcher(
    queue: &BatchQueue,
    pool: &InferencePool,
    engine: &Arc<Engine>,
    stats: &Stats,
    max_batch: usize,
    wait: Duration,
) {
    while let Some(mut batch) = queue.pop_batch(max_batch, wait, stats) {
        if batch.is_empty() {
            continue;
        }
        let n: usize = batch.iter().map(|p| p.n).sum();
        let flat = if batch.len() == 1 {
            // Common un-coalesced case: the request's buffer is already
            // flat — move it instead of re-copying the payload.
            std::mem::take(&mut batch[0].images)
        } else {
            let mut flat = Vec::with_capacity(batch.iter().map(|p| p.images.len()).sum());
            for p in &batch {
                flat.extend_from_slice(&p.images);
            }
            flat
        };
        let t0 = Instant::now();
        let result = pool.classify_flat(engine, Arc::new(flat), n);
        match result {
            Ok(preds) => {
                stats.observe_batch(n, t0.elapsed().as_micros() as u64);
                let mut off = 0usize;
                for p in batch {
                    let out: Vec<u32> = preds[off..off + p.n].iter().map(|&c| c as u32).collect();
                    off += p.n;
                    // Receiver gone = connection already died; fine.
                    let _ = p.reply.send(Ok(out));
                }
            }
            Err(e) => {
                stats.failed_batches.fetch_add(1, Ordering::Relaxed);
                let msg = format!("{e:#}");
                for p in batch {
                    let _ = p.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Per-connection loop: sniff + parse requests, route to the model's
/// queue, await the batcher's reply, answer. Any protocol error closes
/// just this connection.
fn handle(mut stream: TcpStream, router: &Router) -> Result<()> {
    loop {
        let hdr = match read_request_header(&mut stream) {
            Ok(None) => return Ok(()),
            Ok(Some(h)) => h,
            Err(e) => return Err(e.into()),
        };
        if let RequestHeader::V2 { version, .. } = hdr {
            if version != PROTO_VERSION {
                router.stats.bad_version.fetch_add(1, Ordering::Relaxed);
                bail!("unsupported protocol version {version}");
            }
        }
        let model_id = hdr.model_id();
        let Some(entry) = router.registry.get(model_id) else {
            router.stats.unknown_model.fetch_add(1, Ordering::Relaxed);
            bail!("unknown model id {model_id}");
        };
        let stats = router.stats.model(model_id).expect("stats per model");
        let queue = &router.queues[model_id as usize];
        let n = hdr.n() as usize;
        if n == 0 || n > MAX_REQ_IMAGES {
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            bail!("bad batch size {n}");
        }
        let img_elems = entry.engine.img_elems();
        // Stream the payload in, decoding each chunk straight to f32:
        // allocation tracks bytes actually received (a bare header costs
        // ~64KB here, not the full payload up front), and there is never
        // a second full-size byte buffer alive alongside the floats.
        let total = n * img_elems * 4;
        let mut images: Vec<f32> = Vec::new();
        // chunk size is a multiple of 4, so every slice below is too
        let mut chunk = [0u8; 65536];
        let mut remaining = total;
        while remaining > 0 {
            let want = remaining.min(chunk.len());
            stream.read_exact(&mut chunk[..want])?; // mid-stream EOF lands here
            images.extend(
                chunk[..want]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
            remaining -= want;
        }
        let (rtx, rrx) = mpsc::channel();
        let queued = queue.push(
            Pending {
                images,
                n,
                reply: rtx,
            },
            stats,
        );
        if !queued {
            bail!("server shutting down");
        }
        let preds = match rrx.recv() {
            Ok(Ok(p)) => p,
            Ok(Err(e)) => bail!("inference failed: {e}"),
            Err(_) => bail!("batcher dropped the request"),
        };
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::with_capacity(4 + n * 4);
        out.extend_from_slice(&(n as u32).to_le_bytes());
        for p in preds {
            out.extend_from_slice(&p.to_le_bytes());
        }
        stream.write_all(&out)?;
    }
}

/// Client helper (used by the serve example and tests): one v1 request
/// over a fresh connection (answered by the default model).
pub fn classify_remote(addr: &str, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    classify_on(&mut stream, images, n)
}

/// One v2 request over a fresh connection, routed to `model_id`.
pub fn classify_remote_v2(addr: &str, model_id: u16, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let mut stream = TcpStream::connect(addr)?;
    classify_on_v2(&mut stream, model_id, images, n)
}

/// One v1 request/response exchange on an existing connection (clients
/// that pipeline requests reuse the stream).
pub fn classify_on(stream: &mut TcpStream, images: &[f32], n: usize) -> Result<Vec<u32>> {
    let hdr = (n as u32).to_le_bytes();
    exchange(stream, &hdr, images)
}

/// One v2 request/response exchange on an existing connection. v1 and
/// v2 requests may be interleaved freely on one stream.
pub fn classify_on_v2(
    stream: &mut TcpStream,
    model_id: u16,
    images: &[f32],
    n: usize,
) -> Result<Vec<u32>> {
    let hdr = encode_header_v2(model_id, n as u32);
    exchange(stream, &hdr, images)
}

fn exchange(stream: &mut TcpStream, hdr: &[u8], images: &[f32]) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(hdr.len() + images.len() * 4);
    out.extend_from_slice(hdr);
    for v in images {
        out.extend_from_slice(&v.to_le_bytes());
    }
    stream.write_all(&out)?;
    let mut rhdr = [0u8; 4];
    stream.read_exact(&mut rhdr)?;
    let m = u32::from_le_bytes(rhdr) as usize;
    let mut buf = vec![0u8; m * 4];
    stream.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(n: usize) -> (Pending, mpsc::Receiver<Result<Vec<u32>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                images: vec![0.0; n],
                n,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn batch_bucket_is_floor_log2() {
        assert_eq!(Stats::batch_bucket(1), 0);
        assert_eq!(Stats::batch_bucket(2), 1);
        assert_eq!(Stats::batch_bucket(3), 1);
        assert_eq!(Stats::batch_bucket(4), 2);
        assert_eq!(Stats::batch_bucket(64), 6);
        assert_eq!(Stats::batch_bucket(4096), 12);
        assert_eq!(Stats::batch_bucket(100_000), BATCH_BUCKETS - 1);
        assert_eq!(Stats::batch_bucket(0), 0); // defensive clamp
    }

    #[test]
    fn stats_observe_and_report() {
        let s = Stats::default();
        s.observe_batch(8, 100);
        s.observe_batch(16, 300);
        assert_eq!(s.images.load(Ordering::Relaxed), 24);
        assert_eq!(s.batches.load(Ordering::Relaxed), 2);
        assert_eq!(s.total_us.load(Ordering::Relaxed), 400);
        assert_eq!(s.batch_hist[3].load(Ordering::Relaxed), 1);
        assert_eq!(s.batch_hist[4].load(Ordering::Relaxed), 1);
        assert_eq!(s.mean_batch(), 12.0);
        let r = s.report();
        assert!(r.contains("batches 2"), "{r}");
        assert!(r.contains("8:1"), "{r}");
        assert!(r.contains("16:1"), "{r}");
    }

    #[test]
    fn header_v1_roundtrip() {
        let h = RequestHeader::V1 { n: 77 };
        let bytes = h.encode();
        assert_eq!(bytes.len(), 4);
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 0);
        assert_eq!(got.n(), 77);
    }

    #[test]
    fn header_v2_roundtrip() {
        let h = RequestHeader::V2 {
            version: PROTO_VERSION,
            model_id: 3,
            n: 4096,
        };
        let bytes = h.encode();
        assert_eq!(bytes.len(), V2_HEADER_LEN);
        assert_eq!(&bytes[..4], &MAGIC);
        assert_eq!(bytes[..], encode_header_v2(3, 4096)[..]);
        let got = read_request_header(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(got, h);
        assert_eq!(got.model_id(), 3);
    }

    #[test]
    fn header_eof_and_truncation() {
        // empty stream = clean end of connection
        assert_eq!(read_request_header(&mut std::io::empty()).unwrap(), None);
        // EOF inside the 4-byte sniff window also reads as clean end
        // (matches the pre-v2 server's header handling)
        assert_eq!(read_request_header(&mut &MAGIC[..2]).unwrap(), None);
        // but EOF after a complete magic word is a truncated v2 frame
        let full = encode_header_v2(1, 5);
        for cut in 4..V2_HEADER_LEN {
            let err = read_request_header(&mut &full[..cut]).unwrap_err();
            assert_eq!(err.kind(), ErrorKind::UnexpectedEof, "cut={cut}");
        }
    }

    #[test]
    fn magic_cannot_be_a_valid_v1_header() {
        let as_v1 = u32::from_le_bytes(MAGIC) as usize;
        assert!(as_v1 > MAX_REQ_IMAGES, "sniffing would be ambiguous");
    }

    #[test]
    fn queue_coalesces_up_to_max_batch() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (p, rx) = pending(2);
            assert!(q.push(p, &stats));
            rxs.push(rx);
        }
        assert_eq!(stats.queue_peak.load(Ordering::Relaxed), 6);
        // max_batch 4 takes the first two requests (2+2), leaves one
        let batch = q.pop_batch(4, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.iter().map(|p| p.n).sum::<usize>(), 4);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 2);
        let batch = q.pop_batch(4, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queue_admits_oversized_request_alone() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let (p, _rx) = pending(100);
        assert!(q.push(p, &stats));
        let (p2, _rx2) = pending(1);
        assert!(q.push(p2, &stats));
        let batch = q.pop_batch(8, Duration::ZERO, &stats).unwrap();
        assert_eq!(batch.len(), 1, "oversized request dispatched alone");
        assert_eq!(batch[0].n, 100);
    }

    #[test]
    fn full_queue_blocks_push_until_pop_frees_space() {
        let q = Arc::new(BatchQueue::new(4));
        let stats = Arc::new(Stats::default());
        let (p, _rx1) = pending(4);
        assert!(q.push(p, &stats));
        // the queue is at its image cap: a second push must block on
        // not_full until the batcher drains, then admit via its ticket
        let (p2, _rx2) = pending(3);
        let pusher = {
            let (q, s) = (q.clone(), stats.clone());
            std::thread::spawn(move || q.push(p2, &s))
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push admitted past the image cap");
        // max_batch 4: pop returns right after draining the first item,
        // having woken the blocked pusher mid-loop
        let batch = q.pop_batch(4, Duration::from_millis(500), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 4);
        assert!(pusher.join().unwrap(), "blocked push must admit after the drain");
        let batch = q.pop_batch(4, Duration::from_millis(500), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 3);
    }

    #[test]
    fn queue_drains_after_shutdown_then_ends() {
        let q = BatchQueue::new(MAX_REQ_IMAGES);
        let stats = Stats::default();
        let (p, _rx) = pending(3);
        assert!(q.push(p, &stats));
        q.shutdown();
        // queued work is still delivered...
        let batch = q.pop_batch(64, Duration::from_millis(50), &stats).unwrap();
        assert_eq!(batch.len(), 1);
        // ...then the batcher is told to exit, and pushes are refused
        assert!(q.pop_batch(64, Duration::from_millis(50), &stats).is_none());
        let (p2, _rx2) = pending(1);
        assert!(!q.push(p2, &stats));
    }
}
