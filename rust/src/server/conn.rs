//! Event-driven connection I/O: ONE readiness loop owns every client
//! socket in non-blocking mode, replacing the thread-per-connection
//! accept loop. Connection counts now cost a few hundred bytes of
//! state each instead of a stack + thread, which is what lets the
//! serving tier hold thousands of mostly-idle clients in front of the
//! PR 4 scheduler.
//!
//! # Shape
//!
//! ```text
//!   Poller (epoll / poll(2), util::poll) ── readiness ──► EventLoop
//!     token 0: listener   → accept (reject over --max-conns)
//!     token 1: self-pipe  → pool completions rang; flush responses,
//!                           retry queue-parked requests
//!     token n: connection → per-connection state machine:
//!                           header sniff v1/v2 → streamed f32 payload
//!                           → try_push to the model's BatchQueue
//!                           → (in-order) reply staging → partial-write
//!                           flush
//!     token u64::MAX: stats listener (--stats-addr, optional)
//!     token 2^48+n:   stats connection → read HTTP head → snapshot →
//!                           one-shot response → close
//!     token u64::MAX-1: admin listener (--admin-addr, optional)
//!     token 2^49+n:   admin connection → line-oriented control
//!                           protocol → ControlPlane::apply_line →
//!                           one reply line per command
//! ```
//!
//! The BatchQueue / FairScheduler / InferencePool seam is untouched:
//! the loop pushes the same `Pending`s the blocking handlers did, and
//! completions travel the same per-request channel — the only addition
//! is that [`super::sched::ReplySink`] rings this loop's
//! [`crate::util::poll::Waker`] so a completion interrupts the kernel
//! wait.
//!
//! # Invariants
//!
//! * **Bit-identical serving**: request decode, validation order,
//!   rejection stats, and response encoding are byte-for-byte the
//!   blocking server's; per-connection responses go out in request
//!   order (pipelined requests may now *execute* concurrently, but
//!   every image's forward pass is independent, so results cannot
//!   change — pinned by the unchanged integration suites).
//! * **Bounded buffers**: payloads decode straight into the request's
//!   `Vec<f32>` (allocation tracks the validated `n`, capped by the
//!   4096-image protocol limit); staged responses stop being pulled
//!   from their channels past [`WRITE_BUF_SOFT_CAP`] so a non-reading
//!   client cannot balloon the write buffer.
//! * **Backpressure without blocking**: a full model queue parks the
//!   connection (read interest off — the kernel's receive window takes
//!   over) instead of blocking the loop. Liveness: a full queue is
//!   non-empty, the scheduler must eventually pop it (fill or straggler
//!   deadline), every popped batch ends in a completion, and every
//!   completion rings the waker, which retries parked connections.
//! * **A dead client poisons nothing**: response writes are
//!   non-blocking with partial-write carry; `EPIPE`/reset closes that
//!   connection only, and batch completions to dropped receivers are
//!   no-ops.
//! * **Observability never blocks serving**: stats connections live in
//!   their own token space and slab, are capped at
//!   [`MAX_STATS_CONNS`], expire on a fixed deadline, and do not count
//!   toward `--max-conns` / `--max-accepts` or the bounded-run exit
//!   condition. A stats request is answered from a point-in-time
//!   [`Snapshot`] of the same atomics the serving path already
//!   updates — no lock is shared with request handling, and a wedged
//!   or malicious stats client costs one slab slot for ten seconds,
//!   nothing more.
//!
//! Per wakeup the loop sweeps all live connections for reply/park
//! progress — O(open conns), fine into the thousands this tier
//! targets; a dirty-list is the known next step beyond that (see
//! ROADMAP).

use std::collections::VecDeque;
use std::io::{self, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::poll::{Event, Interest, Poller, Waker};

use super::metrics::{self, Snapshot, StatsParse, MAX_STATS_REQUEST};
use super::reload::{ControlPlane, EpochState};
use super::route;
use super::sched::{Doorbell, Pending, ReplySink, TryPush};
use super::{
    RequestHeader, ServerStats, DESC_HEADER_LEN, MAGIC, MAGIC_DESC, MAX_REQ_IMAGES, PROTO_VERSION,
    V2_HEADER_LEN,
};

/// Stop staging completed replies into a connection's write buffer past
/// this many unflushed bytes; they wait in their channels instead (the
/// data exists either way — this just caps the copy).
const WRITE_BUF_SOFT_CAP: usize = 256 * 1024;

/// Largest single read. Payload reads use it whole; header reads are
/// exact-sized (≤ 12 bytes), so one read can never span a request
/// boundary and parking needs no stash buffer.
const READ_CHUNK: usize = 64 * 1024;

/// Reads per connection per readiness event before yielding back to the
/// loop (level-triggered polling re-reports leftover data), so one
/// fire-hose sender cannot starve its neighbours.
const READ_BUDGET: usize = 8;

/// Concurrent stats connections. Observability is strictly
/// best-effort: past the cap new stats clients are accepted and
/// dropped rather than queued, so a scrape storm cannot grow loop
/// state. Serving connections have their own (configurable) cap.
const MAX_STATS_CONNS: usize = 32;

/// Hard wall-clock lifetime of one stats connection, request to close.
/// Stats requests are one tiny read + one bounded write; anything
/// still open after this long is a stuck scraper and gets reclaimed.
const STATS_CONN_TIMEOUT: Duration = Duration::from_secs(10);

// ---------------------------------------------------------------------
// Incremental request decoder (pure; fuzzed by proto_props.rs)
// ---------------------------------------------------------------------

/// What one [`RequestDecoder::feed`] produced.
#[derive(Debug, PartialEq)]
pub enum Decoded {
    /// Everything consumed, nothing completed yet.
    NeedMore,
    /// A full header arrived. The caller must validate it and either
    /// call [`RequestDecoder::begin_payload`] or abandon the stream —
    /// until then [`RequestDecoder::want`] is 0 and `feed` is a no-op.
    Header(RequestHeader),
    /// The in-progress request's payload completed.
    Request {
        header: RequestHeader,
        images: Vec<f32>,
    },
    /// The in-progress request completed in raw (forwarding) mode:
    /// `frame` is the FULL wire frame — header bytes re-encoded
    /// byte-exactly plus payload bytes exactly as received — ready to
    /// append to a backend connection with zero recompute.
    RequestRaw {
        header: RequestHeader,
        frame: Vec<u8>,
    },
}

enum DecodeState {
    /// Accumulating header bytes. `need` is 4 for the sniff window and
    /// grows to [`V2_HEADER_LEN`] once the magic word appears.
    Header {
        buf: [u8; V2_HEADER_LEN],
        got: usize,
        need: usize,
    },
    /// Header emitted; waiting for the caller's verdict.
    Gate(RequestHeader),
    /// Streaming payload bytes, decoding to f32 as they arrive. `carry`
    /// holds a split f32 across short reads.
    Payload {
        header: RequestHeader,
        images: Vec<f32>,
        /// Raw payload bytes still expected.
        remaining: usize,
        carry: [u8; 4],
        carry_len: usize,
    },
    /// Streaming payload bytes verbatim into a forwardable frame
    /// (router mode): `frame` was pre-seeded with the header's exact
    /// wire bytes so completion hands back one contiguous frame.
    PayloadRaw {
        header: RequestHeader,
        frame: Vec<u8>,
        /// Raw payload bytes still expected.
        remaining: usize,
    },
}

/// Incremental decoder for the wire protocol: the streaming counterpart
/// of [`super::read_request_header`] plus payload accumulation, driven
/// by whatever byte slices the socket yields. Framing only — range
/// checks on `n` / version / model id stay the server's job (their
/// rejection stats differ), which is why a parsed header gates payload
/// streaming on an explicit [`RequestDecoder::begin_payload`].
///
/// Panic-free and allocation-bounded for ARBITRARY input: garbage bytes
/// parse as a (v1) header whose `n` the server then rejects; payload
/// allocation happens only after the caller accepted the header. Pinned
/// by the fuzz properties in `rust/tests/proto_props.rs`.
pub struct RequestDecoder {
    state: DecodeState,
}

impl Default for RequestDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestDecoder {
    pub fn new() -> RequestDecoder {
        RequestDecoder {
            state: DecodeState::Header {
                buf: [0; V2_HEADER_LEN],
                got: 0,
                need: 4,
            },
        }
    }

    /// Bytes the decoder can use right now (size reads to this; 0 means
    /// a header is gated on [`RequestDecoder::begin_payload`]).
    pub fn want(&self) -> usize {
        match &self.state {
            DecodeState::Header { got, need, .. } => need - got,
            DecodeState::Gate(_) => 0,
            DecodeState::Payload { remaining, .. } => *remaining,
            DecodeState::PayloadRaw { remaining, .. } => *remaining,
        }
    }

    /// Header bytes accumulated so far when mid-header (EOF semantics:
    /// `Some(1..=3)` is still inside the sniff window and counts as a
    /// clean close; `Some(4..)` is a truncated v2 frame). `None` when
    /// not in the header state.
    pub fn header_progress(&self) -> Option<usize> {
        match &self.state {
            DecodeState::Header { got, .. } => Some(*got),
            _ => None,
        }
    }

    /// The header awaiting a [`RequestDecoder::begin_payload`] / reject
    /// decision, if any.
    pub fn gated(&self) -> Option<RequestHeader> {
        match &self.state {
            DecodeState::Gate(h) => Some(*h),
            _ => None,
        }
    }

    /// Accept the gated header and start streaming its payload for a
    /// model with `img_elems` f32s per image. Caller has validated
    /// `n` (≤ [`MAX_REQ_IMAGES`]), so the allocation here is bounded.
    pub fn begin_payload(&mut self, img_elems: usize) {
        let header = match &self.state {
            DecodeState::Gate(h) => *h,
            _ => {
                debug_assert!(false, "begin_payload outside the header gate");
                return;
            }
        };
        let n = header.n() as usize;
        self.state = DecodeState::Payload {
            header,
            images: Vec::with_capacity(n * img_elems),
            remaining: n * img_elems * 4,
            carry: [0; 4],
            carry_len: 0,
        };
    }

    /// Accept the gated header in raw (forwarding) mode: accumulate
    /// `payload_bytes` verbatim after the header's exact wire bytes, so
    /// the completed [`Decoded::RequestRaw`] frame forwards with zero
    /// recompute. Caller has validated the header, so `payload_bytes`
    /// (= `n × img_elems × 4`) bounds the allocation.
    pub fn begin_payload_raw(&mut self, payload_bytes: usize) {
        let header = match &self.state {
            DecodeState::Gate(h) => *h,
            _ => {
                debug_assert!(false, "begin_payload_raw outside the header gate");
                return;
            }
        };
        debug_assert!(payload_bytes > 0, "routed payloads are never empty");
        let mut frame = header.encode();
        frame.reserve(payload_bytes);
        self.state = DecodeState::PayloadRaw {
            header,
            frame,
            remaining: payload_bytes,
        };
    }

    /// Back to a fresh header state (used after answering a
    /// payload-less describe request in place).
    pub fn reset(&mut self) {
        self.state = DecodeState::Header {
            buf: [0; V2_HEADER_LEN],
            got: 0,
            need: 4,
        };
    }

    /// Feed bytes; consumes `min(bytes.len(), want())` and returns
    /// `(consumed, event)`. At most one event per call when fed at most
    /// `want()` bytes (exact-sized reads guarantee that); oversized
    /// slices are partially consumed — loop on `consumed`.
    pub fn feed(&mut self, bytes: &[u8]) -> (usize, Decoded) {
        match &mut self.state {
            DecodeState::Gate(_) => (0, Decoded::NeedMore),
            DecodeState::Header { buf, got, need } => {
                let take = bytes.len().min(*need - *got);
                buf[*got..*got + take].copy_from_slice(&bytes[..take]);
                *got += take;
                if *got < *need {
                    return (take, Decoded::NeedMore);
                }
                if *need == 4 && buf[..4] == MAGIC {
                    *need = V2_HEADER_LEN; // sniffed v2: extend the header
                    return (take, Decoded::NeedMore);
                }
                if *need == 4 && buf[..4] == MAGIC_DESC {
                    *need = DESC_HEADER_LEN; // sniffed describe request
                    return (take, Decoded::NeedMore);
                }
                let header = if *need == 4 {
                    RequestHeader::V1 {
                        n: u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]),
                    }
                } else if *need == DESC_HEADER_LEN {
                    RequestHeader::Describe {
                        version: u16::from_le_bytes([buf[4], buf[5]]),
                    }
                } else {
                    RequestHeader::V2 {
                        version: u16::from_le_bytes([buf[4], buf[5]]),
                        model_id: u16::from_le_bytes([buf[6], buf[7]]),
                        n: u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]),
                    }
                };
                self.state = DecodeState::Gate(header);
                (take, Decoded::Header(header))
            }
            DecodeState::Payload {
                header,
                images,
                remaining,
                carry,
                carry_len,
            } => {
                let take = bytes.len().min(*remaining);
                let mut src = &bytes[..take];
                *remaining -= take;
                // finish a split f32 first
                if *carry_len > 0 {
                    let fill = src.len().min(4 - *carry_len);
                    carry[*carry_len..*carry_len + fill].copy_from_slice(&src[..fill]);
                    *carry_len += fill;
                    src = &src[fill..];
                    if *carry_len == 4 {
                        images.push(f32::from_le_bytes(*carry));
                        *carry_len = 0;
                    }
                }
                let whole = src.len() / 4 * 4;
                images.extend(
                    src[..whole]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
                );
                let rest = &src[whole..];
                carry[..rest.len()].copy_from_slice(rest);
                *carry_len = rest.len();
                if *remaining > 0 {
                    return (take, Decoded::NeedMore);
                }
                debug_assert_eq!(*carry_len, 0, "payload is a multiple of 4 bytes");
                let header = *header;
                let images = std::mem::take(images);
                self.state = DecodeState::Header {
                    buf: [0; V2_HEADER_LEN],
                    got: 0,
                    need: 4,
                };
                (take, Decoded::Request { header, images })
            }
            DecodeState::PayloadRaw {
                header,
                frame,
                remaining,
            } => {
                let take = bytes.len().min(*remaining);
                frame.extend_from_slice(&bytes[..take]);
                *remaining -= take;
                if *remaining > 0 {
                    return (take, Decoded::NeedMore);
                }
                let header = *header;
                let frame = std::mem::take(frame);
                self.state = DecodeState::Header {
                    buf: [0; V2_HEADER_LEN],
                    got: 0,
                    need: 4,
                };
                (take, Decoded::RequestRaw { header, frame })
            }
        }
    }
}

// ---------------------------------------------------------------------
// Write buffer with partial-write carry
// ---------------------------------------------------------------------

/// Outcome of one [`WriteBuf::flush_to`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Flush {
    /// Everything staged has hit the socket.
    Done,
    /// The socket stopped accepting bytes (`WouldBlock`); register
    /// write interest and resume on writability.
    Blocked,
}

/// Staged response bytes + how far the socket has taken them. The
/// blocking server's `write_all` assumed a healthy socket; this is the
/// explicit partial-write/EPIPE path (unit-tested below, exercised over
/// real sockets by `rust/tests/conn_conformance.rs`).
#[derive(Default)]
pub(crate) struct WriteBuf {
    buf: Vec<u8>,
    pos: usize,
}

impl WriteBuf {
    /// Unflushed bytes.
    pub(crate) fn len(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stage one response frame (`u32 n` + `n` class ids).
    fn push_response(&mut self, preds: &[u32]) {
        self.buf.reserve(4 + preds.len() * 4);
        self.buf
            .extend_from_slice(&(preds.len() as u32).to_le_bytes());
        for p in preds {
            self.buf.extend_from_slice(&p.to_le_bytes());
        }
    }

    /// Stage pre-encoded bytes (stats HTTP responses, forwarded
    /// frames, describe replies).
    pub(crate) fn push_bytes(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Write as much as the socket takes right now. `Err` is fatal for
    /// the connection (EPIPE, reset, ...); `Interrupted` is retried
    /// here, `WouldBlock` returns [`Flush::Blocked`].
    pub(crate) fn flush_to(&mut self, w: &mut impl Write) -> io::Result<Flush> {
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => {
                    return Err(io::Error::new(
                        ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    ))
                }
                Ok(k) => self.pos += k,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    // keep flushed bytes from accumulating forever
                    if self.pos >= WRITE_BUF_SOFT_CAP {
                        self.buf.drain(..self.pos);
                        self.pos = 0;
                    }
                    return Ok(Flush::Blocked);
                }
                Err(e) => return Err(e),
            }
        }
        self.buf.clear();
        self.pos = 0;
        Ok(Flush::Done)
    }
}

// ---------------------------------------------------------------------
// Per-connection state
// ---------------------------------------------------------------------

/// One request in flight through queue/scheduler/pool, awaiting its
/// reply. Per-connection replies are staged strictly in arrival order.
struct InFlight {
    model_id: u16,
    rx: mpsc::Receiver<Result<Vec<u32>, String>>,
    /// When the request finished decoding (the `Pending`'s
    /// `enqueued_at`, surviving queue-full parking): the start of the
    /// end-to-end latency observed into the model's `e2e_hist` when
    /// the reply is staged.
    t0: Instant,
}

enum Phase {
    /// Reading requests normally.
    Open,
    /// A fully-decoded request found its model queue full. Read
    /// interest is off (TCP backpressure); retried on waker rings.
    Parked {
        model_id: u16,
        pending: Pending,
        rx: mpsc::Receiver<Result<Vec<u32>, String>>,
    },
    /// Router mode's park: a routed request is waiting for backend
    /// capacity (or the backend's describe handshake). `Some` holds a
    /// fully-decoded frame that found every backend connection
    /// saturated; `None` parked at the header gate (the decoder still
    /// holds the gated header, no payload read yet). Read interest is
    /// off either way; retried on every sweep.
    RouteParked {
        model_id: u16,
        frame: Option<route::ParkedFrame>,
    },
    /// No more reads (clean half-close, or a counted protocol
    /// rejection): answer everything already accepted, flush, close.
    /// This preserves the blocking server's ordering guarantee that a
    /// bad pipelined request never swallows its predecessors' replies.
    Draining,
}

struct Conn {
    stream: TcpStream,
    token: u64,
    decoder: RequestDecoder,
    phase: Phase,
    /// Replies owed, in request order (front is next on the wire).
    inflight: VecDeque<InFlight>,
    write: WriteBuf,
    /// Interest currently registered with the poller.
    interest: Interest,
    /// Last byte actually moved (either direction) — the idle/read
    /// timeout clock.
    last_activity: Instant,
}

impl Conn {
    /// Is the idle/read timeout armed for this connection? Never while
    /// replies are owed, staged response bytes are unflushed, or a
    /// request is parked: those waits are the *server's* obligations
    /// and must not kill the client. (A reply sitting in the write
    /// buffer is owed exactly as much as one still in its channel —
    /// a congested-but-reading client keeps refreshing `last_activity`
    /// through partial writes, so only truly stalled peers expire.)
    fn timeout_eligible(&self) -> bool {
        self.inflight.is_empty()
            && self.write.is_empty()
            && !matches!(self.phase, Phase::Parked { .. } | Phase::RouteParked { .. })
    }
}

/// Why a connection was torn down (drives counters + logging).
enum CloseReason {
    /// Clean protocol end (EOF at a request boundary, drain finished —
    /// including counted protocol rejections, which drain then close).
    Done,
    /// I/O failure or mid-frame truncation.
    Error(anyhow::Error),
    /// Idle/read deadline expired.
    TimedOut,
}

// ---------------------------------------------------------------------
// The event loop
// ---------------------------------------------------------------------

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const TOKEN_BASE: u64 = 2;

/// Stats-endpoint tokens live far above any serving slot (slot counts
/// are bounded by fd limits, orders of magnitude below 2^48), so one
/// `match` on the token routes an event to the right slab.
const TOKEN_STATS_LISTENER: u64 = u64::MAX;
const STATS_TOKEN_BASE: u64 = 1 << 48;

/// Admin-endpoint tokens: one listener token just below the stats
/// listener's, and a connection space a full power of two above the
/// stats range, so the dispatch `match` stays a strict ladder:
/// client < route < stats < admin < listeners.
const TOKEN_ADMIN_LISTENER: u64 = u64::MAX - 1;
const ADMIN_TOKEN_BASE: u64 = 1 << 49;

/// Concurrent admin connections. The control plane is an operator
/// channel, not a public endpoint: past the cap new connections are
/// accepted and dropped, exactly like a stats scrape storm.
const MAX_ADMIN_CONNS: usize = 8;

/// One in-flight stats scrape: accumulate the request head, answer
/// once, flush, close. No protocol state machine — a stats connection
/// is either still reading or still flushing its single response.
struct StatsConn {
    stream: TcpStream,
    /// Request-head bytes read so far (bounded: parsing rejects heads
    /// past [`MAX_STATS_REQUEST`] bytes).
    buf: Vec<u8>,
    write: WriteBuf,
    /// Response staged; stop reading, close once the flush completes.
    responded: bool,
    opened: Instant,
}

/// One operator control connection: a persistent, line-oriented
/// session (unlike stats scrapes there is no lifetime cap — an
/// operator console stays attached between commands). Each complete
/// line is applied to the control plane and answered with exactly one
/// reply line.
struct AdminConn {
    stream: TcpStream,
    /// Bytes of the current (incomplete) line. Bounded by
    /// [`super::MAX_ADMIN_LINE`]: past it the connection gets an error
    /// reply and closes (line framing is lost beyond that point).
    buf: Vec<u8>,
    write: WriteBuf,
    /// No more reads (EOF or an oversized line): flush staged replies,
    /// then close.
    closing: bool,
}

/// Everything [`run_event_loop`] multiplexes (built by `Server::run`
/// in serving mode, `RouterServer::run` in router mode).
pub(crate) struct LoopCtx {
    /// Control plane: the epoch-versioned registry/queue/policy state
    /// plus the admin command interpreter — `None` in router mode
    /// (requests forward to backends instead of resolving against
    /// local engines).
    pub control: Option<Arc<ControlPlane>>,
    pub stats: Arc<ServerStats>,
    /// The scheduler's doorbell (rung on became-admissible pushes).
    pub doorbell: Arc<Doorbell>,
    /// Concurrent-connection cap; beyond it accepts are rejected
    /// (closed immediately, counted).
    pub max_conns: Option<usize>,
    /// Bounded-run knob: stop accepting after this many accepts and
    /// return once the accepted connections finish.
    pub max_accepts: Option<usize>,
    /// Idle/read timeout (None = never).
    pub conn_timeout: Option<Duration>,
    /// Force the portable poll(2) backend.
    pub poll_fallback: bool,
    /// Already-bound `--stats-addr` listener (None = no endpoint).
    pub stats_listener: Option<TcpListener>,
    /// Already-bound `--admin-addr` listener (None = no control-plane
    /// endpoint; hot add/remove/policy/reload unavailable).
    pub admin_listener: Option<TcpListener>,
    /// Router mode: routing table + backend connection pools, driven
    /// by this same loop (`None` = local serving).
    pub router: Option<route::Router>,
}

pub(crate) fn run_event_loop(listener: TcpListener, ctx: LoopCtx) -> Result<()> {
    EventLoop::new(listener, ctx)?.run()
}

struct EventLoop {
    ctx: LoopCtx,
    poller: Poller,
    waker: Arc<Waker>,
    /// Accept source; dropped once `max_accepts` is reached.
    listener: Option<TcpListener>,
    /// Slot map: token = slot + TOKEN_BASE.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    open: usize,
    accepted: usize,
    accept_errs: u32,
    /// Transient accept-error backoff: until this instant the listener
    /// is masked in the poller and accepts are not retried. A deadline,
    /// NOT a sleep — the loop thread keeps serving every open
    /// connection while the listener cools down (fd exhaustion happens
    /// exactly when thousands of connections need that service).
    accept_retry_at: Option<Instant>,
    listener_dead: bool,
    /// Reusable read buffer (single-threaded loop: one is enough for
    /// every connection).
    chunk: Vec<u8>,
    /// Optional `--stats-addr` listener; dropped (serving untouched)
    /// after a long unbroken accept-error streak.
    stats_listener: Option<TcpListener>,
    /// Stats-connection slab: token = slot + STATS_TOKEN_BASE.
    stats_conns: Vec<Option<StatsConn>>,
    stats_free: Vec<usize>,
    stats_open: usize,
    stats_accept_errs: u32,
    /// Cached epoch snapshot (serving mode): the loop resolves every
    /// registry/queue/stats lookup against this Arc and re-fetches it
    /// when the control plane's epoch counter moves — one atomic load
    /// per iteration, zero locks on the request path, and a swap can
    /// never land mid-request.
    state: Option<Arc<EpochState>>,
    /// Optional `--admin-addr` listener (same give-up policy as the
    /// stats listener: serving survives a dead admin endpoint).
    admin_listener: Option<TcpListener>,
    /// Admin-connection slab: token = slot + ADMIN_TOKEN_BASE.
    admin_conns: Vec<Option<AdminConn>>,
    admin_free: Vec<usize>,
    admin_open: usize,
    admin_accept_errs: u32,
}

impl EventLoop {
    fn new(listener: TcpListener, mut ctx: LoopCtx) -> Result<EventLoop> {
        let mut poller = if ctx.poll_fallback {
            Poller::with_poll_backend()
        } else {
            Poller::new()
        }
        .context("creating readiness poller")?;
        let waker = Arc::new(Waker::new().context("creating loop waker")?);
        poller
            .register(waker.read_fd(), TOKEN_WAKER, Interest::READ)
            .context("registering waker")?;
        listener
            .set_nonblocking(true)
            .context("non-blocking listener")?;
        let listener = if ctx.max_accepts == Some(0) {
            None // "at most 0 connections" means accept none
        } else {
            use std::os::unix::io::AsRawFd;
            poller
                .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
                .context("registering listener")?;
            Some(listener)
        };
        let stats_listener = match ctx.stats_listener.take() {
            Some(l) => {
                l.set_nonblocking(true)
                    .context("non-blocking stats listener")?;
                use std::os::unix::io::AsRawFd;
                poller
                    .register(l.as_raw_fd(), TOKEN_STATS_LISTENER, Interest::READ)
                    .context("registering stats listener")?;
                Some(l)
            }
            None => None,
        };
        let admin_listener = match ctx.admin_listener.take() {
            Some(l) => {
                l.set_nonblocking(true)
                    .context("non-blocking admin listener")?;
                use std::os::unix::io::AsRawFd;
                poller
                    .register(l.as_raw_fd(), TOKEN_ADMIN_LISTENER, Interest::READ)
                    .context("registering admin listener")?;
                Some(l)
            }
            None => None,
        };
        let state = ctx.control.as_ref().map(|c| c.current());
        let mut el = EventLoop {
            ctx,
            poller,
            waker,
            listener,
            conns: Vec::new(),
            free: Vec::new(),
            open: 0,
            accepted: 0,
            accept_errs: 0,
            accept_retry_at: None,
            listener_dead: false,
            chunk: vec![0u8; READ_CHUNK],
            stats_listener,
            stats_conns: Vec::new(),
            stats_free: Vec::new(),
            stats_open: 0,
            stats_accept_errs: 0,
            state,
            admin_listener,
            admin_conns: Vec::new(),
            admin_free: Vec::new(),
            admin_open: 0,
            admin_accept_errs: 0,
        };
        // Router mode: open the backend pools before accepting clients
        // (failures only arm backoff deadlines — the loop starts
        // regardless and keeps retrying).
        if let Some(router) = el.ctx.router.as_mut() {
            router.connect_all(&mut el.poller);
        }
        Ok(el)
    }

    fn run(mut self) -> Result<()> {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if self.listener.is_none() && self.open == 0 {
                break; // bounded run complete (or listener abandoned)
            }
            let timeout = self.next_timeout();
            self.poller
                .wait(&mut events, timeout)
                .context("poller wait")?;
            let mut accept_ready = false;
            let mut stats_accept_ready = false;
            let mut admin_accept_ready = false;
            // Pick up a control-plane swap before touching any
            // connection, so every event in this batch resolves
            // against one consistent epoch.
            self.refresh_epoch();
            for ev in &events {
                match ev.token {
                    TOKEN_LISTENER => accept_ready = true,
                    TOKEN_WAKER => self.waker.drain(),
                    TOKEN_STATS_LISTENER => stats_accept_ready = true,
                    TOKEN_ADMIN_LISTENER => admin_accept_ready = true,
                    t if t >= ADMIN_TOKEN_BASE => self.on_admin_event(*ev),
                    t if t >= STATS_TOKEN_BASE => self.on_stats_event(*ev),
                    t if t >= route::ROUTE_TOKEN_BASE => self.on_route_event(*ev),
                    _ => self.on_conn_event(*ev),
                }
            }
            // Router mode: attempt reconnects whose backoff deadline
            // passed (next_timeout wakes the loop for them).
            if let Some(router) = self.ctx.router.as_mut() {
                router.tick(Instant::now(), &mut self.poller);
            }
            // Accept-backoff deadline reached: unmask the listener and
            // retry (the masked fd emitted no event; the poller timeout
            // brought us here).
            if let Some(t) = self.accept_retry_at {
                if Instant::now() >= t {
                    self.accept_retry_at = None;
                    if let Some(l) = &self.listener {
                        use std::os::unix::io::AsRawFd;
                        let _ =
                            self.poller
                                .modify(l.as_raw_fd(), TOKEN_LISTENER, Interest::READ);
                    }
                    accept_ready = true;
                }
            }
            if accept_ready && self.accept_retry_at.is_none() {
                self.accept_ready();
            }
            if stats_accept_ready {
                self.stats_accept_ready();
            }
            if admin_accept_ready {
                self.admin_accept_ready();
            }
            // Progress sweep: completions may have landed for any
            // connection (the waker says "something finished", not
            // which), and freed queue space un-parks in slot order.
            self.sweep();
            self.sweep_timeouts();
            self.sweep_stats_timeouts();
        }
        if self.listener_dead {
            bail!("accept loop abandoned after repeated listener errors");
        }
        Ok(())
    }

    /// Re-fetch the cached epoch snapshot when the control plane's
    /// counter moved (an admin command swapped the registry). One
    /// atomic load in the steady state; connections resolve every
    /// lookup against the cached Arc, so a swap lands between loop
    /// iterations — never mid-request.
    fn refresh_epoch(&mut self) {
        if let (Some(control), Some(state)) = (&self.ctx.control, &self.state) {
            if control.epoch() != state.epoch {
                self.state = Some(control.current());
            }
        }
    }

    /// Earliest wake deadline: idle timeouts of eligible connections,
    /// the accept-backoff retry, and stats-connection expiry
    /// (whichever comes first).
    fn next_timeout(&self) -> Option<Duration> {
        let now = Instant::now();
        let retry = self
            .accept_retry_at
            .map(|t| t.checked_duration_since(now).unwrap_or(Duration::ZERO));
        let idle = self.ctx.conn_timeout.and_then(|timeout| {
            self.conns
                .iter()
                .flatten()
                .filter(|c| c.timeout_eligible())
                .map(|c| {
                    (c.last_activity + timeout)
                        .checked_duration_since(now)
                        .unwrap_or(Duration::ZERO)
                })
                .min()
        });
        let stats_idle = self
            .stats_conns
            .iter()
            .flatten()
            .map(|c| {
                (c.opened + STATS_CONN_TIMEOUT)
                    .checked_duration_since(now)
                    .unwrap_or(Duration::ZERO)
            })
            .min();
        let route_retry = self
            .ctx
            .router
            .as_ref()
            .and_then(|r| r.next_deadline())
            .map(|t| t.checked_duration_since(now).unwrap_or(Duration::ZERO));
        [retry, idle, stats_idle, route_retry]
            .into_iter()
            .flatten()
            .min()
    }

    fn sweep_timeouts(&mut self) {
        let Some(timeout) = self.ctx.conn_timeout else {
            return;
        };
        let now = Instant::now();
        for slot in 0..self.conns.len() {
            let expired = matches!(
                &self.conns[slot],
                Some(c) if c.timeout_eligible() && now.duration_since(c.last_activity) >= timeout
            );
            if expired {
                self.close(slot, CloseReason::TimedOut);
            }
        }
    }

    /// Reclaim stats connections past their fixed lifetime. Always on
    /// (independent of `--conn-timeout`): a scrape either finishes in
    /// milliseconds or is stuck.
    fn sweep_stats_timeouts(&mut self) {
        if self.stats_open == 0 {
            return;
        }
        let now = Instant::now();
        for slot in 0..self.stats_conns.len() {
            let expired = matches!(
                &self.stats_conns[slot],
                Some(c) if now.duration_since(c.opened) >= STATS_CONN_TIMEOUT
            );
            if expired {
                self.close_stats(slot);
            }
        }
    }

    /// Retry parked pushes and stage/flush replies on every live
    /// connection.
    fn sweep(&mut self) {
        for slot in 0..self.conns.len() {
            if self.conns[slot].is_some() {
                self.progress(slot);
            }
        }
    }

    // -- accept path --------------------------------------------------

    fn accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    self.accept_errs = 0;
                    self.accepted += 1;
                    self.ctx
                        .stats
                        .conns_accepted
                        .fetch_add(1, Ordering::Relaxed);
                    if self.ctx.max_conns.map(|m| self.open >= m).unwrap_or(false) {
                        // Admission rejection: accepted (the kernel
                        // already completed the handshake) and closed
                        // straight back. Cheaper than a thread ever was.
                        self.ctx
                            .stats
                            .conns_rejected
                            .fetch_add(1, Ordering::Relaxed);
                        drop(stream);
                    } else if let Err(e) = self.install(stream) {
                        eprintln!("aquant-serve: failed to install connection: {e:#}");
                    }
                    if self.ctx.max_accepts.map(|m| self.accepted >= m).unwrap_or(false) {
                        self.drop_listener();
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) => {
                    // Transient accept failures (fd exhaustion under
                    // load) must not kill a long-lived server; a long
                    // unbroken streak means the listener is gone.
                    self.accept_errs += 1;
                    eprintln!(
                        "aquant-serve: accept error ({} in a row): {e}",
                        self.accept_errs
                    );
                    if self.accept_errs >= 1000 {
                        eprintln!("aquant-serve: giving up on accept loop");
                        self.listener_dead = true;
                        self.drop_listener();
                    } else {
                        // Cool down WITHOUT blocking the loop: mask the
                        // listener (level-triggered readability would
                        // otherwise spin the poller hot) and arm a
                        // retry deadline that next_timeout honors.
                        use std::os::unix::io::AsRawFd;
                        let _ = self.poller.modify(
                            listener.as_raw_fd(),
                            TOKEN_LISTENER,
                            Interest {
                                readable: false,
                                writable: false,
                            },
                        );
                        self.accept_retry_at =
                            Some(Instant::now() + Duration::from_millis(10));
                    }
                    return;
                }
            }
        }
    }

    fn drop_listener(&mut self) {
        if let Some(l) = self.listener.take() {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(l.as_raw_fd());
        }
    }

    // -- stats endpoint -----------------------------------------------
    //
    // A strictly read-only sidecar on the same loop: nothing below
    // touches queues, the scheduler, or serving-connection state. All
    // it shares with the serving path is `ctx.stats` (relaxed atomics).

    fn stats_accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.stats_listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    self.stats_accept_errs = 0;
                    if self.stats_open >= MAX_STATS_CONNS {
                        // Shed, don't queue: a scrape storm gets reset
                        // connections while serving stays untouched.
                        drop(stream);
                        continue;
                    }
                    if let Err(e) = self.install_stats(stream) {
                        eprintln!("aquant-serve: failed to install stats connection: {e:#}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) => {
                    self.stats_accept_errs += 1;
                    eprintln!(
                        "aquant-serve: stats accept error ({} in a row): {e}",
                        self.stats_accept_errs
                    );
                    // Observability is optional: after a long unbroken
                    // streak drop the endpoint rather than backing off
                    // forever. Serving keeps its own listener.
                    if self.stats_accept_errs >= 100 {
                        eprintln!("aquant-serve: disabling stats endpoint (serving unaffected)");
                        self.drop_stats_listener();
                    }
                    return;
                }
            }
        }
    }

    fn drop_stats_listener(&mut self) {
        if let Some(l) = self.stats_listener.take() {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(l.as_raw_fd());
        }
    }

    fn install_stats(&mut self, stream: TcpStream) -> Result<()> {
        stream
            .set_nonblocking(true)
            .context("non-blocking stats conn")?;
        let slot = match self.stats_free.pop() {
            Some(s) => s,
            None => {
                self.stats_conns.push(None);
                self.stats_conns.len() - 1
            }
        };
        let token = STATS_TOKEN_BASE + slot as u64;
        {
            use std::os::unix::io::AsRawFd;
            if let Err(e) = self.poller.register(stream.as_raw_fd(), token, Interest::READ) {
                self.stats_free.push(slot);
                return Err(e).context("registering stats conn");
            }
        }
        self.stats_conns[slot] = Some(StatsConn {
            stream,
            buf: Vec::new(),
            write: WriteBuf::default(),
            responded: false,
            opened: Instant::now(),
        });
        self.stats_open += 1;
        Ok(())
    }

    fn on_stats_event(&mut self, ev: Event) {
        let slot = (ev.token - STATS_TOKEN_BASE) as usize;
        // Stale event for an already-closed stats slot.
        if self.stats_conns.get(slot).and_then(Option::as_ref).is_none() {
            return;
        }
        if ev.hangup || ev.error {
            self.close_stats(slot);
            return;
        }
        if self.stats_read(slot).is_err() {
            self.close_stats(slot);
            return;
        }
        self.stats_flush(slot);
    }

    /// Accumulate request-head bytes until [`metrics::parse_stats_request`]
    /// reaches a verdict, then stage the one-shot response (a fresh
    /// [`Snapshot`] on success, a plaintext error otherwise). `Err`
    /// means the connection is unsalvageable (EOF mid-head, I/O error).
    fn stats_read(&mut self, slot: usize) -> std::result::Result<(), ()> {
        loop {
            let conn = self.stats_conns[slot].as_mut().expect("live stats conn");
            if conn.responded {
                return Ok(());
            }
            // Cap each read so the accumulated head stays within one
            // read of the parser's reject threshold.
            match conn.stream.read(&mut self.chunk[..MAX_STATS_REQUEST]) {
                Ok(0) => return Err(()), // EOF before a full request head
                Ok(k) => {
                    conn.buf.extend_from_slice(&self.chunk[..k]);
                    match metrics::parse_stats_request(&conn.buf) {
                        StatsParse::Incomplete => continue,
                        StatsParse::Ok(fmt) => {
                            let snap = Snapshot::collect(&self.ctx.stats);
                            conn.write.push_bytes(&metrics::stats_response(&snap, fmt));
                            conn.responded = true;
                            return Ok(());
                        }
                        StatsParse::Reject(status, msg) => {
                            conn.write.push_bytes(&metrics::http_response(
                                status,
                                "text/plain; charset=utf-8",
                                msg,
                            ));
                            conn.responded = true;
                            return Ok(());
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
    }

    /// Flush the staged response; close once it is fully delivered.
    /// On `WouldBlock` switch the poller to write interest (reads are
    /// over — any extra bytes the client pipelines are ignored).
    fn stats_flush(&mut self, slot: usize) {
        let conn = self.stats_conns[slot].as_mut().expect("live stats conn");
        if !conn.write.is_empty() {
            match conn.write.flush_to(&mut conn.stream) {
                Ok(Flush::Done) => {}
                Ok(Flush::Blocked) => {
                    let want = Interest {
                        readable: !conn.responded,
                        writable: true,
                    };
                    use std::os::unix::io::AsRawFd;
                    let fd = conn.stream.as_raw_fd();
                    let _ = self.poller.modify(fd, STATS_TOKEN_BASE + slot as u64, want);
                    return;
                }
                Err(_) => {
                    self.close_stats(slot);
                    return;
                }
            }
        }
        if conn.responded {
            self.close_stats(slot); // answered and drained: done
        }
    }

    fn close_stats(&mut self, slot: usize) {
        let Some(conn) = self.stats_conns[slot].take() else {
            return;
        };
        {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
        }
        self.stats_free.push(slot);
        self.stats_open -= 1;
    }

    // -- admin (control plane) endpoint -------------------------------
    //
    // The operator console for hot model add/remove/retune/reload:
    // persistent line-oriented connections on their own slab. Command
    // application happens HERE, on the loop thread — a swap publishes
    // a new epoch snapshot that the scheduler and this loop pick up at
    // their next epoch check, so no lock is ever shared with serving
    // I/O and two admin connections can never interleave half-applied
    // commands.

    fn admin_accept_ready(&mut self) {
        loop {
            let Some(listener) = &self.admin_listener else { return };
            match listener.accept() {
                Ok((stream, _)) => {
                    self.admin_accept_errs = 0;
                    if self.admin_open >= MAX_ADMIN_CONNS {
                        // Shed, don't queue — same policy as stats.
                        drop(stream);
                        continue;
                    }
                    if let Err(e) = self.install_admin(stream) {
                        eprintln!("aquant-serve: failed to install admin connection: {e:#}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) => {
                    self.admin_accept_errs += 1;
                    eprintln!(
                        "aquant-serve: admin accept error ({} in a row): {e}",
                        self.admin_accept_errs
                    );
                    if self.admin_accept_errs >= 100 {
                        eprintln!("aquant-serve: disabling admin endpoint (serving unaffected)");
                        self.drop_admin_listener();
                    }
                    return;
                }
            }
        }
    }

    fn drop_admin_listener(&mut self) {
        if let Some(l) = self.admin_listener.take() {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(l.as_raw_fd());
        }
    }

    fn install_admin(&mut self, stream: TcpStream) -> Result<()> {
        stream
            .set_nonblocking(true)
            .context("non-blocking admin conn")?;
        let slot = match self.admin_free.pop() {
            Some(s) => s,
            None => {
                self.admin_conns.push(None);
                self.admin_conns.len() - 1
            }
        };
        let token = ADMIN_TOKEN_BASE + slot as u64;
        {
            use std::os::unix::io::AsRawFd;
            if let Err(e) = self.poller.register(stream.as_raw_fd(), token, Interest::READ) {
                self.admin_free.push(slot);
                return Err(e).context("registering admin conn");
            }
        }
        self.admin_conns[slot] = Some(AdminConn {
            stream,
            buf: Vec::new(),
            write: WriteBuf::default(),
            closing: false,
        });
        self.admin_open += 1;
        Ok(())
    }

    fn on_admin_event(&mut self, ev: Event) {
        let slot = (ev.token - ADMIN_TOKEN_BASE) as usize;
        // Stale event for an already-closed admin slot.
        if self.admin_conns.get(slot).and_then(Option::as_ref).is_none() {
            return;
        }
        if ev.hangup || ev.error {
            self.close_admin(slot);
            return;
        }
        if self.admin_read(slot).is_err() {
            self.close_admin(slot);
            return;
        }
        // Commands applied above may have swapped the epoch; pick the
        // new snapshot up before this iteration's progress sweep.
        self.refresh_epoch();
        self.admin_flush(slot);
    }

    /// Read command bytes; every complete `\n`-terminated line is
    /// applied to the control plane and answered with exactly one
    /// reply line. Blank lines are keep-alives. An overlong line gets
    /// an error reply and closes the connection (framing is lost past
    /// that point). `Err` means the connection is unsalvageable.
    fn admin_read(&mut self, slot: usize) -> std::result::Result<(), ()> {
        let Some(control) = self.ctx.control.clone() else {
            // Admin endpoint without a control plane (router mode
            // never binds one) — nothing sensible to do.
            return Err(());
        };
        loop {
            let conn = self.admin_conns[slot].as_mut().expect("live admin conn");
            if conn.closing {
                return Ok(());
            }
            match conn.stream.read(&mut self.chunk[..super::MAX_ADMIN_LINE]) {
                Ok(0) => {
                    conn.closing = true; // EOF: flush replies, then close
                    return Ok(());
                }
                Ok(k) => {
                    conn.buf.extend_from_slice(&self.chunk[..k]);
                    let mut start = 0;
                    while let Some(off) = conn.buf[start..].iter().position(|&b| b == b'\n') {
                        let end = start + off;
                        let reply = match std::str::from_utf8(&conn.buf[start..end]) {
                            Ok(s) if s.trim().is_empty() => None,
                            Ok(s) => Some(control.apply_line(s.trim())),
                            Err(_) => {
                                Some(format!("{} command is not valid utf-8", super::ADMIN_ERR))
                            }
                        };
                        if let Some(reply) = reply {
                            conn.write.push_bytes(reply.as_bytes());
                            conn.write.push_bytes(b"\n");
                        }
                        start = end + 1;
                    }
                    conn.buf.drain(..start);
                    if conn.buf.len() > super::MAX_ADMIN_LINE {
                        let msg = format!(
                            "{} line exceeds {} bytes\n",
                            super::ADMIN_ERR,
                            super::MAX_ADMIN_LINE
                        );
                        conn.write.push_bytes(msg.as_bytes());
                        conn.closing = true;
                        return Ok(());
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
    }

    /// Flush staged reply lines; unlike stats this connection is
    /// persistent, so after a complete flush interest returns to
    /// read-only (unless the connection is closing, which ends it).
    fn admin_flush(&mut self, slot: usize) {
        let (flush_err, done) = {
            let conn = self.admin_conns[slot].as_mut().expect("live admin conn");
            let err = !conn.write.is_empty() && conn.write.flush_to(&mut conn.stream).is_err();
            (err, conn.closing && conn.write.is_empty())
        };
        if flush_err || done {
            self.close_admin(slot);
            return;
        }
        let conn = self.admin_conns[slot].as_ref().expect("live admin conn");
        let want = Interest {
            readable: !conn.closing,
            writable: !conn.write.is_empty(),
        };
        use std::os::unix::io::AsRawFd;
        let fd = conn.stream.as_raw_fd();
        let _ = self.poller.modify(fd, ADMIN_TOKEN_BASE + slot as u64, want);
    }

    fn close_admin(&mut self, slot: usize) {
        let Some(conn) = self.admin_conns[slot].take() else {
            return;
        };
        {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
        }
        self.admin_free.push(slot);
        self.admin_open -= 1;
    }

    fn install(&mut self, stream: TcpStream) -> Result<()> {
        stream.set_nonblocking(true).context("non-blocking conn")?;
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.conns.push(None);
                self.conns.len() - 1
            }
        };
        let token = slot as u64 + TOKEN_BASE;
        {
            use std::os::unix::io::AsRawFd;
            if let Err(e) = self.poller.register(stream.as_raw_fd(), token, Interest::READ) {
                self.free.push(slot);
                return Err(e).context("registering conn");
            }
        }
        self.conns[slot] = Some(Conn {
            stream,
            token,
            decoder: RequestDecoder::new(),
            phase: Phase::Open,
            inflight: VecDeque::new(),
            write: WriteBuf::default(),
            interest: Interest::READ,
            last_activity: Instant::now(),
        });
        self.open += 1;
        self.ctx
            .stats
            .conns_open
            .store(self.open as u64, Ordering::Relaxed);
        Ok(())
    }

    // -- backend (router) events --------------------------------------

    /// Readiness on a backend-connection token: hand it to the router
    /// (flush staged frames / parse replies / tear down + schedule
    /// reconnect). Client-visible effects surface through the reply
    /// channels and the following sweep.
    fn on_route_event(&mut self, ev: Event) {
        let Some(router) = self.ctx.router.as_mut() else {
            return; // stale token without a router: ignore
        };
        router.on_event(ev, &mut self.poller, &mut self.chunk);
    }

    // -- connection events --------------------------------------------

    fn on_conn_event(&mut self, ev: Event) {
        let slot = (ev.token - TOKEN_BASE) as usize;
        // Stale event for a closed slot (possible when one wait batch
        // holds several events and an earlier one closed the conn).
        let Some(conn) = self.conns.get(slot).and_then(Option::as_ref) else {
            return;
        };
        // Full close / error while not reading (parked or draining):
        // the peer can neither send more nor receive answers — reclaim
        // now. An Open connection discovers the same thing through its
        // read path below, with proper EOF semantics.
        if (ev.hangup || ev.error) && !matches!(conn.phase, Phase::Open) {
            self.close(
                slot,
                CloseReason::Error(anyhow::anyhow!("peer closed while awaiting service")),
            );
            return;
        }
        if ev.writable {
            if let Err(reason) = self.try_flush(slot) {
                self.close(slot, reason);
                return;
            }
        }
        if let Some(conn) = self.conns.get(slot).and_then(Option::as_ref) {
            if matches!(conn.phase, Phase::Open) {
                if let Err(reason) = self.do_read(slot) {
                    self.close(slot, reason);
                    return;
                }
            }
        }
        self.progress(slot);
    }

    /// Read up to [`READ_BUDGET`] exact-need chunks, running the
    /// decoder + validation + queue push on each.
    fn do_read(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        for _ in 0..READ_BUDGET {
            let conn = self.conns[slot].as_mut().expect("live conn");
            if !matches!(conn.phase, Phase::Open) {
                return Ok(());
            }
            let want = conn.decoder.want().min(READ_CHUNK);
            if want == 0 {
                // gated header — resolved below, then loop again
            } else {
                match conn.stream.read(&mut self.chunk[..want]) {
                    Ok(0) => return self.on_eof(slot),
                    Ok(k) => {
                        conn.last_activity = Instant::now();
                        let (consumed, event) = conn.decoder.feed(&self.chunk[..k]);
                        debug_assert_eq!(consumed, k, "exact-need reads always fit");
                        match event {
                            Decoded::NeedMore => continue,
                            Decoded::Header(_) => {} // gate handled below
                            Decoded::Request { header, images } => {
                                self.queue_request(slot, header, images)?;
                                continue;
                            }
                            Decoded::RequestRaw { header, frame } => {
                                self.forward_request(slot, header, frame)?;
                                continue;
                            }
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(e) => {
                        return Err(CloseReason::Error(
                            anyhow::Error::from(e).context("reading request"),
                        ))
                    }
                }
            }
            self.resolve_header_gate(slot)?;
        }
        Ok(())
    }

    /// EOF from the peer: clean at a request boundary (or inside the
    /// 4-byte sniff window — the blocking server's rule), truncated
    /// anywhere else. Clean EOF with replies still owed is the graceful
    /// half-close path: the client `shutdown(WR)` and still gets every
    /// answer.
    fn on_eof(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        let conn = self.conns[slot].as_mut().expect("live conn");
        match conn.decoder.header_progress() {
            Some(got) if got < 4 => {
                conn.phase = Phase::Draining;
                Ok(())
            }
            _ => Err(CloseReason::Error(anyhow::anyhow!(
                "connection truncated mid-request"
            ))),
        }
    }

    /// Validate a gated header exactly as the blocking server did —
    /// same order, same stats — then start payload streaming or drain.
    /// Router mode swaps the registry lookup for the routing table
    /// ([`EventLoop::resolve_route_gate`]).
    fn resolve_header_gate(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        if self.ctx.router.is_some() {
            return self.resolve_route_gate(slot);
        }
        let state = self.state.clone().expect("serving mode");
        let conn = self.conns[slot].as_mut().expect("live conn");
        let Some(hdr) = conn.decoder.gated() else {
            return Ok(());
        };
        match hdr {
            RequestHeader::V2 { version, .. } | RequestHeader::Describe { version }
                if version != PROTO_VERSION =>
            {
                self.ctx.stats.bad_version.fetch_add(1, Ordering::Relaxed);
                conn.phase = Phase::Draining;
                return Ok(());
            }
            RequestHeader::Describe { .. } => {
                // Payload-less: answer with the model dimension table
                // (what a router's handshake needs to size payloads)
                // and return the decoder to the next header. Removed
                // (tombstoned) slots report 0 elems, exactly like a
                // route whose handshake is pending.
                let registry = &state.registry;
                let elems: Vec<u32> = (0..registry.len())
                    .map(|id| {
                        registry
                            .get(id as u16)
                            .map(|e| e.engine.img_elems() as u32)
                            .unwrap_or(0)
                    })
                    .collect();
                conn.write.push_bytes(&super::encode_describe_response(&elems));
                conn.decoder.reset();
                return Ok(());
            }
            _ => {}
        }
        let model_id = hdr.model_id();
        // Tombstoned (hot-removed) models fail this lookup: NEW
        // requests get the unknown-model rejection while anything
        // already queued keeps draining on the old engine.
        let Some(entry) = state.registry.get(model_id) else {
            self.ctx.stats.unknown_model.fetch_add(1, Ordering::Relaxed);
            conn.phase = Phase::Draining;
            return Ok(());
        };
        let n = hdr.n() as usize;
        if n == 0 || n > MAX_REQ_IMAGES {
            let mslot = &state.slots[model_id as usize];
            mslot.stats.rejected.fetch_add(1, Ordering::Relaxed);
            conn.phase = Phase::Draining;
            return Ok(());
        }
        conn.decoder.begin_payload(entry.engine.img_elems());
        Ok(())
    }

    /// Router mode's header gate: same validation order and stats as
    /// local serving, but the verdict comes from the routing table and
    /// acceptance starts RAW payload streaming (forwarded verbatim).
    /// A routed model whose backend handshake is pending, or whose
    /// backend connections are all saturated, parks the connection at
    /// the gate — no payload bytes are read into memory that could
    /// only wait.
    fn resolve_route_gate(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        let conn = self.conns[slot].as_mut().expect("live conn");
        let Some(hdr) = conn.decoder.gated() else {
            return Ok(());
        };
        let router = self.ctx.router.as_ref().expect("router mode");
        match hdr {
            RequestHeader::V2 { version, .. } | RequestHeader::Describe { version }
                if version != PROTO_VERSION =>
            {
                self.ctx.stats.bad_version.fetch_add(1, Ordering::Relaxed);
                conn.phase = Phase::Draining;
                return Ok(());
            }
            RequestHeader::Describe { .. } => {
                // Answer from the routing table: per-route img_elems as
                // learned from backend handshakes (0 while pending).
                let elems = router.describe_elems();
                conn.write.push_bytes(&super::encode_describe_response(&elems));
                conn.decoder.reset();
                return Ok(());
            }
            _ => {}
        }
        let model_id = hdr.model_id();
        if model_id as usize >= router.n_routes() {
            self.ctx.stats.unknown_model.fetch_add(1, Ordering::Relaxed);
            conn.phase = Phase::Draining;
            return Ok(());
        }
        let n = hdr.n() as usize;
        if n == 0 || n > MAX_REQ_IMAGES {
            let stats = self.ctx.stats.model(model_id).expect("stats per route");
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            conn.phase = Phase::Draining;
            return Ok(());
        }
        match router.payload_elems(model_id) {
            Some(elems) if router.has_capacity(model_id) => {
                conn.decoder.begin_payload_raw(n * elems as usize * 4);
            }
            _ => {
                conn.phase = Phase::RouteParked {
                    model_id,
                    frame: None,
                };
            }
        }
        Ok(())
    }

    /// A complete raw frame (router mode): forward it to the model's
    /// backend, or park it if every backend connection is saturated.
    fn forward_request(
        &mut self,
        slot: usize,
        header: RequestHeader,
        frame: Vec<u8>,
    ) -> std::result::Result<(), CloseReason> {
        let pf = route::ParkedFrame {
            frame,
            n: header.n(),
            t0: Instant::now(),
        };
        self.route_forward(slot, header.model_id(), pf)
    }

    /// The forward/park seam (router mode's `push_or_park`): used by
    /// both the fresh-frame path and every sweep retry so they cannot
    /// drift apart. On success the reply receiver joins the client
    /// connection's in-flight line — the same in-order staging local
    /// serving uses.
    fn route_forward(
        &mut self,
        slot: usize,
        model_id: u16,
        pf: route::ParkedFrame,
    ) -> std::result::Result<(), CloseReason> {
        let router = self.ctx.router.as_mut().expect("router mode");
        let t0 = pf.t0;
        match router.try_forward(model_id, pf, &mut self.poller) {
            route::Forward::Sent(rx) => {
                let conn = self.conns[slot].as_mut().expect("live conn");
                conn.phase = Phase::Open;
                conn.inflight.push_back(InFlight { model_id, rx, t0 });
                Ok(())
            }
            route::Forward::Saturated(pf) => {
                let conn = self.conns[slot].as_mut().expect("live conn");
                conn.phase = Phase::RouteParked {
                    model_id,
                    frame: Some(pf),
                };
                Ok(())
            }
        }
    }

    /// A complete request: build the Pending and push (or park).
    fn queue_request(
        &mut self,
        slot: usize,
        header: RequestHeader,
        images: Vec<f32>,
    ) -> std::result::Result<(), CloseReason> {
        let (tx, rx) = mpsc::channel();
        let pending = Pending {
            images,
            n: header.n() as usize,
            reply: ReplySink::with_waker(tx, self.waker.clone()),
            enqueued_at: Instant::now(),
        };
        self.push_or_park(slot, header.model_id(), pending, rx)
    }

    /// The single park/unpark seam: try the model's queue; on success
    /// the request joins the connection's in-flight line (ringing the
    /// scheduler when the push is a became-admissible transition), on
    /// `Full` the connection parks with the request intact. Used by
    /// both the initial push and every waker-driven retry so the two
    /// paths cannot drift apart.
    fn push_or_park(
        &mut self,
        slot: usize,
        model_id: u16,
        pending: Pending,
        rx: mpsc::Receiver<Result<Vec<u32>, String>>,
    ) -> std::result::Result<(), CloseReason> {
        // The slot's Arcs outlive any swap: a request validated
        // against an older epoch still lands in the queue the
        // scheduler drains (slots are never reused, and tombstoned
        // slots keep draining until the server exits).
        let state = self.state.clone().expect("serving mode");
        let mslot = &state.slots[model_id as usize];
        let conn = self.conns[slot].as_mut().expect("live conn");
        let t0 = pending.enqueued_at;
        match mslot.queue.try_push(pending, &mslot.stats) {
            TryPush::Queued(ring) => {
                conn.phase = Phase::Open;
                conn.inflight.push_back(InFlight { model_id, rx, t0 });
                if ring {
                    self.ctx.doorbell.ring();
                }
                Ok(())
            }
            TryPush::Full(pending) => {
                conn.phase = Phase::Parked {
                    model_id,
                    pending,
                    rx,
                };
                Ok(())
            }
            TryPush::Shutdown => Err(CloseReason::Error(anyhow::anyhow!("server shutting down"))),
        }
    }

    // -- reply / write / park progress --------------------------------

    /// Drive one connection forward: retry a parked push, stage
    /// completed replies (in order), flush, update interest, close when
    /// drained. Any failure closes the connection.
    fn progress(&mut self, slot: usize) {
        if let Err(reason) = self.progress_inner(slot) {
            self.close(slot, reason);
            return;
        }
        // Close fully-drained connections.
        let done = {
            let Some(conn) = self.conns[slot].as_ref() else {
                return;
            };
            matches!(conn.phase, Phase::Draining)
                && conn.inflight.is_empty()
                && conn.write.is_empty()
        };
        if done {
            self.close(slot, CloseReason::Done);
        } else {
            self.update_interest(slot);
        }
    }

    fn progress_inner(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        self.retry_park(slot)?;
        self.stage_replies(slot)?;
        self.try_flush(slot)
    }

    /// Parked request: try the queue again (a completion freed pool
    /// capacity, so the scheduler may have popped this model's queue).
    /// On success the connection returns to `Open` and read interest
    /// comes back via `update_interest`.
    fn retry_park(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        let conn = self.conns[slot].as_mut().expect("live conn");
        match conn.phase {
            Phase::Parked { .. } => {
                let Phase::Parked {
                    model_id,
                    pending,
                    rx,
                } = std::mem::replace(&mut conn.phase, Phase::Open)
                else {
                    unreachable!()
                };
                self.push_or_park(slot, model_id, pending, rx)
            }
            // Router mode: a parked frame retries the forward; a
            // gate-park re-runs the gate (the backend handshake may
            // have landed, or capacity freed).
            Phase::RouteParked { .. } => {
                let Phase::RouteParked { model_id, frame } =
                    std::mem::replace(&mut conn.phase, Phase::Open)
                else {
                    unreachable!()
                };
                match frame {
                    Some(pf) => self.route_forward(slot, model_id, pf),
                    None => self.resolve_route_gate(slot),
                }
            }
            _ => Ok(()),
        }
    }

    /// Move completed replies (front-first — responses stay in request
    /// order) into the write buffer, up to the soft cap.
    fn stage_replies(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        let conn = self.conns[slot].as_mut().expect("live conn");
        while let Some(front) = conn.inflight.front() {
            if conn.write.len() >= WRITE_BUF_SOFT_CAP {
                break;
            }
            match front.rx.try_recv() {
                Ok(Ok(preds)) => {
                    let stats = match &self.state {
                        // Serving mode: lock-free per-slot handle.
                        Some(state) => state.slots[front.model_id as usize].stats.clone(),
                        // Router mode: routes are fixed at startup, the
                        // row lock is uncontended.
                        None => self.ctx.stats.model(front.model_id).expect("validated id"),
                    };
                    stats.requests.fetch_add(1, Ordering::Relaxed);
                    // End-to-end latency: decode-complete to reply
                    // staged (includes queue wait, batching, inference,
                    // and loop turnaround — what the client feels minus
                    // its own socket).
                    stats
                        .e2e_hist
                        .observe(front.t0.elapsed().as_micros() as u64);
                    conn.write.push_response(&preds);
                    conn.inflight.pop_front();
                }
                Ok(Err(e)) => {
                    return Err(CloseReason::Error(anyhow::anyhow!("inference failed: {e}")))
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Err(CloseReason::Error(anyhow::anyhow!(
                        "scheduler dropped the request"
                    )))
                }
            }
        }
        Ok(())
    }

    fn try_flush(&mut self, slot: usize) -> std::result::Result<(), CloseReason> {
        let conn = self.conns[slot].as_mut().expect("live conn");
        if conn.write.is_empty() {
            return Ok(());
        }
        let before = conn.write.len();
        match conn.write.flush_to(&mut conn.stream) {
            Ok(_) => {
                if conn.write.len() != before {
                    conn.last_activity = Instant::now();
                }
                Ok(())
            }
            // EPIPE / reset from a dead client: close THIS connection;
            // the batch it rode in on is untouched.
            Err(e) => Err(CloseReason::Error(
                anyhow::Error::from(e).context("writing response"),
            )),
        }
    }

    /// Reconcile poller interest with connection state: read only while
    /// Open (parking/drain = TCP backpressure), write only while bytes
    /// are staged.
    fn update_interest(&mut self, slot: usize) {
        let conn = self.conns[slot].as_mut().expect("live conn");
        let want = Interest {
            readable: matches!(conn.phase, Phase::Open),
            writable: !conn.write.is_empty(),
        };
        if want != conn.interest {
            use std::os::unix::io::AsRawFd;
            if self
                .poller
                .modify(conn.stream.as_raw_fd(), conn.token, want)
                .is_ok()
            {
                conn.interest = want;
            }
        }
    }

    fn close(&mut self, slot: usize, reason: CloseReason) {
        let Some(conn) = self.conns[slot].take() else {
            return;
        };
        {
            use std::os::unix::io::AsRawFd;
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
        }
        self.free.push(slot);
        self.open -= 1;
        self.ctx
            .stats
            .conns_open
            .store(self.open as u64, Ordering::Relaxed);
        match reason {
            CloseReason::Done => {}
            CloseReason::TimedOut => {
                self.ctx
                    .stats
                    .conns_timed_out
                    .fetch_add(1, Ordering::Relaxed);
            }
            CloseReason::Error(e) => {
                eprintln!("aquant-serve: connection error: {e:#}");
            }
        }
        // conn drops here: stream closes, parked/in-flight receivers
        // drop (completions to them become no-ops).
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v2_bytes(model_id: u16, n: u32) -> [u8; V2_HEADER_LEN] {
        super::super::encode_header_v2(model_id, n)
    }

    #[test]
    fn decoder_v1_header_then_payload_byte_by_byte() {
        let mut d = RequestDecoder::new();
        assert_eq!(d.want(), 4);
        let hdr = 2u32.to_le_bytes();
        for (i, b) in hdr.iter().enumerate() {
            assert_eq!(d.header_progress(), Some(i));
            let (c, ev) = d.feed(&[*b]);
            assert_eq!(c, 1);
            if i < 3 {
                assert_eq!(ev, Decoded::NeedMore);
            } else {
                assert_eq!(ev, Decoded::Header(RequestHeader::V1 { n: 2 }));
            }
        }
        assert_eq!(d.want(), 0, "gated until begin_payload");
        assert_eq!(d.feed(&[9]), (0, Decoded::NeedMore), "gate consumes nothing");
        d.begin_payload(3); // 2 images x 3 f32 = 24 bytes
        assert_eq!(d.want(), 24);
        let floats: Vec<f32> = (0..6).map(|i| i as f32 * 0.5).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        // drip one byte at a time: exercises the f32 carry
        for (i, b) in bytes.iter().enumerate() {
            let (c, ev) = d.feed(&[*b]);
            assert_eq!(c, 1);
            if i < bytes.len() - 1 {
                assert_eq!(ev, Decoded::NeedMore, "byte {i}");
            } else {
                match ev {
                    Decoded::Request { header, images } => {
                        assert_eq!(header, RequestHeader::V1 { n: 2 });
                        assert_eq!(images, floats);
                    }
                    other => panic!("want Request, got {other:?}"),
                }
            }
        }
        assert_eq!(d.want(), 4, "decoder reset for the next request");
    }

    #[test]
    fn decoder_v2_sniff_extends_header() {
        let mut d = RequestDecoder::new();
        let hdr = v2_bytes(3, 1);
        let (c, ev) = d.feed(&hdr[..4]);
        assert_eq!((c, ev), (4, Decoded::NeedMore), "magic alone is not a header");
        assert_eq!(d.want(), V2_HEADER_LEN - 4);
        let (c, ev) = d.feed(&hdr[4..]);
        assert_eq!(c, V2_HEADER_LEN - 4);
        assert_eq!(
            ev,
            Decoded::Header(RequestHeader::V2 {
                version: PROTO_VERSION,
                model_id: 3,
                n: 1
            })
        );
        assert_eq!(d.header_progress(), None);
    }

    #[test]
    fn decoder_oversized_slice_partially_consumed() {
        let mut d = RequestDecoder::new();
        let mut stream = Vec::new();
        stream.extend_from_slice(&1u32.to_le_bytes());
        stream.extend_from_slice(&1.5f32.to_le_bytes());
        stream.extend_from_slice(&7u32.to_le_bytes()); // next request's header
        let (c, ev) = d.feed(&stream);
        assert_eq!(c, 4, "header only");
        assert_eq!(ev, Decoded::Header(RequestHeader::V1 { n: 1 }));
        d.begin_payload(1);
        let (c, ev) = d.feed(&stream[4..]);
        assert_eq!(c, 4, "payload only — trailing bytes left for the caller");
        match ev {
            Decoded::Request { images, .. } => assert_eq!(images, vec![1.5f32]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decoder_garbage_is_a_v1_header_not_a_panic() {
        // arbitrary non-magic bytes always parse as a v1 header whose n
        // the server then range-checks — no state for garbage to corrupt
        let mut d = RequestDecoder::new();
        let (_, ev) = d.feed(&[0xde, 0xad, 0xbe, 0xef]);
        match ev {
            Decoded::Header(RequestHeader::V1 { n }) => {
                assert_eq!(n, u32::from_le_bytes([0xde, 0xad, 0xbe, 0xef]));
            }
            other => panic!("{other:?}"),
        }
    }

    struct Throttled {
        taken: Vec<u8>,
        budget: usize,
        dead: bool,
    }

    impl Write for Throttled {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            if self.dead {
                return Err(io::Error::new(ErrorKind::BrokenPipe, "EPIPE"));
            }
            if self.budget == 0 {
                return Err(io::Error::new(ErrorKind::WouldBlock, "full"));
            }
            let k = buf.len().min(self.budget);
            self.taken.extend_from_slice(&buf[..k]);
            self.budget -= k;
            Ok(k)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_buf_partial_writes_resume_where_they_stopped() {
        let mut wb = WriteBuf::default();
        wb.push_response(&[1, 2, 3]);
        wb.push_response(&[4]);
        let total = wb.len();
        assert_eq!(total, (4 + 12) + (4 + 4));
        let mut sink = Throttled {
            taken: Vec::new(),
            budget: 5, // mid-frame cut
            dead: false,
        };
        assert_eq!(wb.flush_to(&mut sink).unwrap(), Flush::Blocked);
        assert_eq!(wb.len(), total - 5);
        sink.budget = 7;
        assert_eq!(wb.flush_to(&mut sink).unwrap(), Flush::Blocked);
        sink.budget = usize::MAX;
        assert_eq!(wb.flush_to(&mut sink).unwrap(), Flush::Done);
        assert!(wb.is_empty());
        // byte-exact reassembly across three partial flushes
        let mut want = Vec::new();
        want.extend_from_slice(&3u32.to_le_bytes());
        for p in [1u32, 2, 3] {
            want.extend_from_slice(&p.to_le_bytes());
        }
        want.extend_from_slice(&1u32.to_le_bytes());
        want.extend_from_slice(&4u32.to_le_bytes());
        assert_eq!(sink.taken, want);
        // staging keeps working after a full flush
        wb.push_response(&[9]);
        assert_eq!(wb.len(), 8);
    }

    #[test]
    fn write_buf_push_bytes_interleaves_with_frames() {
        // stats responses use the same partial-write carry as serving
        // frames; raw bytes and framed responses must coexist byte-exact
        let mut wb = WriteBuf::default();
        wb.push_bytes(b"HTTP/1.0 200 OK\r\n\r\n");
        wb.push_response(&[7]);
        let mut sink = Throttled {
            taken: Vec::new(),
            budget: usize::MAX,
            dead: false,
        };
        assert_eq!(wb.flush_to(&mut sink).unwrap(), Flush::Done);
        let mut want = b"HTTP/1.0 200 OK\r\n\r\n".to_vec();
        want.extend_from_slice(&1u32.to_le_bytes());
        want.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(sink.taken, want);
    }

    #[test]
    fn stats_token_space_is_disjoint() {
        // serving tokens are slot + 2 with slots bounded by fd limits;
        // pin the constants so the dispatch match stays unambiguous:
        // client < route < stats < stats-listener
        assert!(STATS_TOKEN_BASE > TOKEN_BASE + (1u64 << 32));
        assert!(TOKEN_STATS_LISTENER > STATS_TOKEN_BASE + MAX_STATS_CONNS as u64);
        assert!(route::ROUTE_TOKEN_BASE > TOKEN_BASE + (1u64 << 32));
        assert!(
            STATS_TOKEN_BASE > route::ROUTE_TOKEN_BASE + route::ROUTE_TOKEN_STRIDE * (1u64 << 16),
            "route tokens (backend x stride + conn) stay below the stats space"
        );
        // admin space sits strictly above stats, and both listeners
        // stay above every slab token
        assert!(ADMIN_TOKEN_BASE > STATS_TOKEN_BASE + MAX_STATS_CONNS as u64);
        assert!(TOKEN_ADMIN_LISTENER > ADMIN_TOKEN_BASE + MAX_ADMIN_CONNS as u64);
        assert_ne!(TOKEN_STATS_LISTENER, TOKEN_ADMIN_LISTENER);
    }

    #[test]
    fn decoder_raw_mode_rebuilds_the_exact_wire_frame() {
        // router mode: header re-encode + verbatim payload must equal
        // the bytes the client sent, byte for byte
        let mut wire = Vec::new();
        wire.extend_from_slice(&v2_bytes(1, 2));
        for f in [0.5f32, -1.0, 3.25, 0.0, 9.5, 2.0] {
            wire.extend_from_slice(&f.to_le_bytes());
        }
        let mut d = RequestDecoder::new();
        let mut off = 0;
        let mut out = None;
        while off < wire.len() {
            if d.want() == 0 {
                assert!(d.gated().is_some());
                d.begin_payload_raw(2 * 3 * 4); // n=2, img_elems=3
                continue;
            }
            // drip odd-sized slices to exercise resume points
            let take = d.want().min(5).min(wire.len() - off);
            let (c, ev) = d.feed(&wire[off..off + take]);
            off += c;
            if let Decoded::RequestRaw { header, frame } = ev {
                assert_eq!(header, RequestHeader::V2 {
                    version: PROTO_VERSION,
                    model_id: 1,
                    n: 2
                });
                out = Some(frame);
            }
        }
        assert_eq!(out.expect("frame completed"), wire);
        assert_eq!(d.want(), 4, "decoder reset for the next request");
    }

    #[test]
    fn decoder_raw_mode_v1_frame_is_byte_identical() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&1u32.to_le_bytes());
        wire.extend_from_slice(&7.5f32.to_le_bytes());
        let mut d = RequestDecoder::new();
        let (c, ev) = d.feed(&wire);
        assert_eq!((c, ev), (4, Decoded::Header(RequestHeader::V1 { n: 1 })));
        d.begin_payload_raw(4);
        let (c, ev) = d.feed(&wire[4..]);
        assert_eq!(c, 4);
        match ev {
            Decoded::RequestRaw { frame, .. } => assert_eq!(frame, wire),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn decoder_sniffs_describe_and_resets() {
        let mut d = RequestDecoder::new();
        let wire = RequestHeader::Describe {
            version: PROTO_VERSION,
        }
        .encode();
        let (c, ev) = d.feed(&wire[..4]);
        assert_eq!((c, ev), (4, Decoded::NeedMore), "magic alone is not a header");
        assert_eq!(d.want(), super::super::DESC_HEADER_LEN - 4);
        let (c, ev) = d.feed(&wire[4..]);
        assert_eq!(c, wire.len() - 4);
        assert_eq!(
            ev,
            Decoded::Header(RequestHeader::Describe {
                version: PROTO_VERSION
            })
        );
        // describe is payload-less: the server answers in place and
        // resets the decoder for the next request
        assert_eq!(d.want(), 0, "gated");
        d.reset();
        assert_eq!(d.want(), 4);
        assert_eq!(d.header_progress(), Some(0));
    }

    #[test]
    fn write_buf_surfaces_epipe() {
        let mut wb = WriteBuf::default();
        wb.push_response(&[0; 4]);
        let mut sink = Throttled {
            taken: Vec::new(),
            budget: 3,
            dead: false,
        };
        assert_eq!(wb.flush_to(&mut sink).unwrap(), Flush::Blocked);
        sink.dead = true;
        let err = wb.flush_to(&mut sink).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::BrokenPipe);
    }
}
