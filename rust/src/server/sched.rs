//! Pluggable scheduling subsystem: per-model serving policies and
//! weighted-fair admission over the shared worker pool.
//!
//! PR 3 gave every model its own [`BatchQueue`] + batcher thread, but
//! pool admission was first-come-first-served: one hot model saturating
//! the shared workers starved a latency-sensitive one, and every model
//! inherited the same global `--max-batch/--batch-wait-us/--queue-images`
//! knobs. This module replaces that fixed global policy with a
//! per-model adaptive one — the serving-side analogue of the paper's
//! move from the fixed 0.5 rounding border to a per-input border
//! function:
//!
//! * [`Policy`] — per-model serving knobs (`max_batch`, `batch_wait_us`,
//!   `queue_images`, integer `weight`), parsed from extended
//!   `--model NAME=SPEC[;key=value...]` specs
//!   ([`crate::config::PolicyOverrides`]) with server-level defaults
//!   filling whatever a spec leaves out.
//! * [`FairScheduler`] — weighted deficit-round-robin (DRR) admission:
//!   the N per-model batcher threads collapse into ONE scheduler loop
//!   ([`run_scheduler`]) that drains each model's queue into the pool in
//!   proportion to its weight while preserving per-model straggler
//!   deadlines and per-model backpressure.
//!
//! # Deficit round robin, adapted to batches
//!
//! A **persistent cursor** walks the models round-robin. A model with
//! an admissible batch (a full `max_batch` worth of images queued, an
//! expired straggler deadline, or shutdown drain) is credited
//! `quantum x weight` images of deficit when the cursor arrives —
//! `quantum` is the largest `max_batch` across models, so every ready
//! model can admit at least one full batch per visit and no weight can
//! starve — then admits batches while its deficit stays positive.
//! When the in-flight cap blocks admission the pass STOPS with the
//! cursor parked on the blocked model; the next wakeup resumes there
//! with its credit intact (and un-re-credited), so pool backpressure
//! can never let earlier-visited models lap a later one — the cursor,
//! not the wakeup, decides whose turn it is. Charges are actual image
//! counts; an oversized request (a single request larger than
//! `max_batch`) is admitted whole once the model holds any credit,
//! driving its deficit negative, and the model then sits out visits
//! until repeated credits bring it back above zero — debt survives
//! idle gaps (only positive credit is dropped when a model has nothing
//! admissible), and when the pool would otherwise go idle the loop is
//! work-conserving: it admits the debtor's next batch anyway, charged
//! against the debt, so a lone indebted model can never wedge itself.
//! For backlogged models this yields service in exact weight
//! proportion, within one quantum per cycle (pinned by the unit tests
//! below and `rust/tests/sched_props.rs`).
//!
//! A model whose queue holds requests that are *not yet admissible*
//! (straggler deadline still running) is passed over without credit —
//! its deadline, not its weight, decides when it next dispatches.
//!
//! # Admission backpressure
//!
//! Fairness at the pool only exists if admission is throttled: with
//! unbounded submission the scheduler would instantly dump every queue
//! into the pool's FIFO and recreate FCFS. The loop therefore tracks
//! in-flight images (submitted, not yet completed) and stops admitting
//! at [`inflight_cap`] — roughly two max-size batches — which keeps the
//! workers pipelined (strictly more than the one-blocking-batch-per-
//! model shape of PR 2/3) while bounding how far admitted-but-unserved
//! work can run ahead of the weights. With a single hosted model this
//! degenerates to PR 2 behavior: every round admits at least one full
//! batch and rounds repeat back-to-back while a backlog exists.
//!
//! The scheduler thread parks on a [`Doorbell`] — rung by request
//! arrivals, batch completions, and shutdown — with a timeout at the
//! earliest pending straggler deadline, so it burns no CPU while idle
//! and never oversleeps a deadline.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{PolicyOverrides, ServeConfig};
use crate::nn::pool::InferencePool;

use super::{ServerStats, Stats};

/// Upper bound on a model's scheduling weight. Generous enough for any
/// real priority split, and — together with the `max_batch` bound in
/// [`Policy::validate`] — it keeps `quantum * weight` far from
/// overflow.
pub const MAX_WEIGHT: u32 = 1024;

/// Lower bound on a model's deficit: one protocol-max request's worth
/// of debt. Classic DRR bounds overshoot at one packet; clamping here
/// keeps that bound even when the work-conserving force-admit path
/// serves a string of oversized requests on an otherwise idle pool —
/// without the floor, that free service would bank unbounded debt and
/// starve the model for an unbounded stretch once contention returns.
pub const DEBT_FLOOR: i64 = -(super::MAX_REQ_IMAGES as i64);

/// One model's resolved serving policy: the per-model version of the
/// global PR 2 knobs plus its fair-share weight. Built by
/// [`Policy::resolve`] from a spec's [`PolicyOverrides`] over the
/// server-level defaults ([`Policy::from_serve_cfg`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// Max images coalesced into one engine batch for this model.
    pub max_batch: usize,
    /// Straggler deadline (µs) once a request is pending.
    pub batch_wait_us: u64,
    /// Bound on queued images; a full queue backpressures this model's
    /// connections only.
    pub queue_images: usize,
    /// Fair-share weight at the pool (1..=[`MAX_WEIGHT`]); a weight-3
    /// model is admitted three images for every one of a weight-1 model
    /// when both are backlogged.
    pub weight: u32,
    /// Optional p99 end-to-end latency target (µs). When set, the
    /// scheduler's [`SloAdapter`] boosts this model's effective weight
    /// (up to [`SLO_FACTOR_MAX`]× the static value, never above
    /// [`MAX_WEIGHT`]) while the observed p99 misses the target, and
    /// decays it back once met. Scheduling order only — predictions
    /// stay bit-identical.
    pub slo_us: Option<u64>,
}

impl Policy {
    /// Server-level defaults: the global `--max-batch/--batch-wait-us/
    /// --queue-images` knobs with weight 1 — exactly the PR 2/PR 3
    /// behavior for specs that set no policy keys.
    pub fn from_serve_cfg(cfg: &ServeConfig) -> Policy {
        Policy {
            max_batch: cfg.max_batch,
            batch_wait_us: cfg.batch_wait_us,
            queue_images: cfg.queue_images,
            weight: 1,
            slo_us: None,
        }
    }

    /// Fill a spec's overrides over `defaults` and validate the result.
    pub fn resolve(defaults: &Policy, over: &PolicyOverrides) -> Result<Policy> {
        let p = Policy {
            max_batch: over.max_batch.unwrap_or(defaults.max_batch),
            batch_wait_us: over.batch_wait_us.unwrap_or(defaults.batch_wait_us),
            queue_images: over.queue_images.unwrap_or(defaults.queue_images),
            weight: over.weight.unwrap_or(defaults.weight),
            slo_us: over.slo_us.or(defaults.slo_us),
        };
        p.validate()?;
        Ok(p)
    }

    /// Same bounds the global knobs get in `ServeConfig::validate`,
    /// plus the weight range (weight 0 would starve the model by
    /// construction — rejected, not silently clamped).
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("policy max_batch must be >= 1");
        }
        if self.max_batch > ServeConfig::MAX_MAX_BATCH {
            bail!(
                "policy max_batch ({}) must be <= {}",
                self.max_batch,
                ServeConfig::MAX_MAX_BATCH
            );
        }
        if self.queue_images < self.max_batch {
            bail!(
                "policy queue_images ({}) must be >= max_batch ({})",
                self.queue_images,
                self.max_batch
            );
        }
        if self.batch_wait_us > ServeConfig::MAX_BATCH_WAIT_US {
            bail!(
                "policy batch_wait_us ({}) must be <= {} (60s)",
                self.batch_wait_us,
                ServeConfig::MAX_BATCH_WAIT_US
            );
        }
        if self.weight == 0 || self.weight > MAX_WEIGHT {
            bail!("policy weight ({}) must be in 1..={MAX_WEIGHT}", self.weight);
        }
        if let Some(slo) = self.slo_us {
            if slo == 0 {
                bail!("policy slo_us must be >= 1 (omit the key for no SLO)");
            }
            if slo > ServeConfig::MAX_BATCH_WAIT_US {
                bail!(
                    "policy slo_us ({slo}) must be <= {} (60s)",
                    ServeConfig::MAX_BATCH_WAIT_US
                );
            }
        }
        Ok(())
    }

    /// Straggler deadline as a `Duration`.
    pub fn wait(&self) -> Duration {
        Duration::from_micros(self.batch_wait_us)
    }

    /// Human one-liner for startup logging.
    pub fn describe(&self) -> String {
        let slo = match self.slo_us {
            Some(us) => format!(", slo p99 {us}us"),
            None => String::new(),
        };
        format!(
            "max-batch {}, wait {}us, queue {}, weight {}{slo}",
            self.max_batch, self.batch_wait_us, self.queue_images, self.weight
        )
    }
}

/// What one admission attempt produced (the `admit` callback of
/// [`FairScheduler::service`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Grant {
    /// A batch of this many images was popped and submitted.
    Admitted(usize),
    /// Nothing admissible from this model right now (queue raced empty
    /// or its deadline hasn't expired) — move on to the next model.
    Skip,
    /// Global admission backpressure (the in-flight cap): STOP the
    /// pass. The scheduler parks on this model — cursor and credit
    /// survive — and resumes here when capacity frees, so the cap can
    /// never let earlier-visited models lap the blocked one.
    Blocked,
}

/// Weighted deficit-round-robin admission core. Deterministic and
/// I/O-free: queue state comes in through the `ready` / `admit`
/// callbacks of [`FairScheduler::service`], so the quantum accounting
/// is unit-testable without threads, sockets, or clocks.
///
/// The cursor is **persistent**, as in classic DRR on a busy egress
/// link: when admission blocks on backpressure the pass stops *without
/// advancing*, and the next pass resumes at the same model with its
/// unspent credit. A fresh-credit-per-pass design (restart at id 0
/// every time) would let model 0 refill the in-flight cap on every
/// wakeup and starve higher ids outright.
pub struct FairScheduler {
    quantum: u64,
    weights: Vec<u64>,
    max_batches: Vec<usize>,
    deficits: Vec<i64>,
    /// Next model to visit; survives across passes (parks on Blocked).
    cursor: usize,
    /// Has the cursor's model been credited for this visit? Prevents
    /// re-crediting a parked model on every wakeup.
    credited: bool,
}

impl FairScheduler {
    /// Build from per-model policies (validated again here so direct
    /// constructions can't smuggle in weight 0). The quantum is the
    /// largest `max_batch` across models, guaranteeing every ready
    /// model at least one full batch per visit.
    pub fn new(policies: &[Policy]) -> Result<FairScheduler> {
        if policies.is_empty() {
            bail!("scheduler needs at least one model policy");
        }
        for (i, p) in policies.iter().enumerate() {
            p.validate().with_context(|| format!("model id {i} policy"))?;
        }
        let quantum = policies.iter().map(|p| p.max_batch).max().unwrap() as u64;
        Ok(FairScheduler {
            quantum,
            weights: policies.iter().map(|p| p.weight as u64).collect(),
            max_batches: policies.iter().map(|p| p.max_batch).collect(),
            deficits: vec![0; policies.len()],
            cursor: 0,
            credited: false,
        })
    }

    /// Images of credit a model earns per visit per weight unit.
    pub fn quantum(&self) -> u64 {
        self.quantum
    }

    pub fn n_models(&self) -> usize {
        self.weights.len()
    }

    /// Current deficit (images of unspent credit; negative after an
    /// oversized admission).
    pub fn deficit(&self, id: usize) -> i64 {
        self.deficits[id]
    }

    /// Charge an out-of-pass admission against a model's deficit (the
    /// scheduler loop's work-conservation path: an idle pool admits a
    /// debt-paying model's batch rather than idling — the charge keeps
    /// the long-run accounting honest, floored at [`DEBT_FLOOR`]).
    pub fn charge(&mut self, id: usize, images: usize) {
        self.deficits[id] = (self.deficits[id] - images as i64).max(DEBT_FLOOR);
    }

    /// Replace one model's weight mid-run — the [`SloAdapter`]'s lever.
    /// Clamped to 1..=[`MAX_WEIGHT`] (never 0: starvation freedom is a
    /// structural invariant, not a policy choice). Deficits are left
    /// untouched, so the new weight simply applies from the model's
    /// next credit onward.
    pub fn set_weight(&mut self, id: usize, weight: u32) {
        self.weights[id] = weight.clamp(1, MAX_WEIGHT) as u64;
    }

    /// Current weight for a model (the static policy weight until
    /// [`FairScheduler::set_weight`] changes it).
    pub fn weight(&self, id: usize) -> u32 {
        self.weights[id] as u32
    }

    /// Seed one model's deficit from a predecessor scheduler (control-
    /// plane swap carry-over: surviving models keep their DRR credit
    /// and, crucially, their oversize debt — a swap must not launder
    /// it). Clamped to the same range `service` maintains: at most one
    /// visit's credit, at least [`DEBT_FLOOR`].
    pub(crate) fn set_deficit(&mut self, id: usize, deficit: i64) {
        let credit = (self.quantum * self.weights[id]) as i64;
        self.deficits[id] = deficit.clamp(DEBT_FLOOR, credit);
    }

    fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.weights.len();
        self.credited = false;
    }

    /// One service pass: visit up to `n_models` cursor positions,
    /// crediting each ready model `quantum x weight` images **once per
    /// visit** (clamped at one visit's worth so a parked model cannot
    /// bank credit across wakeups) and admitting batches while its
    /// deficit stays positive.
    ///
    /// * `ready(id)` — does model `id` have an admissible batch (full
    ///   batch queued, straggler deadline expired, or draining)?
    ///   Not-ready models keep their *debt* (negative deficit — an
    ///   oversized admission must be paid down even across idle gaps)
    ///   but lose any positive credit, then are passed over.
    /// * `admit(id, max_images)` — pop ONE batch of at most
    ///   `max_images` images (an oversized front request alone) and
    ///   submit it; see [`Grant`]. `Blocked` ends the pass with the
    ///   cursor parked on this model.
    ///
    /// Returns total images admitted this pass. With no blocking, one
    /// pass visits every model exactly once — a classic DRR round.
    pub fn service(
        &mut self,
        ready: &mut dyn FnMut(usize) -> bool,
        admit: &mut dyn FnMut(usize, usize) -> Grant,
    ) -> u64 {
        let mut total = 0u64;
        for _ in 0..self.weights.len() {
            let id = self.cursor;
            if !ready(id) {
                // Keep oversize debt, drop unused positive credit:
                // weight credit must not accrue while a model declines
                // service, but debt repayment cannot be dodged by
                // going briefly idle.
                self.deficits[id] = self.deficits[id].min(0);
                self.advance();
                continue;
            }
            if !self.credited {
                let credit = (self.quantum * self.weights[id]) as i64;
                self.deficits[id] = (self.deficits[id] + credit).min(credit);
                self.credited = true;
            }
            while self.deficits[id] > 0 {
                match admit(id, self.max_batches[id]) {
                    Grant::Admitted(got) => {
                        // floored at one protocol-max request of debt
                        self.deficits[id] =
                            (self.deficits[id] - got as i64).max(DEBT_FLOOR);
                        total += got as u64;
                    }
                    Grant::Skip => break,
                    Grant::Blocked => return total, // park; resume here
                }
            }
            self.advance();
        }
        total
    }
}

/// Hard cap on the SLO weight boost: an adaptive weight never exceeds
/// `SLO_FACTOR_MAX ×` the static policy weight (and never [`MAX_WEIGHT`]).
/// Bounded by design so one missed SLO cannot monopolize the pool.
pub const SLO_FACTOR_MAX: f64 = 8.0;

/// Multiplicative boost per adaptation tick while the SLO is missed
/// (scaled by how far past the target the EWMA sits, capped at 2x the
/// overshoot). Small on purpose: ~7 ticks (≈2s) to double a weight.
const SLO_STEP: f64 = 0.1;

/// Fraction of the remaining distance back to the static weight
/// recovered per tick once the SLO is met (or no signal arrives).
const SLO_RETURN_RATE: f64 = 0.1;

/// Relative deadband around the SLO: within ±5% the factor only
/// decays, so p99 ≈ SLO converges to the static weight instead of
/// oscillating around it.
const SLO_DEADBAND: f64 = 0.05;

/// EWMA smoothing applied to per-interval observed p99s.
const SLO_EWMA_ALPHA: f64 = 0.2;

/// Minimum requests completed in an adaptation interval for its p99 to
/// update the EWMA — a 3-request interval's "p99" is noise.
pub const SLO_MIN_SAMPLES: u64 = 16;

/// How often the scheduler loop runs an adaptation tick (only when at
/// least one model sets `slo_us`; otherwise the loop never wakes for it).
pub(crate) const SLO_ADAPT_INTERVAL: Duration = Duration::from_millis(250);

/// SLO-driven weight adaptation: turns PR 4's static fair-share
/// weights adaptive, bounded, and self-reverting. Pure state machine —
/// observed p99s come in through [`SloAdapter::tick`], effective
/// weights come out — so the control law is unit- and property-
/// testable without threads or clocks (`rust/tests/sched_props.rs`).
///
/// Dynamics per tick, per model with an SLO:
/// 1. A fresh interval p99 (if the interval had ≥ [`SLO_MIN_SAMPLES`]
///    requests) folds into a slow EWMA.
/// 2. EWMA above `slo × (1 + deadband)` → multiply the boost factor up
///    (proportional to the overshoot); otherwise decay it toward 1.
/// 3. Factor clamps to `[1, SLO_FACTOR_MAX]`; the effective weight is
///    `round(static × factor)` clamped to `[static, MAX_WEIGHT]`.
///
/// Boost-only by construction: weights never drop below the static
/// policy value, so no model can be *penalized* by another model's SLO
/// and the PR 4 starvation bound (every ready model served every
/// round) is preserved verbatim.
pub struct SloAdapter {
    static_weights: Vec<u32>,
    slo_us: Vec<Option<u64>>,
    ewma_p99_us: Vec<Option<f64>>,
    factors: Vec<f64>,
}

impl SloAdapter {
    pub fn new(policies: &[Policy]) -> SloAdapter {
        SloAdapter {
            static_weights: policies.iter().map(|p| p.weight).collect(),
            slo_us: policies.iter().map(|p| p.slo_us).collect(),
            ewma_p99_us: vec![None; policies.len()],
            factors: vec![1.0; policies.len()],
        }
    }

    /// Does any model carry an SLO? When false the scheduler skips
    /// adaptation entirely (no periodic wakeups, no overhead).
    pub fn enabled(&self) -> bool {
        self.slo_us.iter().any(|s| s.is_some())
    }

    /// Current boost factor (1.0 = static weight).
    pub fn factor(&self, id: usize) -> f64 {
        self.factors[id]
    }

    /// Smoothed observed p99, once enough samples arrived.
    pub fn ewma_p99_us(&self, id: usize) -> Option<f64> {
        self.ewma_p99_us[id]
    }

    /// Seed one model's adaptation state from a predecessor adapter
    /// (control-plane swap carry-over): an SLO model keeps its boost
    /// and smoothed p99 across a swap instead of re-learning from
    /// scratch. The factor is clamped to the invariant range.
    pub(crate) fn seed(&mut self, id: usize, ewma_p99_us: Option<f64>, factor: f64) {
        self.ewma_p99_us[id] = ewma_p99_us;
        self.factors[id] = factor.clamp(1.0, SLO_FACTOR_MAX);
    }

    /// Effective weight for one model under the current factors.
    pub fn effective_weight(&self, id: usize) -> u32 {
        let w = (self.static_weights[id] as f64 * self.factors[id]).round() as u32;
        w.clamp(self.static_weights[id], MAX_WEIGHT)
    }

    /// One adaptation step: fold this interval's observed p99s
    /// (`None` = too few samples) into the EWMAs, move the boost
    /// factors, and return the effective weight per model (models
    /// without an SLO always return their static weight).
    pub fn tick(&mut self, interval_p99_us: &[Option<f64>]) -> Vec<u32> {
        for id in 0..self.factors.len() {
            let Some(slo) = self.slo_us[id] else { continue };
            let fresh = interval_p99_us.get(id).copied().flatten();
            if let Some(p99) = fresh {
                self.ewma_p99_us[id] = Some(match self.ewma_p99_us[id] {
                    Some(prev) => prev + SLO_EWMA_ALPHA * (p99 - prev),
                    None => p99,
                });
            }
            let f = &mut self.factors[id];
            match (fresh, self.ewma_p99_us[id]) {
                // boost only on live evidence: a stale miss EWMA with
                // no fresh samples means the traffic stopped, and an
                // idle model needs no boost
                (Some(_), Some(e)) if e > slo as f64 * (1.0 + SLO_DEADBAND) => {
                    let over = (e / slo as f64 - 1.0).min(1.0);
                    *f *= 1.0 + SLO_STEP * over;
                }
                // met, inside the deadband, or no signal: drift home
                _ => *f += (1.0 - *f) * SLO_RETURN_RATE,
            }
            *f = f.clamp(1.0, SLO_FACTOR_MAX);
        }
        (0..self.factors.len())
            .map(|id| self.effective_weight(id))
            .collect()
    }
}

/// Where a completed request's predictions go. The channel carries the
/// payload; the optional waker interrupts the connection event loop's
/// `Poller::wait` so the response is flushed promptly — without it a
/// completion would sit in the channel until some unrelated socket
/// event (the loop parks in the kernel, not on this channel). Blocking
/// callers (tests, the pre-event-loop client helpers) just omit the
/// waker and `recv()` as before.
pub(crate) struct ReplySink {
    tx: mpsc::Sender<Result<Vec<u32>, String>>,
    waker: Option<Arc<crate::util::poll::Waker>>,
}

impl ReplySink {
    /// Channel-only sink (blocking consumers).
    pub fn new(tx: mpsc::Sender<Result<Vec<u32>, String>>) -> ReplySink {
        ReplySink { tx, waker: None }
    }

    /// Sink that also rings an event loop's waker on every send.
    pub fn with_waker(
        tx: mpsc::Sender<Result<Vec<u32>, String>>,
        waker: Arc<crate::util::poll::Waker>,
    ) -> ReplySink {
        ReplySink {
            tx,
            waker: Some(waker),
        }
    }

    /// Deliver the result. A gone receiver means the connection already
    /// died — fine either way; the waker still rings so the loop can
    /// retry queue-parked requests (freed pool capacity means the
    /// scheduler just popped, i.e. queue space may have opened up).
    pub fn send(&self, r: Result<Vec<u32>, String>) {
        let _ = self.tx.send(r);
        if let Some(w) = &self.waker {
            w.wake();
        }
    }
}

impl Drop for ReplySink {
    /// A sink dropped without sending (queue shutdown, pool submit
    /// failure) leaves its connection's receiver disconnected — ring
    /// the loop anyway so it notices promptly instead of waiting for an
    /// unrelated event. Answered requests ring twice; wakes coalesce.
    fn drop(&mut self) {
        if let Some(w) = &self.waker {
            w.wake();
        }
    }
}

/// One parsed request waiting to be scheduled.
pub(crate) struct Pending {
    pub images: Vec<f32>,
    pub n: usize,
    pub reply: ReplySink,
    /// Arrival time — the straggler deadline is `enqueued_at + wait`.
    pub enqueued_at: Instant,
}

#[derive(Default)]
struct QueueState {
    items: VecDeque<Pending>,
    queued_images: usize,
    shutdown: bool,
    /// FIFO admission tickets: `next_ticket` is taken on push arrival,
    /// `serving` is the ticket currently allowed to admit. Without
    /// this, a large request could starve forever behind a stream of
    /// small ones that always win the condvar race.
    next_ticket: u64,
    serving: u64,
    /// Push-side image cap (the policy's `queue_images`). Lives under
    /// the lock so a control-plane `policy` retune applies to live
    /// queues ([`BatchQueue::set_bounds`]).
    cap_images: usize,
    /// The model's `max_batch`: push uses it to detect the
    /// became-admissible transitions that must wake the scheduler.
    ready_images: usize,
}

/// Outcome of a non-blocking [`BatchQueue::try_push`].
pub(crate) enum TryPush {
    /// Enqueued; the bool is the became-admissible doorbell hint (same
    /// meaning as the blocking push's `Some(ring)`).
    Queued(bool),
    /// No room (or ticketed pushers are ahead); the request comes back
    /// untouched so the caller can park it.
    Full(Pending),
    /// Server shutting down; the request is dropped.
    Shutdown,
}

/// What a non-destructive queue poll saw (scheduler-side view).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Poll {
    /// An admissible batch is available right now.
    Ready,
    /// Requests queued, none admissible yet; the front dispatches at
    /// this deadline.
    Wait(Instant),
    /// Nothing queued.
    Empty,
    /// Shut down and fully drained.
    Drained,
}

/// Bounded request queue: connection threads push (blocking on the
/// per-model image cap — backpressure stays per model), the scheduler
/// polls and pops coalesced batches. Popping is non-blocking
/// ([`BatchQueue::try_pop`]) because ONE scheduler thread multiplexes
/// every model's queue; the blocking wait lives in the scheduler's
/// doorbell, not here.
pub(crate) struct BatchQueue {
    state: Mutex<QueueState>,
    not_full: Condvar,
}

impl BatchQueue {
    pub fn new(cap_images: usize, ready_images: usize) -> Self {
        BatchQueue {
            // The configured bound is honored as-is: push admits a
            // request larger than the cap only when the queue is empty,
            // so a tight bound can't deadlock a max-size request.
            state: Mutex::new(QueueState {
                cap_images,
                ready_images,
                ..QueueState::default()
            }),
            not_full: Condvar::new(),
        }
    }

    /// Retune the push-side bounds in place (a control-plane `policy`
    /// swap). A raised cap may unblock parked pushers, so waiters are
    /// notified; a lowered cap applies to future pushes only — nothing
    /// already queued is dropped.
    pub fn set_bounds(&self, cap_images: usize, ready_images: usize) {
        let mut st = self.state.lock().unwrap();
        st.cap_images = cap_images;
        st.ready_images = ready_images;
        drop(st);
        self.not_full.notify_all();
    }

    /// Block until there is room, then enqueue (FIFO across blocked
    /// pushers — see `QueueState` tickets; while a large request waits,
    /// later arrivals wait behind it, so the queue drains and even an
    /// over-cap request is eventually admitted alone). Returns `None`
    /// if the server is shutting down (request dropped); otherwise
    /// `Some(ring)` — ring the scheduler's doorbell only when this push
    /// could have changed its plans: the queue went empty→non-empty
    /// (the sleeping scheduler knows no deadline for it yet) or the
    /// fill crossed `ready_images` (Wait→Ready). A Wait→Wait push
    /// leaves the front request — and thus the scheduler's sleep
    /// deadline — unchanged, so under saturating arrival rates the
    /// scheduler isn't stampeded with a wakeup per request.
    ///
    /// The event-loop server pushes through the non-blocking
    /// [`BatchQueue::try_push`] instead; this blocking form stays as
    /// the reference semantics try_push must agree with (the unit
    /// tests run both against the same queue states).
    #[cfg_attr(not(test), allow(dead_code))]
    pub fn push(&self, p: Pending, stats: &Stats) -> Option<bool> {
        let mut st = self.state.lock().unwrap();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        while !st.shutdown
            && (ticket != st.serving
                || (!st.items.is_empty() && st.queued_images + p.n > st.cap_images))
        {
            st = self.not_full.wait(st).unwrap();
        }
        if st.shutdown {
            // Terminal: every other waiter also exits via this branch,
            // so the unconsumed ticket cannot wedge the line.
            return None;
        }
        let was_empty = st.items.is_empty();
        let old_images = st.queued_images;
        st.serving += 1;
        st.queued_images += p.n;
        let ring = was_empty
            || (old_images < st.ready_images && st.queued_images >= st.ready_images);
        let depth = st.queued_images as u64;
        st.items.push_back(p);
        stats.queue_depth.store(depth, Ordering::Relaxed);
        stats.queue_peak.fetch_max(depth, Ordering::Relaxed);
        drop(st);
        // wake the next ticket in line
        self.not_full.notify_all();
        Some(ring)
    }

    /// Non-blocking push for the connection event loop (ONE thread
    /// feeding every queue must never sleep on one model's cap).
    /// `Full` hands the request back — the caller parks it, drops the
    /// connection's read interest (a full queue becomes plain TCP
    /// backpressure), and retries on the next completion wakeup: every
    /// admission's batch ends in a completion that rings the loop's
    /// waker, and a full queue is by definition non-empty, so a retry
    /// wakeup always arrives. Admission honors the same rules as the
    /// blocking [`BatchQueue::push`]: FIFO behind any ticketed blocked
    /// pushers, the image cap, and the empty-queue oversize exception.
    pub fn try_push(&self, p: Pending, stats: &Stats) -> TryPush {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return TryPush::Shutdown;
        }
        if st.next_ticket != st.serving
            || (!st.items.is_empty() && st.queued_images + p.n > st.cap_images)
        {
            return TryPush::Full(p);
        }
        st.next_ticket += 1;
        st.serving += 1;
        let was_empty = st.items.is_empty();
        let old_images = st.queued_images;
        st.queued_images += p.n;
        let ring = was_empty
            || (old_images < st.ready_images && st.queued_images >= st.ready_images);
        let depth = st.queued_images as u64;
        st.items.push_back(p);
        stats.queue_depth.store(depth, Ordering::Relaxed);
        stats.queue_peak.fetch_max(depth, Ordering::Relaxed);
        TryPush::Queued(ring)
    }

    /// Is a batch admissible under (`max_images`, `wait`) at `now`?
    /// Admissible = a full batch's worth of images is queued, the
    /// front request's straggler deadline has expired, or the server
    /// is draining. Never blocks, never pops.
    pub fn poll(&self, max_images: usize, wait: Duration, now: Instant) -> Poll {
        let st = self.state.lock().unwrap();
        let Some(front) = st.items.front() else {
            return if st.shutdown { Poll::Drained } else { Poll::Empty };
        };
        let deadline = front.enqueued_at + wait;
        if st.shutdown || st.queued_images >= max_images || deadline <= now {
            Poll::Ready
        } else {
            Poll::Wait(deadline)
        }
    }

    /// Pop one coalesced batch of at most `max_images` images if one is
    /// admissible (same condition as [`BatchQueue::poll`]); the front
    /// request is always taken even when oversized — the pool shards it
    /// across workers anyway. Returns None when nothing is admissible.
    pub fn try_pop(
        &self,
        max_images: usize,
        wait: Duration,
        now: Instant,
        stats: &Stats,
    ) -> Option<Vec<Pending>> {
        let mut st = self.state.lock().unwrap();
        let front = st.items.front()?;
        let deadline = front.enqueued_at + wait;
        if !st.shutdown && st.queued_images < max_images && deadline > now {
            return None;
        }
        let mut batch = Vec::new();
        let mut images = 0usize;
        while let Some(front) = st.items.front() {
            if !batch.is_empty() && images + front.n > max_images {
                break;
            }
            let p = st.items.pop_front().unwrap();
            images += p.n;
            st.queued_images -= p.n;
            batch.push(p);
        }
        stats
            .queue_depth
            .store(st.queued_images as u64, Ordering::Relaxed);
        drop(st);
        // Space freed: wake pushers blocked on the per-model cap.
        self.not_full.notify_all();
        Some(batch)
    }

    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.not_full.notify_all();
    }
}

/// Wakeup channel for the scheduler thread: an epoch counter under a
/// mutex. Ring on request arrival, batch completion, and shutdown;
/// the scheduler snapshots the epoch *before* scanning queues, so a
/// ring that races the scan is never lost.
pub(crate) struct Doorbell {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Doorbell {
    pub fn new() -> Self {
        Doorbell {
            epoch: Mutex::new(0),
            cv: Condvar::new(),
        }
    }

    pub fn ring(&self) {
        *self.epoch.lock().unwrap() += 1;
        self.cv.notify_all();
    }

    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Park until the epoch moves past `seen`, or (when given) until
    /// `deadline` — whichever comes first.
    pub fn wait_past(&self, seen: u64, deadline: Option<Instant>) {
        let mut e = self.epoch.lock().unwrap();
        while *e == seen {
            match deadline {
                None => e = self.cv.wait(e).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return;
                    }
                    let (guard, timeout) = self.cv.wait_timeout(e, d - now).unwrap();
                    e = guard;
                    if timeout.timed_out() {
                        return;
                    }
                }
            }
        }
    }
}

/// In-flight image cap for scheduler admission: about two max-size
/// batches (never below two images per worker), enough to keep every
/// worker pipelined while bounding how far FIFO pool order can run
/// ahead of the weighted shares.
pub(crate) fn inflight_cap(quantum: u64, workers: usize) -> u64 {
    (2 * quantum).max(2 * workers as u64)
}

/// Everything the scheduler loop multiplexes: the control plane's
/// current epoch state (registry + one slot per model: queue, policy,
/// engine, counters) plus the shared pool, stats, and wakeup plumbing.
pub(crate) struct SchedCtx {
    pub control: Arc<super::reload::ControlPlane>,
    pub stats: Arc<ServerStats>,
    pub pool: Arc<InferencePool>,
    pub doorbell: Arc<Doorbell>,
    /// Images submitted to the pool and not yet completed.
    pub in_flight: Arc<AtomicU64>,
}

/// The scheduler loop: ONE thread replacing the N per-model batchers.
/// Runs DRR rounds while admissible work and in-flight headroom exist,
/// parks on the doorbell (bounded by the earliest straggler deadline)
/// otherwise, and exits once every queue reports shut-down-and-drained.
/// In-flight batches at exit are completed by the pool's workers before
/// the pool joins them (results flow through each batch's done
/// callback, not through this thread).
///
/// On a control-plane swap (epoch change) the loop rebuilds its
/// [`FairScheduler`] and [`SloAdapter`] over the new slot table,
/// carrying per-slot DRR deficits and SLO state for surviving slots —
/// see [`rebuild_for_epoch`]. Tombstoned slots stay in the rotation
/// with the policy they died with, so work queued before a removal
/// drains on the old engine under the old batching rules.
pub(crate) fn run_scheduler(ctx: SchedCtx) {
    let mut state = ctx.control.current();
    let policies: Vec<Policy> = state.slots.iter().map(|s| s.policy).collect();
    let mut fs = FairScheduler::new(&policies).expect("policies validated at bind");
    let mut cap = inflight_cap(fs.quantum(), ctx.pool.workers());
    let mut polls = vec![Poll::Empty; state.slots.len()];
    // SLO adaptation state: e2e-histogram snapshots to diff per
    // interval. All of it is dead weight (no wakeups, no work) unless
    // some policy actually sets `slo_us`.
    let mut slo = SloAdapter::new(&policies);
    let mut slo_on = slo.enabled();
    let mut last_e2e: Vec<_> = state
        .slots
        .iter()
        .map(|s| s.stats.e2e_hist.counts())
        .collect();
    let mut next_adapt = Instant::now() + SLO_ADAPT_INTERVAL;
    loop {
        let tick = ctx.doorbell.epoch();
        if ctx.control.epoch() != state.epoch {
            state = ctx.control.current();
            let (nfs, nslo) = rebuild_for_epoch(&state, &fs, &slo, &mut last_e2e);
            fs = nfs;
            slo = nslo;
            slo_on = slo.enabled();
            cap = inflight_cap(fs.quantum(), ctx.pool.workers());
            polls = vec![Poll::Empty; state.slots.len()];
        }
        let n = state.slots.len();
        let now = Instant::now();
        if slo_on && now >= next_adapt {
            adapt_slo_weights(&state, &mut fs, &mut slo, &mut last_e2e);
            next_adapt = now + SLO_ADAPT_INTERVAL;
        }
        for (id, slot) in state.slots.iter().enumerate() {
            polls[id] = slot.queue.poll(slot.policy.max_batch, slot.policy.wait(), now);
        }
        if polls.iter().all(|p| *p == Poll::Drained) {
            return;
        }
        let any_ready = polls.iter().any(|p| *p == Poll::Ready);
        let room = ctx.in_flight.load(Ordering::Acquire) < cap;
        if any_ready && room {
            let admitted = fs.service(
                &mut |id| polls[id] == Poll::Ready,
                &mut |id, max_images| admit_one(&ctx, &state, cap, id, max_images),
            );
            for id in 0..n {
                state.slots[id]
                    .stats
                    .deficit
                    .store(fs.deficit(id), Ordering::Relaxed);
            }
            if admitted > 0 {
                ctx.stats.rounds.fetch_add(1, Ordering::Relaxed);
                continue; // back-to-back passes while work + headroom exist
            }
            // Work-conservation: a pass can admit nothing while a model
            // is still paying down oversize debt (deficit <= 0 after its
            // credit). With batches in flight the next completion rings
            // another pass; with an IDLE pool no future event would —
            // so admit one batch from the first ready model regardless
            // of debt (charged, so long-run weights stay honest; with
            // nothing else runnable, fairness costs nobody anything).
            if ctx.in_flight.load(Ordering::Acquire) == 0 {
                let mut forced = 0usize;
                for id in 0..n {
                    if polls[id] != Poll::Ready {
                        continue;
                    }
                    if let Grant::Admitted(got) =
                        admit_one(&ctx, &state, cap, id, state.slots[id].policy.max_batch)
                    {
                        fs.charge(id, got);
                        state.slots[id]
                            .stats
                            .deficit
                            .store(fs.deficit(id), Ordering::Relaxed);
                        forced = got;
                        break;
                    }
                }
                if forced > 0 {
                    ctx.stats.rounds.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
        }
        // (When the pool is saturated — any_ready && !room — the next
        // completion rings the doorbell; `deferred` is counted only at
        // actual Blocked admission attempts inside admit_one, so the
        // stat isn't amplified by every push-wakeup during saturation.)
        let deadline = polls
            .iter()
            .filter_map(|p| match p {
                Poll::Wait(d) => Some(*d),
                _ => None,
            })
            .min();
        // With SLO adaptation live, parking also bounds at the next
        // adaptation tick so a long idle stretch still decays boosts.
        let deadline = if slo_on {
            Some(deadline.map_or(next_adapt, |d| d.min(next_adapt)))
        } else {
            deadline
        };
        ctx.doorbell.wait_past(tick, deadline);
    }
}

/// Rebuild the DRR + SLO state over a new epoch's slot table: a fresh
/// [`FairScheduler`]/[`SloAdapter`] from the (re-resolved) policies,
/// with each surviving slot's deficit, boost factor, and p99 EWMA
/// seeded from the predecessor (slot ids are stable across swaps and
/// the table only grows). New slots start clean; their e2e snapshot
/// baseline is their (zero) current histogram.
fn rebuild_for_epoch(
    state: &super::reload::EpochState,
    old_fs: &FairScheduler,
    old_slo: &SloAdapter,
    last_e2e: &mut Vec<[u64; super::metrics::LAT_BUCKETS]>,
) -> (FairScheduler, SloAdapter) {
    let policies: Vec<Policy> = state.slots.iter().map(|s| s.policy).collect();
    let mut fs =
        FairScheduler::new(&policies).expect("control plane re-validates policies per swap");
    let mut slo = SloAdapter::new(&policies);
    for id in 0..old_fs.n_models().min(policies.len()) {
        fs.set_deficit(id, old_fs.deficit(id));
        slo.seed(id, old_slo.ewma_p99_us(id), old_slo.factor(id));
    }
    while last_e2e.len() < state.slots.len() {
        let id = last_e2e.len();
        last_e2e.push(state.slots[id].stats.e2e_hist.counts());
    }
    (fs, slo)
}

/// One SLO adaptation tick: diff each model's e2e histogram against
/// the last tick's snapshot, estimate the interval p99 (when the
/// interval saw ≥ [`SLO_MIN_SAMPLES`] requests), feed the adapter, and
/// install the resulting weights + gauges. Runs on the scheduler
/// thread between passes — never on the serving path.
fn adapt_slo_weights(
    state: &super::reload::EpochState,
    fs: &mut FairScheduler,
    slo: &mut SloAdapter,
    last_e2e: &mut [[u64; super::metrics::LAT_BUCKETS]],
) {
    let n = state.slots.len();
    let mut p99s = vec![None; n];
    for id in 0..n {
        let cur = state.slots[id].stats.e2e_hist.counts();
        let mut delta = [0u64; super::metrics::LAT_BUCKETS];
        let mut total = 0u64;
        for b in 0..super::metrics::LAT_BUCKETS {
            // counters are monotone; saturate anyway (relaxed loads)
            delta[b] = cur[b].saturating_sub(last_e2e[id][b]);
            total += delta[b];
        }
        last_e2e[id] = cur;
        if total >= SLO_MIN_SAMPLES {
            p99s[id] = crate::util::quantile::bucket_quantile(&delta, 0.99);
        }
    }
    let weights = slo.tick(&p99s);
    for id in 0..n {
        fs.set_weight(id, weights[id]);
        state.slots[id].stats.effective_weight_milli.store(
            (state.slots[id].policy.weight as f64 * slo.factor(id) * 1000.0).round() as u64,
            Ordering::Relaxed,
        );
    }
}

/// Admit one batch from model `id` into the pool: pop, flatten, submit
/// with a completion callback that answers every coalesced request,
/// then account. `Blocked` = in-flight cap reached (the pass parks
/// here); `Skip` = nothing admissible from this queue right now.
fn admit_one(
    ctx: &SchedCtx,
    state: &super::reload::EpochState,
    cap: u64,
    id: usize,
    max_images: usize,
) -> Grant {
    let slot = &state.slots[id];
    if ctx.in_flight.load(Ordering::Acquire) >= cap {
        slot.stats.deferred.fetch_add(1, Ordering::Relaxed);
        return Grant::Blocked;
    }
    let stats = &slot.stats;
    let Some(mut batch) = slot.queue.try_pop(
        max_images,
        slot.policy.wait(),
        Instant::now(),
        stats,
    ) else {
        return Grant::Skip;
    };
    // Queue wait per popped request: enqueue (payload decoded) → here.
    let popped_at = Instant::now();
    for p in &batch {
        stats.queue_wait_hist.observe(
            popped_at
                .saturating_duration_since(p.enqueued_at)
                .as_micros() as u64,
        );
    }
    let n: usize = batch.iter().map(|p| p.n).sum();
    let flat = if batch.len() == 1 {
        // Common un-coalesced case: the request's buffer is already
        // flat — move it instead of re-copying the payload.
        std::mem::take(&mut batch[0].images)
    } else {
        let mut flat = Vec::with_capacity(batch.iter().map(|p| p.images.len()).sum());
        for p in &mut batch {
            // free each source buffer as it's copied: `batch` lives on
            // inside the completion callback, and keeping every
            // payload alive there would double the batch's memory for
            // the whole inference
            let imgs = std::mem::take(&mut p.images);
            flat.extend_from_slice(&imgs);
        }
        flat
    };
    ctx.in_flight.fetch_add(n as u64, Ordering::AcqRel);
    stats.admitted.fetch_add(1, Ordering::Relaxed);
    let done = {
        let stats = stats.clone();
        let in_flight = ctx.in_flight.clone();
        let doorbell = ctx.doorbell.clone();
        let t0 = Instant::now();
        move |result: Result<Vec<usize>, String>| {
            match result {
                Ok(preds) => {
                    stats.observe_batch(n, t0.elapsed().as_micros() as u64);
                    let mut off = 0usize;
                    for p in batch {
                        let out: Vec<u32> =
                            preds[off..off + p.n].iter().map(|&c| c as u32).collect();
                        off += p.n;
                        // Receiver gone = connection already died; fine.
                        p.reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    stats.failed_batches.fetch_add(1, Ordering::Relaxed);
                    for p in batch {
                        p.reply.send(Err(e.clone()));
                    }
                }
            }
            in_flight.fetch_sub(n as u64, Ordering::AcqRel);
            doorbell.ring();
        }
    };
    if let Err(e) = ctx.pool.submit(
        id as u16,
        &slot.engine,
        Arc::new(flat),
        n,
        Box::new(done),
    ) {
        // Pool gone (cannot happen while the server owns it, but stay
        // honest): `submit` only fails before dispatch, with the
        // callback dropped un-invoked — dropping the replies closes the
        // waiting connections instead of hanging them.
        stats.failed_batches.fetch_add(1, Ordering::Relaxed);
        ctx.in_flight.fetch_sub(n as u64, Ordering::AcqRel);
        eprintln!("aquant-serve: pool submit failed: {e:#}");
        return Grant::Skip;
    }
    Grant::Admitted(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, weight: u32) -> Policy {
        Policy {
            max_batch,
            batch_wait_us: 0,
            queue_images: 8192,
            weight,
            slo_us: None,
        }
    }

    #[test]
    fn policy_resolve_fills_defaults_and_validates() {
        let d = Policy::from_serve_cfg(&ServeConfig::default());
        assert_eq!(d.max_batch, 64);
        assert_eq!(d.weight, 1);
        let over = PolicyOverrides {
            max_batch: Some(8),
            weight: Some(3),
            ..PolicyOverrides::default()
        };
        let p = Policy::resolve(&d, &over).unwrap();
        assert_eq!(p.max_batch, 8);
        assert_eq!(p.batch_wait_us, d.batch_wait_us);
        assert_eq!(p.queue_images, d.queue_images);
        assert_eq!(p.weight, 3);

        // weight 0 is rejected, not clamped
        let bad = PolicyOverrides {
            weight: Some(0),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &bad).is_err());
        let bad = PolicyOverrides {
            weight: Some(MAX_WEIGHT + 1),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &bad).is_err());
        // per-model bounds mirror the global ones
        let bad = PolicyOverrides {
            max_batch: Some(0),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &bad).is_err());
        let bad = PolicyOverrides {
            queue_images: Some(4),
            max_batch: Some(8),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &bad).is_err());
        // bounded max_batch: quantum * weight must stay overflow-safe
        let bad = PolicyOverrides {
            max_batch: Some(ServeConfig::MAX_MAX_BATCH + 1),
            queue_images: Some(usize::MAX),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &bad).is_err());
        let ok = PolicyOverrides {
            max_batch: Some(ServeConfig::MAX_MAX_BATCH),
            queue_images: Some(ServeConfig::MAX_MAX_BATCH),
            weight: Some(MAX_WEIGHT),
            ..PolicyOverrides::default()
        };
        assert!(Policy::resolve(&d, &ok).is_ok());
    }

    #[test]
    fn scheduler_rejects_weight_zero_and_empty() {
        assert!(FairScheduler::new(&[]).is_err());
        let mut p = policy(8, 1);
        p.weight = 0;
        assert!(FairScheduler::new(&[p]).is_err());
        assert!(FairScheduler::new(&[policy(8, 3), policy(8, 1)]).is_ok());
    }

    /// Simulated backlogged queues: `admit` serves whole batches of
    /// `req` -image requests up to the max_images bound (no
    /// backpressure — one pass == one classic DRR round).
    fn drain_round(
        fs: &mut FairScheduler,
        backlog: &mut [u64],
        req: usize,
    ) -> Vec<u64> {
        let mut admitted = vec![0u64; backlog.len()];
        // readiness snapshot, as in the real scheduler loop
        let ready: Vec<bool> = backlog.iter().map(|b| *b > 0).collect();
        fs.service(
            &mut |id| ready[id],
            &mut |id, max_images| {
                if backlog[id] == 0 {
                    return Grant::Skip;
                }
                // a batch = as many req-sized requests as fit (>= 1)
                let per = ((max_images / req).max(1) * req) as u64;
                let take = per.min(backlog[id]);
                backlog[id] -= take;
                admitted[id] += take;
                Grant::Admitted(take as usize)
            },
        );
        admitted
    }

    #[test]
    fn backlogged_weights_3_to_1_admit_in_exact_ratio() {
        // Acceptance criterion: 2 models, weights 3:1, both saturated —
        // admitted accounting matches 3:1 within one quantum per round.
        let mut fs = FairScheduler::new(&[policy(8, 3), policy(8, 1)]).unwrap();
        let q = fs.quantum();
        assert_eq!(q, 8);
        let mut backlog = [1_000_000u64, 1_000_000u64];
        let mut tot = [0u64, 0u64];
        for _ in 0..100 {
            let adm = drain_round(&mut fs, &mut backlog, 1);
            // per-round deviation from the weighted share is < 1 quantum
            assert!(adm[0] <= 3 * q + q, "round admitted {} for weight 3", adm[0]);
            assert!(adm[1] <= q + q, "round admitted {} for weight 1", adm[1]);
            tot[0] += adm[0];
            tot[1] += adm[1];
        }
        // 1-image requests divide the quantum exactly: the ratio is exact
        assert_eq!(tot[0], 3 * tot[1], "admitted {tot:?}");
        assert_eq!(tot[1], 100 * q);
        // zero starvation: the low-weight model was served every round
        assert!(tot[1] > 0);
    }

    #[test]
    fn ragged_requests_stay_within_one_quantum_per_round() {
        // 3-image requests do not divide max_batch 8: per-round
        // admissions overshoot by at most one batch (< one quantum).
        let mut fs = FairScheduler::new(&[policy(8, 3), policy(8, 1)]).unwrap();
        let q = fs.quantum() as i64;
        let mut backlog = [600_000u64, 600_000u64];
        let mut tot = [0i64, 0i64];
        for _ in 0..200 {
            let adm = drain_round(&mut fs, &mut backlog, 3);
            tot[0] += adm[0] as i64;
            tot[1] += adm[1] as i64;
            // cumulative deviation from the 3:1 share stays bounded by
            // one quantum per model (the unspent deficit)
            assert!((tot[0] - 3 * tot[1]).abs() <= 4 * q, "{tot:?}");
        }
        assert!(tot[0] > 0 && tot[1] > 0);
    }

    #[test]
    fn oversized_request_goes_negative_then_recovers() {
        let mut fs = FairScheduler::new(&[policy(8, 1), policy(8, 1)]).unwrap();
        let q = fs.quantum() as i64; // 8
        // Model 0's front request is 50 images (oversized, admitted
        // whole once any credit exists), then stays backlogged with
        // full batches; model 1 is backlogged throughout.
        let mut oversize_left = true;
        let mut per_round_m0 = Vec::new();
        let mut m1 = 0u64;
        for round in 0..10 {
            let mut adm0 = 0u64;
            fs.service(
                &mut |_| true,
                &mut |id, max_images| {
                    if id == 0 {
                        let got = if oversize_left {
                            oversize_left = false;
                            50 // single oversized request, admitted alone
                        } else {
                            max_images
                        };
                        adm0 += got as u64;
                        Grant::Admitted(got)
                    } else {
                        m1 += max_images as u64;
                        Grant::Admitted(max_images)
                    }
                },
            );
            per_round_m0.push(adm0);
            if round == 0 {
                // charged in full: deficit went negative (q - 50)
                assert_eq!(fs.deficit(0), q - 50);
            }
        }
        // Rounds 1..=5 pay the debt back (credit +q per round from -42);
        // round 6 the model is above zero again and admits a batch.
        assert_eq!(per_round_m0[0], 50);
        assert_eq!(&per_round_m0[1..6], &[0, 0, 0, 0, 0]);
        assert!(per_round_m0[6] > 0, "{per_round_m0:?}");
        // model 1 kept its full share every round meanwhile
        assert_eq!(m1, 10 * fs.quantum());
        // long-run totals converge back toward the 1:1 weights
        let m0: u64 = per_round_m0.iter().sum();
        assert!(m0.abs_diff(m1) <= 2 * fs.quantum(), "m0 {m0} m1 {m1}");
    }

    #[test]
    fn single_model_degenerates_to_continuous_batching() {
        // PR 2 behavior: one model, any weight — every round admits at
        // least one full batch, and a backlog drains in
        // ceil(backlog / round_admission) back-to-back rounds with no
        // deficit ever blocking a ready batch for more than one round.
        for weight in [1u32, 7] {
            let mut fs = FairScheduler::new(&[policy(16, weight)]).unwrap();
            let mut backlog = [1000u64];
            let mut rounds = 0u64;
            while backlog[0] > 0 {
                let before = backlog[0];
                let adm = drain_round(&mut fs, &mut backlog, 1);
                assert!(
                    adm[0] >= before.min(16),
                    "a ready model admits >= one full batch (got {})",
                    adm[0]
                );
                rounds += 1;
                assert!(rounds <= 1000, "drain must terminate");
            }
            // weight only changes round granularity, not completion
            assert_eq!(backlog[0], 0, "weight {weight}");
        }
    }

    #[test]
    fn blocked_passes_do_not_bank_credit() {
        let mut fs = FairScheduler::new(&[policy(8, 2)]).unwrap();
        // model is ready but admission is fully backpressured: the
        // cursor parks, and parked wakeups must not re-credit
        for _ in 0..10 {
            fs.service(&mut |_| true, &mut |_, _| Grant::Blocked);
        }
        // credit is one visit's worth, not 10 wakeups' worth
        assert_eq!(fs.deficit(0), 2 * fs.quantum() as i64);
        // idle drops the unused credit entirely
        fs.service(&mut |_| false, &mut |_, _| Grant::Skip);
        assert_eq!(fs.deficit(0), 0);
    }

    #[test]
    fn backpressure_parks_the_cursor_so_low_ids_cannot_lap_high_ids() {
        // Regression: with weights 3:1 and an in-flight cap that fits
        // only 2 batches, a restart-at-id-0 scheduler would let model 0
        // refill the cap on every wakeup and starve model 1 forever.
        // The parked cursor must keep the 3:1 share instead.
        let mut fs = FairScheduler::new(&[policy(8, 3), policy(8, 1)]).unwrap();
        let cap = 16u64; // images, = inflight_cap(8, workers=2)
        let mut in_flight = 0u64;
        let mut completions: Vec<(usize, u64)> = Vec::new(); // (model, images)
        let mut served = [0u64, 0u64];
        // event loop: each iteration = one wakeup (completion or initial)
        for _ in 0..400 {
            fs.service(
                &mut |_| true, // both models saturated forever
                &mut |id, max_images| {
                    if in_flight >= cap {
                        return Grant::Blocked;
                    }
                    let got = max_images as u64;
                    in_flight += got;
                    completions.push((id, got));
                    served[id] += got;
                    Grant::Admitted(max_images)
                },
            );
            // complete the oldest batch (pool FIFO), freeing capacity
            if !completions.is_empty() {
                let (_, done) = completions.remove(0);
                in_flight -= done;
            }
        }
        assert!(served[1] > 0, "high-id model starved: {served:?}");
        let ratio = served[0] as f64 / served[1] as f64;
        assert!(
            (ratio - 3.0).abs() < 0.5,
            "weighted share lost under backpressure: {served:?} (ratio {ratio:.2})"
        );
    }

    #[test]
    fn oversize_debt_survives_idle_gaps() {
        // Regression: a model must not erase oversize debt by going
        // briefly idle — only positive credit is dropped when not ready.
        let mut fs = FairScheduler::new(&[policy(8, 1), policy(8, 1)]).unwrap();
        let q = fs.quantum() as i64;
        // model 0 admits a 50-image oversized request...
        fs.service(
            &mut |id| id == 0,
            &mut |id, _| {
                if id == 0 {
                    Grant::Admitted(50)
                } else {
                    Grant::Skip
                }
            },
        );
        assert_eq!(fs.deficit(0), q - 50);
        // ...then goes idle for several passes while model 1 runs
        for _ in 0..5 {
            fs.service(&mut |id| id == 1, &mut |_, m| Grant::Admitted(m));
        }
        // the debt is still owed (idle dropped nothing below zero)
        assert_eq!(fs.deficit(0), q - 50, "idle gap forgave oversize debt");
    }

    #[test]
    fn debt_is_floored_at_one_protocol_max_request() {
        // A string of force-admitted oversized requests (idle-pool work
        // conservation) must not bank unbounded debt: the floor keeps
        // post-idle starvation bounded by one max request's repayment.
        let mut fs = FairScheduler::new(&[policy(8, 1)]).unwrap();
        for _ in 0..100 {
            fs.charge(0, 4096);
        }
        assert_eq!(fs.deficit(0), DEBT_FLOOR);
        assert_eq!(DEBT_FLOOR, -4096);
        // in-pass oversize admissions hit the same floor
        let mut fs = FairScheduler::new(&[policy(8, 1)]).unwrap();
        let mut left = 3u32;
        for _ in 0..3 {
            fs.service(
                &mut |_| true,
                &mut |_, _| {
                    if left == 0 {
                        return Grant::Skip;
                    }
                    left -= 1;
                    Grant::Admitted(4096)
                },
            );
        }
        assert!(fs.deficit(0) >= DEBT_FLOOR, "{}", fs.deficit(0));
    }

    fn pending(n: usize) -> (Pending, mpsc::Receiver<Result<Vec<u32>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                images: vec![0.0; n],
                n,
                reply: ReplySink::new(tx),
                enqueued_at: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn queue_poll_tracks_fill_deadline_and_shutdown() {
        let q = BatchQueue::new(8192, 4);
        let stats = Stats::default();
        let wait = Duration::from_secs(3600);
        let now = Instant::now();
        assert_eq!(q.poll(4, wait, now), Poll::Empty);
        let (p, _rx) = pending(2);
        assert!(q.push(p, &stats).is_some());
        // 2 < 4 images and the deadline is an hour out -> Wait
        match q.poll(4, wait, now) {
            Poll::Wait(d) => assert!(d > now),
            other => panic!("want Wait, got {other:?}"),
        }
        assert!(q.try_pop(4, wait, now, &stats).is_none());
        // deadline expiry makes the same queue Ready
        let later = now + wait + Duration::from_secs(1);
        assert_eq!(q.poll(4, wait, later), Poll::Ready);
        // filling to max_batch makes it Ready immediately
        let (p, _rx2) = pending(2);
        assert!(q.push(p, &stats).is_some());
        assert_eq!(q.poll(4, wait, now), Poll::Ready);
        let batch = q.try_pop(4, wait, now, &stats).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
        // drained + shutdown
        q.shutdown();
        assert_eq!(q.poll(4, wait, now), Poll::Drained);
    }

    #[test]
    fn push_rings_only_on_became_admissible_transitions() {
        let q = BatchQueue::new(8192, 4);
        let stats = Stats::default();
        // empty -> non-empty: the scheduler knows no deadline yet
        let (p, _r1) = pending(1);
        assert_eq!(q.push(p, &stats), Some(true));
        // Wait -> Wait (2 < 4 images): front deadline unchanged, no ring
        let (p, _r2) = pending(1);
        assert_eq!(q.push(p, &stats), Some(false));
        // crossing the max_batch fill (2 -> 4): Wait -> Ready, ring
        let (p, _r3) = pending(2);
        assert_eq!(q.push(p, &stats), Some(true));
        // already Ready by fill: further pushes don't re-ring
        let (p, _r4) = pending(3);
        assert_eq!(q.push(p, &stats), Some(false));
        // drain back to empty; the next push rings again
        let now = Instant::now();
        while q.try_pop(4, Duration::ZERO, now, &stats).is_some() {
            if stats.queue_depth.load(Ordering::Relaxed) == 0 {
                break;
            }
        }
        let (p, _r5) = pending(1);
        assert_eq!(q.push(p, &stats), Some(true));
    }

    #[test]
    fn queue_coalesces_up_to_max_images() {
        let q = BatchQueue::new(8192, 4);
        let stats = Stats::default();
        let mut rxs = Vec::new();
        for _ in 0..3 {
            let (p, rx) = pending(2);
            assert!(q.push(p, &stats).is_some());
            rxs.push(rx);
        }
        assert_eq!(stats.queue_peak.load(Ordering::Relaxed), 6);
        let now = Instant::now();
        // max 4 takes the first two requests (2+2), leaves one
        let batch = q.try_pop(4, Duration::ZERO, now, &stats).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.iter().map(|p| p.n).sum::<usize>(), 4);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 2);
        let batch = q.try_pop(4, Duration::ZERO, now, &stats).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn queue_admits_oversized_request_alone() {
        let q = BatchQueue::new(8192, 8);
        let stats = Stats::default();
        let (p, _rx) = pending(100);
        assert!(q.push(p, &stats).is_some());
        let (p2, _rx2) = pending(1);
        assert!(q.push(p2, &stats).is_some());
        let batch = q
            .try_pop(8, Duration::ZERO, Instant::now(), &stats)
            .unwrap();
        assert_eq!(batch.len(), 1, "oversized request dispatched alone");
        assert_eq!(batch[0].n, 100);
    }

    #[test]
    fn full_queue_blocks_push_until_pop_frees_space() {
        let q = Arc::new(BatchQueue::new(4, 4));
        let stats = Arc::new(Stats::default());
        let (p, _rx1) = pending(4);
        assert!(q.push(p, &stats).is_some());
        // the queue is at its image cap: a second push must block on
        // not_full until the scheduler drains, then admit via its ticket
        let (p2, _rx2) = pending(3);
        let pusher = {
            let (q, s) = (q.clone(), stats.clone());
            std::thread::spawn(move || q.push(p2, &s).is_some())
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push admitted past the image cap");
        let batch = q
            .try_pop(4, Duration::ZERO, Instant::now(), &stats)
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 4);
        assert!(pusher.join().unwrap(), "blocked push must admit after the drain");
        let batch = q
            .try_pop(4, Duration::ZERO, Instant::now(), &stats)
            .unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n, 3);
    }

    #[test]
    fn try_push_mirrors_blocking_push_without_blocking() {
        let q = BatchQueue::new(4, 4);
        let stats = Stats::default();
        // empty queue: even an over-cap request is admitted alone
        let (p, _r1) = pending(100);
        assert!(matches!(q.try_push(p, &stats), TryPush::Queued(true)));
        // non-empty + over cap: handed back intact, not dropped
        let (p, _r2) = pending(3);
        let back = match q.try_push(p, &stats) {
            TryPush::Full(p) => p,
            _ => panic!("full queue must return the request"),
        };
        assert_eq!(back.n, 3);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 100);
        // drain, then the same request goes in (ring: empty -> ready,
        // 3 < ready_images 4 but the queue was empty)
        let now = Instant::now();
        assert!(q.try_pop(4, Duration::ZERO, now, &stats).is_some());
        assert!(matches!(q.try_push(back, &stats), TryPush::Queued(true)));
        // a second small push: Wait -> Ready crossing rings
        let (p, _r3) = pending(1);
        assert!(matches!(q.try_push(p, &stats), TryPush::Queued(true)));
        // shutdown refuses and drops
        q.shutdown();
        let (p, _r4) = pending(1);
        assert!(matches!(q.try_push(p, &stats), TryPush::Shutdown));
    }

    #[test]
    fn try_push_yields_to_ticketed_blocked_pushers() {
        // A blocked blocking-push holds a ticket; try_push must not cut
        // the line even when the instantaneous image count has room.
        let q = Arc::new(BatchQueue::new(4, 4));
        let stats = Arc::new(Stats::default());
        let (p, _r1) = pending(4);
        assert!(q.push(p, &stats).is_some());
        let (big, _r2) = pending(4);
        let pusher = {
            let (q, s) = (q.clone(), stats.clone());
            std::thread::spawn(move || q.push(big, &s).is_some())
        };
        while q.state.lock().unwrap().next_ticket < 2 {
            std::thread::yield_now(); // until the blocked push takes its ticket
        }
        let (p, _r3) = pending(1);
        assert!(
            matches!(q.try_push(p, &stats), TryPush::Full(_)),
            "try_push must queue behind the ticketed pusher"
        );
        let now = Instant::now();
        assert!(q.try_pop(4, Duration::ZERO, now, &stats).is_some());
        assert!(pusher.join().unwrap());
    }

    #[test]
    fn queue_drains_after_shutdown_then_reports_drained() {
        let q = BatchQueue::new(8192, 64);
        let stats = Stats::default();
        let (p, _rx) = pending(3);
        assert!(q.push(p, &stats).is_some());
        q.shutdown();
        let now = Instant::now();
        // queued work is still admissible (shutdown forces Ready)...
        assert_eq!(q.poll(64, Duration::from_secs(60), now), Poll::Ready);
        let batch = q.try_pop(64, Duration::from_secs(60), now, &stats).unwrap();
        assert_eq!(batch.len(), 1);
        // ...then the scheduler is told this model is done, and pushes
        // are refused
        assert_eq!(q.poll(64, Duration::ZERO, now), Poll::Drained);
        let (p2, _rx2) = pending(1);
        assert!(q.push(p2, &stats).is_none());
    }

    #[test]
    fn doorbell_rings_are_never_lost() {
        let d = Arc::new(Doorbell::new());
        let seen = d.epoch();
        // ring BEFORE the wait: wait_past must return immediately
        d.ring();
        d.wait_past(seen, None);
        // timeout path returns without a ring
        let seen = d.epoch();
        let t0 = Instant::now();
        d.wait_past(seen, Some(Instant::now() + Duration::from_millis(20)));
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // a concurrent ring wakes a parked waiter
        let d2 = d.clone();
        let seen = d.epoch();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            d2.ring();
        });
        d.wait_past(seen, None);
        h.join().unwrap();
    }

    #[test]
    fn inflight_cap_scales_with_quantum_and_workers() {
        assert_eq!(inflight_cap(64, 2), 128);
        assert_eq!(inflight_cap(1, 8), 16);
        assert!(inflight_cap(4096, 4) >= 8192);
    }

    fn slo_policy(weight: u32, slo_us: Option<u64>) -> Policy {
        Policy {
            slo_us,
            ..policy(16, weight)
        }
    }

    #[test]
    fn slo_policy_validation() {
        assert!(slo_policy(1, Some(0)).validate().is_err());
        assert!(slo_policy(1, Some(1)).validate().is_ok());
        assert!(slo_policy(1, Some(ServeConfig::MAX_BATCH_WAIT_US))
            .validate()
            .is_ok());
        assert!(slo_policy(1, Some(ServeConfig::MAX_BATCH_WAIT_US + 1))
            .validate()
            .is_err());
        assert!(slo_policy(1, Some(5000)).describe().contains("slo p99 5000us"));
        assert!(!slo_policy(1, None).describe().contains("slo"));
    }

    #[test]
    fn slo_adapter_boosts_on_miss_and_reverts_on_meet() {
        // model 0: weight 2 with a 1ms SLO; model 1: no SLO, weight 5
        let policies = [slo_policy(2, Some(1000)), slo_policy(5, None)];
        let mut a = SloAdapter::new(&policies);
        assert!(a.enabled());
        assert_eq!(a.effective_weight(0), 2);
        assert_eq!(a.effective_weight(1), 5);

        // sustained 4x miss: the factor must climb well above 1 but
        // never past SLO_FACTOR_MAX, and the no-SLO model never moves
        for _ in 0..200 {
            let w = a.tick(&[Some(4000.0), Some(1_000_000.0)]);
            assert!(w[0] >= 2 && w[0] <= (2.0 * SLO_FACTOR_MAX) as u32, "{w:?}");
            assert_eq!(w[1], 5, "no-SLO model must keep its static weight");
        }
        assert!(a.factor(0) > 2.0, "sustained miss barely moved: {}", a.factor(0));
        assert!(a.factor(0) <= SLO_FACTOR_MAX);
        let boosted = a.effective_weight(0);
        assert!(boosted > 2, "{boosted}");

        // p99 settling exactly on the SLO (inside the deadband): the
        // factor decays home and the weight converges to static
        for _ in 0..400 {
            a.tick(&[Some(1000.0), None]);
        }
        assert!(
            (a.factor(0) - 1.0).abs() < 0.02,
            "factor failed to converge: {}",
            a.factor(0)
        );
        assert_eq!(a.effective_weight(0), 2);
    }

    #[test]
    fn slo_adapter_silent_intervals_decay_home() {
        let policies = [slo_policy(1, Some(500))];
        let mut a = SloAdapter::new(&policies);
        for _ in 0..50 {
            a.tick(&[Some(50_000.0)]); // hard miss
        }
        let peak = a.factor(0);
        assert!(peak > 1.5, "{peak}");
        // traffic stops: no intervals reach SLO_MIN_SAMPLES -> None.
        // An idle model needs no boost, so the factor must drain.
        for _ in 0..400 {
            a.tick(&[None]);
        }
        assert!(a.factor(0) < 1.05, "idle decay failed: {}", a.factor(0));
    }

    #[test]
    fn slo_effective_weight_clamps_at_max_weight() {
        let policies = [slo_policy(MAX_WEIGHT, Some(1))];
        let mut a = SloAdapter::new(&policies);
        for _ in 0..500 {
            let w = a.tick(&[Some(1e9)]);
            assert_eq!(w[0], MAX_WEIGHT, "boost may never exceed MAX_WEIGHT");
        }
    }

    #[test]
    fn set_weight_is_clamped_and_visible() {
        let mut fs = FairScheduler::new(&[policy(8, 1), policy(8, 3)]).unwrap();
        assert_eq!(fs.weight(0), 1);
        fs.set_weight(0, 7);
        assert_eq!(fs.weight(0), 7);
        fs.set_weight(0, 0); // clamped up: starvation freedom is structural
        assert_eq!(fs.weight(0), 1);
        fs.set_weight(1, MAX_WEIGHT + 100);
        assert_eq!(fs.weight(1), MAX_WEIGHT);
    }

    #[test]
    fn set_deficit_carries_debt_but_clamps_both_ways() {
        // the control-plane swap path: a rebuilt scheduler seeds each
        // surviving slot's deficit from its predecessor
        let mut fs = FairScheduler::new(&[policy(8, 2)]).unwrap();
        fs.set_deficit(0, -100);
        assert_eq!(fs.deficit(0), -100, "oversize debt survives a swap");
        fs.set_deficit(0, DEBT_FLOOR - 10_000);
        assert_eq!(fs.deficit(0), DEBT_FLOOR);
        // positive credit caps at one visit's worth (quantum x weight)
        fs.set_deficit(0, i64::MAX);
        assert_eq!(fs.deficit(0), 8 * 2);
    }

    #[test]
    fn slo_seed_restores_boost_state_across_rebuild() {
        let policies = [slo_policy(2, Some(1000))];
        let mut a = SloAdapter::new(&policies);
        for _ in 0..100 {
            a.tick(&[Some(4000.0)]);
        }
        let (factor, ewma) = (a.factor(0), a.ewma_p99_us(0));
        assert!(factor > 1.5);
        let mut b = SloAdapter::new(&policies);
        b.seed(0, ewma, factor);
        assert_eq!(b.factor(0), factor);
        assert_eq!(b.ewma_p99_us(0), ewma);
        assert_eq!(b.effective_weight(0), a.effective_weight(0));
        // out-of-range factors (hand-rolled state) clamp to invariant
        b.seed(0, None, 1e9);
        assert_eq!(b.factor(0), SLO_FACTOR_MAX);
        b.seed(0, None, 0.0);
        assert_eq!(b.factor(0), 1.0);
    }

    #[test]
    fn set_bounds_retunes_a_live_queue() {
        let q = BatchQueue::new(4, 4);
        let stats = Stats::default();
        let (p, _r1) = pending(4);
        assert!(matches!(q.try_push(p, &stats), TryPush::Queued(true)));
        // at the cap: another push is refused...
        let (p, _r2) = pending(2);
        assert!(matches!(q.try_push(p, &stats), TryPush::Full(_)));
        // ...until a control-plane retune raises the bound in place
        q.set_bounds(16, 8);
        let (p, _r3) = pending(2);
        assert!(matches!(q.try_push(p, &stats), TryPush::Queued(_)));
        // lowering below the current fill drops nothing, it just
        // refuses new pushes while over the bound
        q.set_bounds(2, 2);
        assert_eq!(stats.queue_depth.load(Ordering::Relaxed), 6);
        let (p, _r4) = pending(1);
        assert!(matches!(q.try_push(p, &stats), TryPush::Full(_)));
        let now = Instant::now();
        assert!(q.try_pop(64, Duration::ZERO, now, &stats).is_some());
        let (p, _r5) = pending(1);
        assert!(matches!(q.try_push(p, &stats), TryPush::Queued(true)));
    }
}
