//! Router tier: cross-process sharding over the same event loop.
//!
//! `--route MODEL=host:port` (repeatable) turns the binary into a
//! front-end that speaks the exact wire protocol of a serving process —
//! same readiness loop, same incremental [`super::conn::RequestDecoder`]
//! — but forwards each framed request to a backend host instead of
//! queueing it into a local `BatchQueue`. Route order assigns the
//! router-visible model ids (first `--route` is id 0, the v1 default),
//! and protocol v2's model id is the routing key.
//!
//! # Shape
//!
//! ```text
//!   clients ──► router event loop ──► per-backend conn pool ──► backends
//!               (decoder in raw        (persistent, pipelined,
//!                frame mode: no         non-blocking; in-flight
//!                f32 decode)            FIFO per connection)
//!               ◄── in-order reply ◄── replies re-associate to the
//!                   staging             FIFO front (TCP orders them)
//! ```
//!
//! # Invariants
//!
//! * **Zero-recompute forward path**: payload bytes are forwarded as
//!   received — the decoder accumulates the raw frame
//!   ([`super::conn::Decoded::RequestRaw`]) and the router appends it
//!   whole to one backend connection's write buffer. No f32
//!   decode/re-encode, and frames never interleave mid-frame.
//! * **Byte-identical frames**: the forwarded bytes are exactly the
//!   bytes the client sent (header re-encoding is byte-exact, pinned by
//!   `proto_props.rs`), so backends must host each routed model at the
//!   SAME id the router exposes.
//! * **Reply re-association is a FIFO**: one backend TCP connection
//!   answers requests in order, so each connection carries a
//!   [`PendingReply`] FIFO and every complete reply pops the front.
//!   A count mismatch or a reply with an empty window is a protocol
//!   error that kills that backend connection only.
//! * **Failure isolation**: a backend disconnect fails exactly the
//!   requests in that connection's in-flight window (their clients get
//!   an error close); other backends — and other connections to the
//!   same backend — keep serving. Reconnects retry on a backoff
//!   deadline folded into the loop's timeout (never a sleep).
//! * **Backpressure**: when every connection to a model's backend has a
//!   full in-flight window or write buffer, the client connection parks
//!   (read interest off — TCP takes over) until a completion frees
//!   capacity.
//!
//! The payload length of a frame is `n × img_elems × 4`, and
//! `img_elems` is per-model knowledge only backends have — so on
//! connect the router sends a describe request (`"AQSD"` magic, see
//! [`super::MAGIC_DESC`]) and each backend answers with its model
//! dimension table. A connection forwards nothing until the handshake
//! completes; requests arriving earlier park at the header gate.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{RouteSpec, ServeConfig};
use crate::util::poll::{Event, Interest, Poller};

use super::conn::{self, WriteBuf};
use super::metrics::{self, LatencyHist};
use super::{RequestHeader, ServerStats, MAX_REQ_IMAGES, PROTO_VERSION};

/// Backend-connection tokens: `ROUTE_TOKEN_BASE + backend·STRIDE +
/// conn`. Far above any client slot (bounded by fd limits) and below
/// the stats token space — the event loop's token `match` relies on
/// this ordering (pinned by `stats_token_space_is_disjoint`).
pub(crate) const ROUTE_TOKEN_BASE: u64 = 1 << 40;

/// Token stride per backend — also the hard ceiling on `--route-pool`.
pub(crate) const ROUTE_TOKEN_STRIDE: u64 = 64;

/// Blocking connect budget per attempt. Backend connects are the one
/// blocking syscall in router mode: on loopback/LAN a refused port
/// fails immediately and an established handshake is microseconds, so
/// this bounds only the pathological SYN-blackhole case. Reconnect
/// attempts are additionally spaced by the backoff deadline.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(150);

/// Reconnect backoff bounds (doubles per failure, resets on a
/// completed handshake).
const BACKOFF_MIN: Duration = Duration::from_millis(50);
const BACKOFF_MAX: Duration = Duration::from_secs(2);

/// Stop choosing a backend connection once this many unflushed bytes
/// are staged on it. A single frame larger than the cap still forwards
/// (the check gates *choosing* the connection, not the append), so an
/// oversized frame can never deadlock.
const BACKEND_WRITE_SOFT_CAP: usize = 1 << 20;

/// Reads per backend connection per readiness event (level-triggered
/// polling re-reports leftovers, same rationale as the client side).
const READ_BUDGET: usize = 16;

/// Describe replies may name at most this many models (the u16 id
/// space) — bounds allocation against a garbage-spewing backend.
const MAX_DESC_MODELS: usize = 1 << 16;

// ---------------------------------------------------------------------
// Incremental reply reader (pure; fuzzed by proto_props.rs)
// ---------------------------------------------------------------------

/// Incremental parser for one response frame: `u32 count` then `count`
/// u32 words. Used for both backend replies (count = image count,
/// capped at [`MAX_REQ_IMAGES`]) and describe replies (count = model
/// count, capped at [`MAX_DESC_MODELS`]). Consumes at most one frame
/// per [`ReplyReader::feed`] call — trailing bytes stay with the
/// caller, which is what keeps pipelined replies separable.
pub struct ReplyReader {
    cap: usize,
    want_count: bool,
    word: [u8; 4],
    word_got: usize,
    n: u32,
    words: Vec<u32>,
}

impl Default for ReplyReader {
    fn default() -> Self {
        Self::new()
    }
}

impl ReplyReader {
    /// Reader for backend reply frames (count ≤ [`MAX_REQ_IMAGES`]).
    pub fn new() -> ReplyReader {
        Self::with_cap(MAX_REQ_IMAGES)
    }

    /// Reader with an explicit count cap (describe replies use the u16
    /// model-id space).
    pub fn with_cap(cap: usize) -> ReplyReader {
        ReplyReader {
            cap,
            want_count: true,
            word: [0; 4],
            word_got: 0,
            n: 0,
            words: Vec::new(),
        }
    }

    /// Feed bytes; returns `(consumed, completed_frame)`. Stops
    /// consuming right after a frame completes (never over-consumes
    /// into the next frame); loop on `consumed` to drain a buffer.
    /// `Err` means the stream is not speaking the protocol (count of
    /// zero or past the cap) and the connection is unsalvageable.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<(usize, Option<Vec<u32>>), &'static str> {
        let mut used = 0;
        while used < bytes.len() {
            let fill = (4 - self.word_got).min(bytes.len() - used);
            self.word[self.word_got..self.word_got + fill]
                .copy_from_slice(&bytes[used..used + fill]);
            self.word_got += fill;
            used += fill;
            if self.word_got < 4 {
                break;
            }
            self.word_got = 0;
            let w = u32::from_le_bytes(self.word);
            if self.want_count {
                if w == 0 || w as usize > self.cap {
                    return Err("response count out of range");
                }
                self.n = w;
                self.words = Vec::with_capacity(w as usize);
                self.want_count = false;
            } else {
                self.words.push(w);
                if self.words.len() == self.n as usize {
                    let out = std::mem::take(&mut self.words);
                    self.want_count = true;
                    return Ok((used, Some(out)));
                }
            }
        }
        Ok((used, None))
    }
}

// ---------------------------------------------------------------------
// Per-backend in-flight bookkeeping (pure parts are property-tested)
// ---------------------------------------------------------------------

/// One forwarded request awaiting its reply on a backend connection.
/// The FIFO order of these IS the re-association: backend replies
/// arrive in forward order per TCP connection.
pub struct PendingReply {
    /// Completion channel into the owning client connection's
    /// `InFlight` entry (the event loop stages replies from it in
    /// client-request order, same machinery as local serving).
    pub tx: mpsc::Sender<Result<Vec<u32>, String>>,
    /// Image count the reply must carry (mismatch = protocol error).
    pub n: u32,
    /// Forward time — the backend round-trip clock.
    pub t0: Instant,
}

/// A fully-received frame that could not be forwarded yet (no backend
/// connection with window/write capacity): parked with its client
/// connection, retried on every sweep.
pub(crate) struct ParkedFrame {
    pub frame: Vec<u8>,
    pub n: u32,
    pub t0: Instant,
}

/// Complete the front of a backend connection's in-flight window with
/// a parsed reply. Pure FIFO pop + validation, shared by the event
/// loop and the re-association property tests in `proto_props.rs`.
pub fn complete_front(
    fifo: &mut VecDeque<PendingReply>,
    classes: Vec<u32>,
    stats: &BackendStats,
) -> Result<(), &'static str> {
    let Some(front) = fifo.pop_front() else {
        return Err("reply with an empty in-flight window");
    };
    if front.n as usize != classes.len() {
        // push it back so the caller's teardown fails it with the rest
        fifo.push_front(front);
        return Err("reply image count mismatch");
    }
    stats.rtt.observe(front.t0.elapsed().as_micros() as u64);
    stats.answered.fetch_add(1, Ordering::Relaxed);
    stats.inflight.fetch_sub(1, Ordering::Relaxed);
    let _ = front.tx.send(Ok(classes));
    Ok(())
}

/// Fail every request in a backend connection's in-flight window (the
/// backend died or broke protocol). Only THIS window fails — other
/// connections and backends are untouched.
pub fn fail_window(fifo: &mut VecDeque<PendingReply>, stats: &BackendStats, msg: &str) {
    while let Some(p) = fifo.pop_front() {
        stats.failed.fetch_add(1, Ordering::Relaxed);
        stats.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = p.tx.send(Err(msg.to_string()));
    }
}

// ---------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------

/// Per-backend router counters, surfaced through `GET /stats`.
#[derive(Debug)]
pub struct BackendStats {
    /// Backend address (`host:port`), the identity key.
    pub addr: String,
    /// Route keys served by this backend, in model-id order.
    pub models: Vec<String>,
    /// Frames forwarded to the backend.
    pub forwarded: AtomicU64,
    /// Replies delivered back to clients.
    pub answered: AtomicU64,
    /// Requests failed by a backend disconnect / protocol error.
    pub failed: AtomicU64,
    /// Requests currently in flight to this backend (gauge).
    pub inflight: AtomicU64,
    /// Reconnect attempts after a lost connection.
    pub reconnects: AtomicU64,
    /// Backend round-trip time (forward → reply parsed), µs.
    pub rtt: LatencyHist,
}

impl BackendStats {
    fn new(addr: String, models: Vec<String>) -> BackendStats {
        BackendStats {
            addr,
            models,
            forwarded: AtomicU64::new(0),
            answered: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            rtt: LatencyHist::default(),
        }
    }
}

/// All router-mode statistics: one [`BackendStats`] per distinct
/// backend address (routes sharing a `host:port` share one pool and
/// one stats entry).
#[derive(Debug)]
pub struct RouterStats {
    pub backends: Vec<Arc<BackendStats>>,
}

impl RouterStats {
    /// Build the per-backend entries for a route table, deduplicating
    /// by address in first-seen order — the same order
    /// [`Router::new`] assigns backend indices, so stats and pool
    /// stay aligned.
    pub fn for_routes(routes: &[RouteSpec]) -> RouterStats {
        let mut addrs: Vec<String> = Vec::new();
        let mut models: Vec<Vec<String>> = Vec::new();
        for r in routes {
            match addrs.iter().position(|a| *a == r.addr) {
                Some(i) => models[i].push(r.name.clone()),
                None => {
                    addrs.push(r.addr.clone());
                    models.push(vec![r.name.clone()]);
                }
            }
        }
        RouterStats {
            backends: addrs
                .into_iter()
                .zip(models)
                .map(|(a, m)| Arc::new(BackendStats::new(a, m)))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------
// The router: routing table + backend pools
// ---------------------------------------------------------------------

/// What [`Router::try_forward`] did with a frame.
pub(crate) enum Forward {
    /// Appended to a backend connection; the receiver completes when
    /// the reply re-associates (or the window fails).
    Sent(mpsc::Receiver<Result<Vec<u32>, String>>),
    /// Every connection to the model's backend is saturated or not yet
    /// handshaken — park the frame and retry on the next sweep.
    Saturated(ParkedFrame),
}

/// One pooled connection to a backend.
struct BackendConn {
    /// `None` while disconnected (awaiting the reconnect deadline).
    stream: Option<TcpStream>,
    write: WriteBuf,
    interest: Interest,
    /// Describe handshake parser; the connection forwards nothing
    /// until it yields the backend's dimension table.
    desc: ReplyReader,
    ready: bool,
    /// Reply parser (active once ready).
    rd: ReplyReader,
    /// Forwarded-but-unanswered requests, forward order.
    fifo: VecDeque<PendingReply>,
    /// When to attempt the next (re)connect; folded into the event
    /// loop's poll timeout so a down backend never blocks the loop.
    reconnect_at: Option<Instant>,
    backoff: Duration,
}

impl BackendConn {
    fn idle() -> BackendConn {
        BackendConn {
            stream: None,
            write: WriteBuf::default(),
            interest: Interest::READ,
            desc: ReplyReader::with_cap(MAX_DESC_MODELS),
            ready: false,
            rd: ReplyReader::new(),
            fifo: VecDeque::new(),
            reconnect_at: None,
            backoff: BACKOFF_MIN,
        }
    }
}

struct Backend {
    addr: String,
    stats: Arc<BackendStats>,
    conns: Vec<BackendConn>,
    /// Per-model `img_elems` learned from the describe handshake
    /// (router-visible model id → f32s per image). Kept across
    /// disconnects — a backend restart with different dims re-learns
    /// on the next completed handshake.
    dims: Option<Vec<u32>>,
}

/// Routing table + per-backend connection pools, driven by the event
/// loop (single-threaded, like everything else on the loop).
pub(crate) struct Router {
    /// Router-visible model id (route order) → backend index.
    table: Vec<usize>,
    backends: Vec<Backend>,
    /// Per-connection in-flight window (`--route-inflight`).
    window: usize,
    stats: Arc<RouterStats>,
}

impl Router {
    pub(crate) fn new(routes: &[RouteSpec], cfg: &ServeConfig, stats: Arc<RouterStats>) -> Router {
        let pool = cfg.route_pool.clamp(1, ROUTE_TOKEN_STRIDE as usize);
        let mut table = Vec::with_capacity(routes.len());
        let mut backends: Vec<Backend> = Vec::new();
        for r in routes {
            let idx = match backends.iter().position(|b| b.addr == r.addr) {
                Some(i) => i,
                None => {
                    let i = backends.len();
                    backends.push(Backend {
                        addr: r.addr.clone(),
                        stats: stats.backends[i].clone(),
                        conns: (0..pool).map(|_| BackendConn::idle()).collect(),
                        dims: None,
                    });
                    i
                }
            };
            table.push(idx);
        }
        Router {
            table,
            backends,
            window: cfg.route_inflight.max(1),
            stats,
        }
    }

    pub(crate) fn n_routes(&self) -> usize {
        self.table.len()
    }

    /// f32s per image for a routed model, once its backend's describe
    /// handshake completed (`None` = park at the gate).
    pub(crate) fn payload_elems(&self, model_id: u16) -> Option<u32> {
        let b = &self.backends[*self.table.get(model_id as usize)?];
        let elems = *b.dims.as_ref()?.get(model_id as usize)?;
        (elems > 0).then_some(elems)
    }

    /// Dimension table the router itself answers describe requests
    /// with: per routed model, the backend-learned `img_elems` (0 while
    /// that backend's handshake is still pending).
    pub(crate) fn describe_elems(&self) -> Vec<u32> {
        (0..self.table.len())
            .map(|id| self.payload_elems(id as u16).unwrap_or(0))
            .collect()
    }

    /// Can a frame for `model_id` be forwarded right now? (Used at the
    /// header gate so payload bytes aren't read into memory that can
    /// only park.)
    pub(crate) fn has_capacity(&self, model_id: u16) -> bool {
        let Some(&b) = self.table.get(model_id as usize) else {
            return false;
        };
        self.backends[b]
            .conns
            .iter()
            .any(|c| self.conn_has_capacity(c))
    }

    fn conn_has_capacity(&self, c: &BackendConn) -> bool {
        c.stream.is_some()
            && c.ready
            && c.fifo.len() < self.window
            && c.write.len() < BACKEND_WRITE_SOFT_CAP
    }

    /// Forward one complete frame: append it whole to the least-loaded
    /// backend connection with capacity and push the pending entry onto
    /// that connection's FIFO. The frame bytes are exactly what the
    /// client sent.
    pub(crate) fn try_forward(
        &mut self,
        model_id: u16,
        pf: ParkedFrame,
        poller: &mut Poller,
    ) -> Forward {
        let b = self.table[model_id as usize];
        let pick = self.backends[b]
            .conns
            .iter()
            .enumerate()
            .filter(|(_, c)| self.conn_has_capacity(c))
            .min_by_key(|(_, c)| c.fifo.len())
            .map(|(i, _)| i);
        let Some(ci) = pick else {
            return Forward::Saturated(pf);
        };
        let (tx, rx) = mpsc::channel();
        let stats = self.backends[b].stats.clone();
        {
            let c = &mut self.backends[b].conns[ci];
            c.write.push_bytes(&pf.frame);
            c.fifo.push_back(PendingReply {
                tx,
                n: pf.n,
                t0: pf.t0,
            });
        }
        stats.forwarded.fetch_add(1, Ordering::Relaxed);
        stats.inflight.fetch_add(1, Ordering::Relaxed);
        // Eager flush: most frames hit the socket buffer immediately;
        // a failure here fails the window (the rx above included) and
        // schedules the reconnect — the caller still gets Sent.
        self.flush_conn(b, ci, poller);
        Forward::Sent(rx)
    }

    /// Initial connection attempts for every pooled connection (called
    /// once before the loop starts; failures schedule backoff retries).
    pub(crate) fn connect_all(&mut self, poller: &mut Poller) {
        for b in 0..self.backends.len() {
            for c in 0..self.backends[b].conns.len() {
                self.try_connect(b, c, poller);
            }
        }
    }

    /// Earliest reconnect deadline (folded into the poll timeout).
    pub(crate) fn next_deadline(&self) -> Option<Instant> {
        self.backends
            .iter()
            .flat_map(|b| b.conns.iter())
            .filter_map(|c| c.reconnect_at)
            .min()
    }

    /// Attempt reconnects whose deadline passed.
    pub(crate) fn tick(&mut self, now: Instant, poller: &mut Poller) {
        for b in 0..self.backends.len() {
            for c in 0..self.backends[b].conns.len() {
                let due = self.backends[b].conns[c]
                    .reconnect_at
                    .map(|t| now >= t)
                    .unwrap_or(false);
                if due {
                    self.backends[b].conns[c].reconnect_at = None;
                    self.backends[b]
                        .stats
                        .reconnects
                        .fetch_add(1, Ordering::Relaxed);
                    self.try_connect(b, c, poller);
                }
            }
        }
    }

    fn token(b: usize, c: usize) -> u64 {
        ROUTE_TOKEN_BASE + b as u64 * ROUTE_TOKEN_STRIDE + c as u64
    }

    fn try_connect(&mut self, b: usize, c: usize, poller: &mut Poller) {
        let addr = self.backends[b].addr.clone();
        let stream = (|| -> Result<TcpStream> {
            let sa = addr
                .to_socket_addrs()
                .with_context(|| format!("resolving backend {addr}"))?
                .next()
                .with_context(|| format!("backend {addr} resolved to no address"))?;
            let s = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT)
                .with_context(|| format!("connecting backend {addr}"))?;
            let _ = s.set_nodelay(true);
            s.set_nonblocking(true).context("non-blocking backend conn")?;
            Ok(s)
        })();
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("aquant-route: backend {addr}: {e:#}");
                self.schedule_reconnect(b, c);
                return;
            }
        };
        {
            use std::os::unix::io::AsRawFd;
            if let Err(e) = poller.register(stream.as_raw_fd(), Self::token(b, c), Interest::READ)
            {
                eprintln!("aquant-route: backend {addr}: registering: {e:#}");
                self.schedule_reconnect(b, c);
                return;
            }
        }
        let conn = &mut self.backends[b].conns[c];
        conn.stream = Some(stream);
        conn.interest = Interest::READ;
        conn.ready = false;
        conn.desc = ReplyReader::with_cap(MAX_DESC_MODELS);
        conn.rd = ReplyReader::new();
        conn.write = WriteBuf::default();
        // Handshake: ask for the backend's model dimension table. The
        // connection forwards nothing until the reply arrives.
        conn.write.push_bytes(
            &RequestHeader::Describe {
                version: PROTO_VERSION,
            }
            .encode(),
        );
        self.flush_conn(b, c, poller);
    }

    fn schedule_reconnect(&mut self, b: usize, c: usize) {
        let conn = &mut self.backends[b].conns[c];
        conn.reconnect_at = Some(Instant::now() + conn.backoff);
        conn.backoff = (conn.backoff * 2).min(BACKOFF_MAX);
    }

    /// Tear down one backend connection: fail exactly its in-flight
    /// window, keep every other connection serving, arm the reconnect
    /// deadline.
    fn fail_conn(&mut self, b: usize, c: usize, poller: &mut Poller, msg: &str) {
        let addr = self.backends[b].addr.clone();
        let stats = self.backends[b].stats.clone();
        let conn = &mut self.backends[b].conns[c];
        if let Some(s) = conn.stream.take() {
            use std::os::unix::io::AsRawFd;
            let _ = poller.deregister(s.as_raw_fd());
        }
        conn.ready = false;
        conn.write = WriteBuf::default();
        conn.desc = ReplyReader::with_cap(MAX_DESC_MODELS);
        conn.rd = ReplyReader::new();
        if !conn.fifo.is_empty() {
            eprintln!(
                "aquant-route: backend {addr}: {msg}; failing {} in-flight request(s)",
                conn.fifo.len()
            );
        } else {
            eprintln!("aquant-route: backend {addr}: {msg}");
        }
        fail_window(&mut conn.fifo, &stats, &format!("backend {addr}: {msg}"));
        self.schedule_reconnect(b, c);
    }

    /// Handle a readiness event for a backend-connection token.
    pub(crate) fn on_event(&mut self, ev: Event, poller: &mut Poller, chunk: &mut [u8]) {
        let idx = ev.token - ROUTE_TOKEN_BASE;
        let (b, c) = (
            (idx / ROUTE_TOKEN_STRIDE) as usize,
            (idx % ROUTE_TOKEN_STRIDE) as usize,
        );
        let live = self
            .backends
            .get(b)
            .and_then(|bk| bk.conns.get(c))
            .map(|conn| conn.stream.is_some())
            .unwrap_or(false);
        if !live {
            return; // stale event for a torn-down connection
        }
        if ev.error || ev.hangup {
            self.fail_conn(b, c, poller, "connection error");
            return;
        }
        if ev.writable {
            self.flush_conn(b, c, poller);
        }
        if ev.readable {
            self.read_conn(b, c, poller, chunk);
        }
    }

    fn flush_conn(&mut self, b: usize, c: usize, poller: &mut Poller) {
        let conn = &mut self.backends[b].conns[c];
        let Some(stream) = conn.stream.as_mut() else {
            return;
        };
        if !conn.write.is_empty() {
            if let Err(e) = conn.write.flush_to(stream) {
                self.fail_conn(b, c, poller, &format!("write failed: {e}"));
                return;
            }
        }
        self.update_interest(b, c, poller);
    }

    fn update_interest(&mut self, b: usize, c: usize, poller: &mut Poller) {
        let conn = &mut self.backends[b].conns[c];
        let Some(stream) = conn.stream.as_ref() else {
            return;
        };
        let want = Interest {
            readable: true,
            writable: !conn.write.is_empty(),
        };
        if want != conn.interest {
            use std::os::unix::io::AsRawFd;
            if poller
                .modify(stream.as_raw_fd(), Self::token(b, c), want)
                .is_ok()
            {
                conn.interest = want;
            }
        }
    }

    fn read_conn(&mut self, b: usize, c: usize, poller: &mut Poller, chunk: &mut [u8]) {
        for _ in 0..READ_BUDGET {
            let conn = &mut self.backends[b].conns[c];
            let Some(stream) = conn.stream.as_mut() else {
                return;
            };
            match stream.read(chunk) {
                Ok(0) => {
                    self.fail_conn(b, c, poller, "disconnected");
                    return;
                }
                Ok(k) => {
                    if let Err(msg) = self.feed_bytes(b, c, k, chunk) {
                        self.fail_conn(b, c, poller, msg);
                        return;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    self.fail_conn(b, c, poller, &format!("read failed: {e}"));
                    return;
                }
            }
        }
    }

    /// Parse `chunk[..k]`: finish the describe handshake if pending,
    /// then re-associate complete replies to the FIFO front.
    fn feed_bytes(&mut self, b: usize, c: usize, k: usize, chunk: &[u8]) -> Result<(), &'static str> {
        let mut off = 0;
        while off < k {
            let backend = &mut self.backends[b];
            let conn = &mut backend.conns[c];
            if !conn.ready {
                let (used, done) = conn.desc.feed(&chunk[off..k])?;
                off += used;
                if let Some(elems) = done {
                    // Every route pointing at this backend must name a
                    // model the backend actually hosts — at the SAME id
                    // (frames forward verbatim; ids are not rewritten).
                    for (id, &tb) in self.table.iter().enumerate() {
                        if tb == b && elems.get(id).map(|&e| e == 0).unwrap_or(true) {
                            return Err("backend does not host a routed model id");
                        }
                    }
                    backend.dims = Some(elems);
                    conn.ready = true;
                    conn.backoff = BACKOFF_MIN;
                }
            } else {
                let (used, done) = conn.rd.feed(&chunk[off..k])?;
                off += used;
                if let Some(classes) = done {
                    complete_front(&mut conn.fifo, classes, &backend.stats)?;
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// RouterServer: bind/run wrapper (the router-mode `Server`)
// ---------------------------------------------------------------------

/// A bound router: listener + route table + knobs. The router-mode
/// counterpart of [`super::Server`] — same bind/run split so callers
/// (and tests) learn ephemeral ports and grab stats handles before the
/// blocking loop starts.
pub struct RouterServer {
    listener: TcpListener,
    stats_listener: Option<TcpListener>,
    routes: Vec<RouteSpec>,
    cfg: ServeConfig,
    stats: Arc<ServerStats>,
    router_stats: Arc<RouterStats>,
}

impl RouterServer {
    /// Bind the client listener (and the optional stats listener).
    /// Route order assigns router-visible model ids: the first route is
    /// id 0 and serves protocol-v1 clients.
    pub fn bind(routes: Vec<RouteSpec>, addr: &str, cfg: ServeConfig) -> Result<RouterServer> {
        cfg.validate()?;
        if routes.is_empty() {
            bail!("router mode needs at least one --route MODEL=host:port");
        }
        if routes.len() > u16::MAX as usize + 1 {
            bail!("too many routes ({}) for the u16 model-id space", routes.len());
        }
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let stats_listener = match cfg.stats_addr.as_deref() {
            Some(a) => Some(
                TcpListener::bind(a).with_context(|| format!("binding stats endpoint {a}"))?,
            ),
            None => None,
        };
        let router_stats = Arc::new(RouterStats::for_routes(&routes));
        let stats = Arc::new(ServerStats::for_router(
            routes.iter().map(|r| r.name.clone()).collect(),
            router_stats.clone(),
        ));
        Ok(RouterServer {
            listener,
            stats_listener,
            routes,
            cfg,
            stats,
            router_stats,
        })
    }

    /// Actual bound address (use after binding port 0).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Bound stats-endpoint address when `--stats-addr` is configured.
    pub fn stats_local_addr(&self) -> Option<std::net::SocketAddr> {
        self.stats_listener.as_ref().and_then(|l| l.local_addr().ok())
    }

    /// Live statistics handle (per-route request counters + server
    /// counters), valid before/during/after `run`.
    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    /// Live per-backend router counters.
    pub fn router_stats(&self) -> Arc<RouterStats> {
        self.router_stats.clone()
    }

    /// Run the router: the same ONE readiness event loop as serving
    /// mode, with backend pools in place of queues/scheduler/pool.
    /// Blocks under the same `max_accepts` bounded-run rules.
    pub fn run(self) -> Result<()> {
        let addr = self
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".into());
        println!(
            "aquant-serve: router on {addr} ({} route(s), pool {} conn(s)/backend, \
             in-flight window {}/conn)",
            self.routes.len(),
            self.cfg.route_pool,
            self.cfg.route_inflight,
        );
        for (id, r) in self.routes.iter().enumerate() {
            println!("aquant-serve:   id {id} = {} -> {}", r.name, r.addr);
        }
        if let Some(a) = self.stats_local_addr() {
            println!("aquant-serve: stats endpoint on http://{a}/stats (?fmt=text for plaintext)");
        }
        let history = self.cfg.stats_history.clone().map(|path| {
            println!(
                "aquant-serve: appending stats history to {path} every {}s",
                self.cfg.stats_history_every_s
            );
            metrics::HistoryWriter::spawn(
                path,
                Duration::from_secs(self.cfg.stats_history_every_s.max(1)),
                self.stats.clone(),
            )
        });
        let router = Router::new(&self.routes, &self.cfg, self.router_stats.clone());
        let loop_ctx = conn::LoopCtx {
            control: None,
            stats: self.stats.clone(),
            doorbell: Arc::new(super::sched::Doorbell::new()),
            max_conns: self.cfg.max_conns,
            max_accepts: self.cfg.max_accepts,
            conn_timeout: (self.cfg.conn_timeout_ms > 0)
                .then(|| Duration::from_millis(self.cfg.conn_timeout_ms)),
            poll_fallback: self.cfg.poll_fallback,
            stats_listener: self.stats_listener,
            admin_listener: None,
            router: Some(router),
        };
        let served = conn::run_event_loop(self.listener, loop_ctx);
        if let Some(w) = history {
            w.stop();
        }
        served
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats1() -> BackendStats {
        BackendStats::new("127.0.0.1:1".into(), vec!["a".into()])
    }

    fn pending(n: u32) -> (PendingReply, mpsc::Receiver<Result<Vec<u32>, String>>) {
        let (tx, rx) = mpsc::channel();
        (
            PendingReply {
                tx,
                n,
                t0: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn reply_reader_one_frame_per_feed_never_over_consumes() {
        let mut rd = ReplyReader::new();
        // two pipelined replies back to back: [2; 7, 9] [1; 3]
        let mut bytes = Vec::new();
        for w in [2u32, 7, 9, 1, 3] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        let (used, done) = rd.feed(&bytes).unwrap();
        assert_eq!(used, 12, "stops at the first frame boundary");
        assert_eq!(done, Some(vec![7, 9]));
        let (used2, done2) = rd.feed(&bytes[used..]).unwrap();
        assert_eq!(used2, 8);
        assert_eq!(done2, Some(vec![3]));
    }

    #[test]
    fn reply_reader_byte_by_byte() {
        let mut rd = ReplyReader::new();
        let mut bytes = Vec::new();
        for w in [3u32, 10, 20, 30] {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        for (i, b) in bytes.iter().enumerate() {
            let (used, done) = rd.feed(std::slice::from_ref(b)).unwrap();
            assert_eq!(used, 1);
            if i + 1 < bytes.len() {
                assert_eq!(done, None, "byte {i}");
            } else {
                assert_eq!(done, Some(vec![10, 20, 30]));
            }
        }
    }

    #[test]
    fn reply_reader_rejects_out_of_range_counts() {
        let mut rd = ReplyReader::new();
        assert!(rd.feed(&0u32.to_le_bytes()).is_err(), "zero count");
        let mut rd = ReplyReader::new();
        let too_big = (MAX_REQ_IMAGES as u32 + 1).to_le_bytes();
        assert!(rd.feed(&too_big).is_err());
        // the describe cap admits the full u16 model-id space
        let mut rd = ReplyReader::with_cap(MAX_DESC_MODELS);
        assert!(rd.feed(&(MAX_DESC_MODELS as u32).to_le_bytes()).is_ok());
    }

    #[test]
    fn complete_front_pops_in_order_and_validates_count() {
        let stats = stats1();
        let mut fifo = VecDeque::new();
        let (p1, rx1) = pending(2);
        let (p2, rx2) = pending(1);
        stats.inflight.store(2, Ordering::Relaxed);
        fifo.push_back(p1);
        fifo.push_back(p2);
        complete_front(&mut fifo, vec![5, 6], &stats).unwrap();
        assert_eq!(rx1.try_recv().unwrap().unwrap(), vec![5, 6]);
        // count mismatch: front stays queued so teardown can fail it
        assert!(complete_front(&mut fifo, vec![1, 2, 3], &stats).is_err());
        assert_eq!(fifo.len(), 1);
        complete_front(&mut fifo, vec![8], &stats).unwrap();
        assert_eq!(rx2.try_recv().unwrap().unwrap(), vec![8]);
        assert_eq!(stats.answered.load(Ordering::Relaxed), 2);
        assert_eq!(stats.inflight.load(Ordering::Relaxed), 0);
        assert_eq!(stats.rtt.count(), 2);
        // a reply with nothing in flight is a protocol error
        assert!(complete_front(&mut fifo, vec![1], &stats).is_err());
    }

    #[test]
    fn fail_window_errors_every_pending_request() {
        let stats = stats1();
        let mut fifo = VecDeque::new();
        let (p1, rx1) = pending(1);
        let (p2, rx2) = pending(1);
        stats.inflight.store(2, Ordering::Relaxed);
        fifo.push_back(p1);
        fifo.push_back(p2);
        fail_window(&mut fifo, &stats, "backend gone");
        assert!(fifo.is_empty());
        assert_eq!(stats.failed.load(Ordering::Relaxed), 2);
        assert_eq!(stats.inflight.load(Ordering::Relaxed), 0);
        assert!(rx1.try_recv().unwrap().unwrap_err().contains("backend gone"));
        assert!(rx2.try_recv().unwrap().is_err());
    }

    #[test]
    fn router_stats_dedupes_backends_by_addr() {
        let routes = vec![
            RouteSpec {
                name: "a".into(),
                addr: "h1:1".into(),
            },
            RouteSpec {
                name: "b".into(),
                addr: "h2:2".into(),
            },
            RouteSpec {
                name: "c".into(),
                addr: "h1:1".into(),
            },
        ];
        let rs = RouterStats::for_routes(&routes);
        assert_eq!(rs.backends.len(), 2);
        assert_eq!(rs.backends[0].addr, "h1:1");
        assert_eq!(rs.backends[0].models, vec!["a".to_string(), "c".to_string()]);
        assert_eq!(rs.backends[1].models, vec!["b".to_string()]);
    }

    #[test]
    fn router_table_aligns_with_stats_dedup_order() {
        let routes = vec![
            RouteSpec {
                name: "a".into(),
                addr: "h1:1".into(),
            },
            RouteSpec {
                name: "b".into(),
                addr: "h2:2".into(),
            },
            RouteSpec {
                name: "c".into(),
                addr: "h1:1".into(),
            },
        ];
        let stats = Arc::new(RouterStats::for_routes(&routes));
        let cfg = ServeConfig::default();
        let r = Router::new(&routes, &cfg, stats.clone());
        assert_eq!(r.table, vec![0, 1, 0]);
        assert_eq!(r.n_routes(), 3);
        assert_eq!(r.backends.len(), 2);
        assert_eq!(r.backends[0].stats.addr, stats.backends[0].addr);
        // no handshake yet: every gate parks, describe reports zeros
        assert!(!r.has_capacity(0));
        assert_eq!(r.payload_elems(0), None);
        assert_eq!(r.describe_elems(), vec![0, 0, 0]);
    }
}
