//! Serving-observability tier: lock-free latency histograms, the
//! point-in-time [`Snapshot`] the stats endpoint serves, the tiny
//! HTTP-subset request/response codec that endpoint speaks, and the
//! JSON-lines history writer.
//!
//! # Latency recording
//!
//! Three distributions are recorded per model, all in microseconds and
//! all on hot paths, so [`LatencyHist`] is a fixed array of atomic
//! log2 buckets — `observe` is two relaxed `fetch_add`s plus a
//! `fetch_max`, no locks, no allocation:
//!
//! * **e2e** — per *request*, from the moment its payload finished
//!   decoding (enqueue into the model's batch queue) until its reply is
//!   staged into the connection's write buffer. This is what a client
//!   experiences net of socket I/O, and what the `slo_us=` policy key
//!   targets.
//! * **queue_wait** — per request, enqueue → scheduler pop: time spent
//!   waiting for fair-share admission. High queue_wait with low
//!   service time means the model is weight-starved, not slow.
//! * **service** — per *batch*, scheduler admission → pool completion
//!   (the pre-existing `total_us` measurement, now also bucketed).
//!
//! Quantiles come from `util::quantile::bucket_quantile`: log2 buckets
//! bound the relative error below 2x, which is the right trade for a
//! wait-free recording path (exact quantiles would need a mutex or a
//! sampling reservoir on every request).
//!
//! # The endpoint codec
//!
//! `GET /stats` answers a JSON [`Snapshot`]; `GET /stats?fmt=text` the
//! plaintext rendering. The parser here is deliberately *not* an HTTP
//! implementation: it accepts exactly one GET request head (≤
//! [`MAX_STATS_REQUEST`] bytes), ignores every header, and always
//! answers `Connection: close`. Anything else — other methods, other
//! paths, an oversized or malformed head — produces a one-shot error
//! response and a close, without ever touching the serving path (the
//! event loop serves both listeners, but stats connections have their
//! own token space, their own slab, and never count against
//! `--max-conns` or `--max-accepts`).

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::util::json::{self, Json};
use crate::util::quantile::bucket_quantile;

use super::ServerStats;

/// Log2-µs histogram buckets: bucket i counts observations in
/// [2^i, 2^(i+1)) µs, i.e. sub-µs .. ~35 minutes in the second-to-last
/// bucket; the last is open-ended. 32 buckets exactly, so the array
/// still derives `Default` (std stops at 32) and `Stats` stays
/// `#[derive(Default)]`.
pub const LAT_BUCKETS: usize = 32;

/// Lock-free latency histogram: fixed log2-µs buckets plus
/// count/sum/max, all relaxed atomics. Good for concurrent recording
/// from the event loop and scheduler threads while readers snapshot.
#[derive(Debug, Default)]
pub struct LatencyHist {
    buckets: [AtomicU64; LAT_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHist {
    /// Bucket index for a latency of `us` microseconds: floor(log2 us),
    /// clamped to the last bucket (0 lands in bucket 0).
    pub fn bucket(us: u64) -> usize {
        let us = us.max(1);
        ((63 - us.leading_zeros()) as usize).min(LAT_BUCKETS - 1)
    }

    /// Record one observation. Wait-free; safe from any thread.
    pub fn observe(&self, us: u64) {
        self.buckets[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Mean observation, or 0 when empty.
    pub fn mean_us(&self) -> f64 {
        let c = self.count.load(Ordering::Relaxed);
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    /// Point-in-time copy of the bucket counts (for delta-based
    /// interval quantiles — the SLO adapter diffs two of these).
    pub fn counts(&self) -> [u64; LAT_BUCKETS] {
        let mut out = [0u64; LAT_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Estimated `q`-quantile in µs (`None` when no observations).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        bucket_quantile(&self.counts(), q)
    }

    /// Freeze count/mean/max + p50/p90/p99 for a snapshot.
    pub fn summary(&self) -> HistSummary {
        let counts = self.counts();
        HistSummary {
            count: counts.iter().sum(),
            mean_us: self.mean_us(),
            max_us: self.max_us(),
            p50_us: bucket_quantile(&counts, 0.50),
            p90_us: bucket_quantile(&counts, 0.90),
            p99_us: bucket_quantile(&counts, 0.99),
        }
    }
}

/// Frozen summary of one [`LatencyHist`]. Quantiles are `None` (JSON
/// `null`, text "-") when nothing was observed — never a fake 0.
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_us: f64,
    pub max_us: u64,
    pub p50_us: Option<f64>,
    pub p90_us: Option<f64>,
    pub p99_us: Option<f64>,
}

impl HistSummary {
    fn to_json(&self) -> Json {
        let q = |v: Option<f64>| v.map(json::num).unwrap_or(Json::Null);
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean_us", json::num(self.mean_us)),
            ("max_us", json::num(self.max_us as f64)),
            ("p50_us", q(self.p50_us)),
            ("p90_us", q(self.p90_us)),
            ("p99_us", q(self.p99_us)),
        ])
    }

    /// "p50/p90/p99 120/450/900us" (or "-" for empty histograms).
    fn quantile_line(&self) -> String {
        let f = |v: Option<f64>| match v {
            Some(v) => format!("{v:.0}"),
            None => "-".into(),
        };
        format!(
            "p50/p90/p99 {}/{}/{}us",
            f(self.p50_us),
            f(self.p90_us),
            f(self.p99_us)
        )
    }
}

/// One model's slice of a [`Snapshot`].
#[derive(Debug, Clone)]
pub struct ModelSnapshot {
    pub id: usize,
    pub name: String,
    /// Registry epoch this model was (hot-)added in: 0 for the startup
    /// set, the swap's epoch for models added over the admin endpoint.
    pub added_at_epoch: u64,
    pub requests: u64,
    pub images: u64,
    pub batches: u64,
    pub failed_batches: u64,
    pub rejected: u64,
    pub queue_depth: u64,
    pub queue_peak: u64,
    pub admitted: u64,
    pub deferred: u64,
    pub deficit: i64,
    pub mean_batch: f64,
    /// Static configured fair-share weight.
    pub weight: u64,
    /// Configured p99 e2e SLO in µs (0 = no SLO on this model).
    pub slo_us: u64,
    /// Current adaptive weight ×1000 (== weight×1000 when no SLO or no
    /// pressure; boosted while the SLO is being missed).
    pub effective_weight_milli: u64,
    pub e2e: HistSummary,
    pub queue_wait: HistSummary,
    pub service: HistSummary,
}

impl ModelSnapshot {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("name", json::s(&self.name)),
            ("added_at_epoch", json::num(self.added_at_epoch as f64)),
            ("requests", json::num(self.requests as f64)),
            ("images", json::num(self.images as f64)),
            ("batches", json::num(self.batches as f64)),
            ("failed_batches", json::num(self.failed_batches as f64)),
            ("rejected", json::num(self.rejected as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("queue_peak", json::num(self.queue_peak as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("deferred", json::num(self.deferred as f64)),
            ("deficit", json::num(self.deficit as f64)),
            ("mean_batch", json::num(self.mean_batch)),
            ("weight", json::num(self.weight as f64)),
            ("slo_us", json::num(self.slo_us as f64)),
            (
                "effective_weight_milli",
                json::num(self.effective_weight_milli as f64),
            ),
            ("e2e", self.e2e.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("service", self.service.to_json()),
        ])
    }
}

/// One backend's slice of a router-mode [`Snapshot`].
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    /// Backend address (`host:port`).
    pub addr: String,
    /// Route keys this backend serves.
    pub models: Vec<String>,
    pub forwarded: u64,
    pub answered: u64,
    pub failed: u64,
    /// Requests currently awaiting a backend reply (gauge).
    pub inflight: u64,
    pub reconnects: u64,
    /// Backend round-trip time (forward → reply parsed).
    pub rtt: HistSummary,
}

impl BackendSnapshot {
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("addr", json::s(&self.addr)),
            (
                "models",
                json::arr(self.models.iter().map(|m| json::s(m))),
            ),
            ("forwarded", json::num(self.forwarded as f64)),
            ("answered", json::num(self.answered as f64)),
            ("failed", json::num(self.failed as f64)),
            ("inflight", json::num(self.inflight as f64)),
            ("reconnects", json::num(self.reconnects as f64)),
            ("rtt", self.rtt.to_json()),
        ])
    }
}

/// Point-in-time view of a whole [`ServerStats`]: what `GET /stats`
/// serves and what each history line persists. Collected with relaxed
/// loads while the server runs, so counters may be mutually a few
/// events apart — each value is individually exact.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub uptime_s: f64,
    pub models: Vec<ModelSnapshot>,
    /// Router mode only: one entry per distinct backend address
    /// (empty when serving locally).
    pub backends: Vec<BackendSnapshot>,
    pub unknown_model: u64,
    pub bad_version: u64,
    pub rounds: u64,
    /// Current registry epoch (0 until the first admin swap).
    pub registry_epoch: u64,
    /// Completed control-plane swaps (add/remove/policy/reload).
    pub reloads: u64,
    pub conns_open: u64,
    pub conns_accepted: u64,
    pub conns_rejected: u64,
    pub conns_timed_out: u64,
    /// Active SIMD kernel backend (`scalar`/`avx2`/`neon`).
    pub kernel_backend: &'static str,
    /// Resolved GEMM accuracy mode (`exact`, or `fma` when the relaxed
    /// kernels were opted into via `--fast-kernels` / `AQUANT_FAST`).
    pub fast_mode: &'static str,
}

impl Snapshot {
    /// Freeze the current counters. Read-only: safe to call from any
    /// thread, any number of times, while serving continues.
    pub fn collect(stats: &ServerStats) -> Snapshot {
        let models = stats
            .rows_snapshot()
            .into_iter()
            .enumerate()
            .map(|(id, (name, s, added_at_epoch))| ModelSnapshot {
                id,
                name,
                added_at_epoch,
                requests: s.requests.load(Ordering::Relaxed),
                images: s.images.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                failed_batches: s.failed_batches.load(Ordering::Relaxed),
                rejected: s.rejected.load(Ordering::Relaxed),
                queue_depth: s.queue_depth.load(Ordering::Relaxed),
                queue_peak: s.queue_peak.load(Ordering::Relaxed),
                admitted: s.admitted.load(Ordering::Relaxed),
                deferred: s.deferred.load(Ordering::Relaxed),
                deficit: s.deficit.load(Ordering::Relaxed),
                mean_batch: s.mean_batch(),
                weight: s.weight.load(Ordering::Relaxed),
                slo_us: s.slo_us.load(Ordering::Relaxed),
                effective_weight_milli: s.effective_weight_milli.load(Ordering::Relaxed),
                e2e: s.e2e_hist.summary(),
                queue_wait: s.queue_wait_hist.summary(),
                service: s.service_hist.summary(),
            })
            .collect();
        let backends = stats
            .router()
            .map(|r| {
                r.backends
                    .iter()
                    .map(|b| BackendSnapshot {
                        addr: b.addr.clone(),
                        models: b.models.clone(),
                        forwarded: b.forwarded.load(Ordering::Relaxed),
                        answered: b.answered.load(Ordering::Relaxed),
                        failed: b.failed.load(Ordering::Relaxed),
                        inflight: b.inflight.load(Ordering::Relaxed),
                        reconnects: b.reconnects.load(Ordering::Relaxed),
                        rtt: b.rtt.summary(),
                    })
                    .collect()
            })
            .unwrap_or_default();
        Snapshot {
            uptime_s: stats.uptime().as_secs_f64(),
            models,
            backends,
            unknown_model: stats.unknown_model.load(Ordering::Relaxed),
            bad_version: stats.bad_version.load(Ordering::Relaxed),
            rounds: stats.rounds.load(Ordering::Relaxed),
            registry_epoch: stats.registry_epoch.load(Ordering::Relaxed),
            reloads: stats.reloads.load(Ordering::Relaxed),
            conns_open: stats.conns_open.load(Ordering::Relaxed),
            conns_accepted: stats.conns_accepted.load(Ordering::Relaxed),
            conns_rejected: stats.conns_rejected.load(Ordering::Relaxed),
            conns_timed_out: stats.conns_timed_out.load(Ordering::Relaxed),
            kernel_backend: crate::nn::kernels::active().name(),
            fast_mode: crate::nn::kernels::fast_mode().name(),
        }
    }

    /// The JSON document `GET /stats` returns (field glossary in
    /// README "Observability").
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("uptime_s", json::num(self.uptime_s)),
            (
                "models",
                json::arr(self.models.iter().map(|m| m.to_json())),
            ),
        ];
        if !self.backends.is_empty() {
            fields.push((
                "router",
                json::obj(vec![(
                    "backends",
                    json::arr(self.backends.iter().map(|b| b.to_json())),
                )]),
            ));
        }
        fields.push((
            "server",
            json::obj(vec![
                ("unknown_model", json::num(self.unknown_model as f64)),
                ("bad_version", json::num(self.bad_version as f64)),
                ("rounds", json::num(self.rounds as f64)),
                ("registry_epoch", json::num(self.registry_epoch as f64)),
                ("reloads", json::num(self.reloads as f64)),
                ("conns_open", json::num(self.conns_open as f64)),
                ("conns_accepted", json::num(self.conns_accepted as f64)),
                ("conns_rejected", json::num(self.conns_rejected as f64)),
                ("conns_timed_out", json::num(self.conns_timed_out as f64)),
                ("kernel_backend", json::s(self.kernel_backend)),
                ("fast_mode", json::s(self.fast_mode)),
            ]),
        ));
        json::obj(fields)
    }

    /// The plaintext rendering `GET /stats?fmt=text` returns: one line
    /// per model plus a server line, grep-friendly.
    pub fn to_text(&self) -> String {
        let mut out = format!(
            "aquant stats: uptime {:.1}s, {} model(s)\n",
            self.uptime_s,
            self.models.len()
        );
        for m in &self.models {
            out.push_str(&format!(
                "model {} {}: requests {}  images {}  batches {} (mean {:.1} img/batch)  \
                 queue depth {} (peak {})  admitted {}  deferred {}  deficit {}  \
                 e2e {}  queue-wait {}  service {}  weight {}{}  eff-weight {:.3}x\n",
                m.id,
                m.name,
                m.requests,
                m.images,
                m.batches,
                m.mean_batch,
                m.queue_depth,
                m.queue_peak,
                m.admitted,
                m.deferred,
                m.deficit,
                m.e2e.quantile_line(),
                m.queue_wait.quantile_line(),
                m.service.quantile_line(),
                m.weight,
                if m.slo_us > 0 {
                    format!(" (slo p99 {}us)", m.slo_us)
                } else {
                    String::new()
                },
                m.effective_weight_milli as f64 / 1000.0,
            ));
        }
        for b in &self.backends {
            out.push_str(&format!(
                "backend {} [{}]: forwarded {}  answered {}  failed {}  in-flight {}  \
                 reconnects {}  rtt {}\n",
                b.addr,
                b.models.join(","),
                b.forwarded,
                b.answered,
                b.failed,
                b.inflight,
                b.reconnects,
                b.rtt.quantile_line(),
            ));
        }
        out.push_str(&format!(
            "server: unknown-model {}  bad-version {}  sched-rounds {}  \
             epoch {}  reloads {}  \
             conns open {} / accepted {} / rejected {} / timed-out {}  \
             kernels {} ({})\n",
            self.unknown_model,
            self.bad_version,
            self.rounds,
            self.registry_epoch,
            self.reloads,
            self.conns_open,
            self.conns_accepted,
            self.conns_rejected,
            self.conns_timed_out,
            self.kernel_backend,
            self.fast_mode,
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// Endpoint codec (pure functions; the event loop owns the sockets)
// ---------------------------------------------------------------------------

/// Cap on a stats request head. A real `GET /stats` head is < 100
/// bytes; anything still incomplete past this is hostile or lost and
/// gets rejected without buffering more.
pub const MAX_STATS_REQUEST: usize = 4096;

/// Response format a parsed stats request asked for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsFormat {
    Json,
    Text,
}

/// Outcome of parsing the bytes read so far from a stats connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsParse {
    /// Head not terminated yet — keep reading (caller enforces the
    /// size cap by passing at most [`MAX_STATS_REQUEST`] bytes).
    Incomplete,
    /// A well-formed `GET /stats` head: answer in this format.
    Ok(StatsFormat),
    /// Reject: respond with this status line + message, then close.
    Reject(&'static str, &'static str),
}

/// Parse a stats-endpoint request head. The head ends at the first
/// blank line (`\r\n\r\n` or `\n\n`); headers are ignored. Oversized
/// (no terminator within [`MAX_STATS_REQUEST`] bytes) and malformed
/// heads reject immediately.
pub fn parse_stats_request(buf: &[u8]) -> StatsParse {
    let head_end = find_head_end(buf);
    let head = match head_end {
        Some(n) => &buf[..n],
        None if buf.len() >= MAX_STATS_REQUEST => {
            return StatsParse::Reject(
                "431 Request Header Fields Too Large",
                "request head exceeds 4096 bytes\n",
            )
        }
        None => return StatsParse::Incomplete,
    };
    let head = match std::str::from_utf8(head) {
        Ok(h) => h,
        Err(_) => return StatsParse::Reject("400 Bad Request", "non-UTF8 request\n"),
    };
    let line = head.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => return StatsParse::Reject("400 Bad Request", "malformed request line\n"),
    };
    if method != "GET" {
        return StatsParse::Reject("405 Method Not Allowed", "only GET is supported\n");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    if path != "/stats" {
        return StatsParse::Reject("404 Not Found", "only /stats is served\n");
    }
    match query {
        None | Some("") => StatsParse::Ok(StatsFormat::Json),
        Some("fmt=json") => StatsParse::Ok(StatsFormat::Json),
        Some("fmt=text") => StatsParse::Ok(StatsFormat::Text),
        Some(_) => StatsParse::Reject(
            "400 Bad Request",
            "unknown query (supported: fmt=json, fmt=text)\n",
        ),
    }
}

/// First index *past* the head terminator, if present.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| i + 4)
        .or_else(|| buf.windows(2).position(|w| w == b"\n\n").map(|i| i + 2))
}

/// Build a complete one-shot HTTP response (the endpoint always
/// closes after answering, so HTTP/1.0 + Connection: close).
pub fn http_response(status: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Render the response for a successfully parsed stats request.
pub fn stats_response(snapshot: &Snapshot, fmt: StatsFormat) -> Vec<u8> {
    match fmt {
        StatsFormat::Json => http_response(
            "200 OK",
            "application/json",
            &snapshot.to_json().dump(),
        ),
        StatsFormat::Text => {
            http_response("200 OK", "text/plain; charset=utf-8", &snapshot.to_text())
        }
    }
}

// ---------------------------------------------------------------------------
// Persistent history (JSON-lines appender)
// ---------------------------------------------------------------------------

/// Background snapshot persister: appends one JSON line (a
/// [`Snapshot::to_json`] object plus a `"t"` unix-seconds stamp) to a
/// history file every `every`, plus a final line at [`stop`] so even
/// the shortest bounded run leaves its terminal counters on disk.
/// Write failures are reported once on stderr and then ignored — the
/// history file must never take the server down.
///
/// [`stop`]: HistoryWriter::stop
pub struct HistoryWriter {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl HistoryWriter {
    pub fn spawn(path: String, every: Duration, stats: Arc<ServerStats>) -> HistoryWriter {
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let flag = stop.clone();
        let handle = std::thread::Builder::new()
            .name("aquant-stats-history".into())
            .spawn(move || {
                let mut warned = false;
                loop {
                    append_snapshot(&path, &stats, &mut warned);
                    let (lock, cvar) = &*flag;
                    let mut stopped = lock.lock().unwrap();
                    while !*stopped {
                        let (g, timeout) = cvar.wait_timeout(stopped, every).unwrap();
                        stopped = g;
                        if timeout.timed_out() {
                            break;
                        }
                    }
                    if *stopped {
                        drop(stopped);
                        // final flush: persist the terminal counters
                        append_snapshot(&path, &stats, &mut warned);
                        return;
                    }
                }
            })
            .expect("spawning the stats-history thread");
        HistoryWriter {
            stop,
            handle: Some(handle),
        }
    }

    /// Signal the writer, wait for its final flush.
    pub fn stop(mut self) {
        let (lock, cvar) = &*self.stop;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn append_snapshot(path: &str, stats: &ServerStats, warned: &mut bool) {
    let mut j = Snapshot::collect(stats).to_json();
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    if let Json::Obj(m) = &mut j {
        m.insert("t".into(), json::num(t));
    }
    let line = j.dump();
    let res = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| writeln!(f, "{line}"));
    if let Err(e) = res {
        if !*warned {
            eprintln!("aquant-serve: stats history write to {path:?} failed: {e} (suppressing further warnings)");
            *warned = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::registry::ModelRegistry;
    use crate::nn::synth;
    use crate::util::rng::Rng;

    fn test_stats() -> ServerStats {
        let mut rng = Rng::new(5);
        let (topo, weights) = synth::tiny_model(&mut rng);
        let eng = Arc::new(synth::engine_with_random_borders(
            &topo, &weights, &mut rng, true, true,
        ));
        let reg = ModelRegistry::new(vec![("a".into(), eng.clone()), ("b".into(), eng)])
            .unwrap();
        ServerStats::new(&reg)
    }

    #[test]
    fn hist_buckets_and_quantiles() {
        let h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.99), None);
        for us in [0, 1, 3, 100, 1000, 1_000_000, u64::MAX] {
            h.observe(us);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max_us(), u64::MAX);
        let s = h.summary();
        let (p50, p90, p99) = (
            s.p50_us.unwrap(),
            s.p90_us.unwrap(),
            s.p99_us.unwrap(),
        );
        assert!(p50 <= p90 && p90 <= p99, "{p50} {p90} {p99}");
        assert_eq!(LatencyHist::bucket(0), 0);
        assert_eq!(LatencyHist::bucket(1), 0);
        assert_eq!(LatencyHist::bucket(2), 1);
        assert_eq!(LatencyHist::bucket(1023), 9);
        assert_eq!(LatencyHist::bucket(1024), 10);
        assert_eq!(LatencyHist::bucket(u64::MAX), LAT_BUCKETS - 1);
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        let stats = test_stats();
        let m0 = stats.model(0).unwrap();
        m0.observe_batch(8, 500);
        m0.requests.fetch_add(3, Ordering::Relaxed);
        m0.e2e_hist.observe(700);
        m0.e2e_hist.observe(1500);
        m0.queue_wait_hist.observe(90);
        let snap = Snapshot::collect(&stats);
        assert_eq!(snap.models.len(), 2);
        assert_eq!(snap.models[0].requests, 3);
        assert_eq!(snap.models[0].images, 8);
        assert_eq!(snap.models[0].e2e.count, 2);
        assert_eq!(snap.models[1].requests, 0);
        // serialized form parses back and carries the same numbers
        let j = Json::parse(&snap.to_json().dump()).unwrap();
        let models = j.req("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].req("requests").unwrap().as_i64(), Some(3));
        assert_eq!(models[0].req("name").unwrap().as_str(), Some("a"));
        // empty histogram quantiles serialize as null, not 0
        assert_eq!(
            models[1].req("e2e").unwrap().req("p99_us").unwrap(),
            &Json::Null
        );
        assert!(j.req("server").unwrap().get("rounds").is_some());
        // control-plane gauges ride along: startup models carry
        // added_at_epoch 0 and no swap has happened yet
        assert_eq!(models[0].req("added_at_epoch").unwrap().as_i64(), Some(0));
        let server = j.req("server").unwrap();
        assert_eq!(server.req("registry_epoch").unwrap().as_i64(), Some(0));
        assert_eq!(server.req("reloads").unwrap().as_i64(), Some(0));
        // the kernel identity rides along: fast mode is "exact" unless
        // the relaxed kernels were explicitly requested
        let server = j.req("server").unwrap();
        assert_eq!(
            server.req("kernel_backend").unwrap().as_str(),
            Some(crate::nn::kernels::active().name())
        );
        assert_eq!(server.req("fast_mode").unwrap().as_str(), Some(snap.fast_mode));
        // the text rendering mentions every model
        let text = snap.to_text();
        assert!(text.contains("model 0 a:"), "{text}");
        assert!(text.contains("model 1 b:"), "{text}");
        assert!(text.contains(&format!("kernels {}", snap.kernel_backend)), "{text}");
    }

    #[test]
    fn router_snapshot_surfaces_per_backend_counters() {
        use super::super::route::RouterStats;
        use crate::config::RouteSpec;
        let routes = vec![
            RouteSpec {
                name: "tiny".into(),
                addr: "127.0.0.1:9001".into(),
            },
            RouteSpec {
                name: "bench".into(),
                addr: "127.0.0.1:9002".into(),
            },
        ];
        let router = Arc::new(RouterStats::for_routes(&routes));
        router.backends[0].forwarded.fetch_add(5, Ordering::Relaxed);
        router.backends[0].answered.fetch_add(4, Ordering::Relaxed);
        router.backends[0].inflight.fetch_add(1, Ordering::Relaxed);
        router.backends[0].rtt.observe(250);
        router.backends[1].failed.fetch_add(2, Ordering::Relaxed);
        router.backends[1].reconnects.fetch_add(3, Ordering::Relaxed);
        let stats = ServerStats::for_router(
            vec!["tiny".into(), "bench".into()],
            router,
        );
        let snap = Snapshot::collect(&stats);
        assert_eq!(snap.backends.len(), 2);
        assert_eq!(snap.backends[0].forwarded, 5);
        assert_eq!(snap.backends[0].inflight, 1);
        assert_eq!(snap.backends[0].rtt.count, 1);
        assert_eq!(snap.backends[1].failed, 2);
        assert_eq!(snap.backends[1].reconnects, 3);
        // JSON: router key present, backends carry addr + counters
        let j = Json::parse(&snap.to_json().dump()).unwrap();
        let backends = j
            .req("router")
            .unwrap()
            .req("backends")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(backends.len(), 2);
        assert_eq!(
            backends[0].req("addr").unwrap().as_str(),
            Some("127.0.0.1:9001")
        );
        assert_eq!(backends[0].req("forwarded").unwrap().as_i64(), Some(5));
        assert_eq!(backends[1].req("reconnects").unwrap().as_i64(), Some(3));
        // text rendering names each backend
        let text = snap.to_text();
        assert!(text.contains("backend 127.0.0.1:9001 [tiny]:"), "{text}");
        assert!(text.contains("reconnects 3"), "{text}");
        // local-serving snapshots carry no router key
        let local = Snapshot::collect(&test_stats());
        assert!(local.backends.is_empty());
        let j = Json::parse(&local.to_json().dump()).unwrap();
        assert!(j.get("router").is_none());
    }

    #[test]
    fn parse_accepts_stats_gets() {
        for (req, fmt) in [
            ("GET /stats HTTP/1.1\r\nHost: x\r\n\r\n", StatsFormat::Json),
            ("GET /stats HTTP/1.0\r\n\r\n", StatsFormat::Json),
            ("GET /stats?fmt=json HTTP/1.1\r\n\r\n", StatsFormat::Json),
            ("GET /stats?fmt=text HTTP/1.1\r\n\r\n", StatsFormat::Text),
            ("GET /stats HTTP/1.1\n\n", StatsFormat::Json),
        ] {
            assert_eq!(
                parse_stats_request(req.as_bytes()),
                StatsParse::Ok(fmt),
                "{req:?}"
            );
        }
    }

    #[test]
    fn parse_rejects_everything_else() {
        // incomplete: no verdict yet
        assert_eq!(
            parse_stats_request(b"GET /stats HTTP/1.1\r\n"),
            StatsParse::Incomplete
        );
        let reject = |req: &str| match parse_stats_request(req.as_bytes()) {
            StatsParse::Reject(status, _) => status.to_string(),
            other => panic!("{req:?} -> {other:?}"),
        };
        assert!(reject("POST /stats HTTP/1.1\r\n\r\n").starts_with("405"));
        assert!(reject("GET /other HTTP/1.1\r\n\r\n").starts_with("404"));
        assert!(reject("GET /stats?fmt=xml HTTP/1.1\r\n\r\n").starts_with("400"));
        assert!(reject("garbage\r\n\r\n").starts_with("400"));
        assert!(reject("\r\n\r\n").starts_with("400"));
        // oversized head without a terminator
        let big = vec![b'A'; MAX_STATS_REQUEST];
        assert!(matches!(
            parse_stats_request(&big),
            StatsParse::Reject(s, _) if s.starts_with("431")
        ));
    }

    #[test]
    fn http_responses_are_framed() {
        let r = http_response("200 OK", "application/json", "{}");
        let text = String::from_utf8(r).unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }

    #[test]
    fn history_writer_appends_and_final_flushes() {
        let stats = Arc::new(test_stats());
        stats
            .model(0)
            .unwrap()
            .requests
            .fetch_add(7, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "aquant_hist_test_{}.jsonl",
            std::process::id()
        ));
        let path_s = path.to_string_lossy().to_string();
        let _ = std::fs::remove_file(&path);
        // long interval: the lines present must be the startup write +
        // the final stop() flush, not timer ticks
        let w = HistoryWriter::spawn(path_s.clone(), Duration::from_secs(3600), stats);
        w.stop();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2, "{body}");
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("t").is_some());
            let models = j.req("models").unwrap().as_arr().unwrap();
            assert_eq!(models[0].req("requests").unwrap().as_i64(), Some(7));
        }
        let _ = std::fs::remove_file(&path);
    }
}
