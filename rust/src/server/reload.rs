//! Control plane: epoch-swapped model registry under live traffic.
//!
//! A serving process owns ONE [`ControlPlane`]. Its current
//! [`EpochState`] is an immutable snapshot — registry + one
//! [`SlotState`] per model slot ever assigned — shared as an `Arc`
//! (the cirrus `ConfigReloaded { new_config: Arc<Config> }` shape:
//! readers clone the Arc, writers publish a whole new value). The
//! admin listener (see [`super::conn`]) feeds operator lines into
//! [`ControlPlane::apply_line`]; each applied command derives a
//! next-epoch registry, rebuilds the slot table, publishes it, and
//! rings the scheduler's doorbell.
//!
//! # Swap semantics (the drain guarantees)
//!
//! * **Per-slot Arcs survive swaps.** A surviving model keeps its
//!   `BatchQueue`, `Stats`, and (unless re-added) `Engine` across
//!   epochs, so requests queued before a swap drain normally and
//!   counters never reset. In-flight batches already carry their
//!   `Arc<Engine>` — they finish on the old engine no matter what.
//! * **Removed models tombstone, never vanish.** The slot stays in
//!   the table with `live = false`: the scheduler keeps polling its
//!   queue (draining whatever was admitted before the removal, on
//!   the old engine), while the connection gate rejects NEW requests
//!   for the id with the existing unknown-model error. Ids are never
//!   reused; re-adding the name assigns a fresh id.
//! * **Policy retunes apply at the next scheduling decision.** The
//!   scheduler reads `max_batch`/`batch_wait_us`/`weight`/`slo_us`
//!   from the current epoch's slot table every pass, and the queue's
//!   push-side bound is retuned in place — only the queue's wakeup
//!   hint (`ready_images`) keeps its creation-time value, a
//!   heuristic with no correctness weight.
//! * **A rejected command changes nothing.** Registry derivation and
//!   policy re-resolution both complete before anything is
//!   published; any failure replies `err ...` and the old epoch
//!   stays current.
//!
//! Scheduling is the only thing a swap may change — predictions stay
//! bit-identical for unchanged models (pinned by
//! `rust/tests/reload_conformance.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::config::{ModelSource, ModelSpec, PolicyOverrides};
use crate::nn::engine::Engine;
use crate::nn::registry::ModelRegistry;
use crate::nn::synth;

use super::sched::{BatchQueue, Doorbell, Policy};
use super::{ServerStats, Stats};
use super::{
    ADMIN_CMD_ADD, ADMIN_CMD_POLICY, ADMIN_CMD_RELOAD, ADMIN_CMD_REMOVE, ADMIN_ERR, ADMIN_OK,
};

/// One model slot at one epoch: everything the scheduler and the
/// connection gate need, indexed by wire id. Slots are append-only —
/// a removed model's slot stays (with `live = false`) so its queue
/// keeps draining on its old engine.
pub(crate) struct SlotState {
    pub queue: Arc<BatchQueue>,
    /// Resolved serving policy. For a tombstoned slot this is the
    /// policy it died with — the drain keeps its batching behavior.
    pub policy: Policy,
    pub engine: Arc<Engine>,
    pub stats: Arc<Stats>,
    /// Live in this epoch's registry; `false` = tombstoned (new
    /// requests rejected, queued ones drain).
    pub live: bool,
}

/// An immutable epoch snapshot: the registry plus the derived
/// per-slot serving state. Readers hold it as an `Arc` and never see
/// it mutate; the control plane publishes a fresh one per swap.
pub(crate) struct EpochState {
    pub epoch: u64,
    pub registry: Arc<ModelRegistry>,
    /// Indexed by model id; `len()` == slots ever assigned.
    pub slots: Vec<SlotState>,
}

/// The serving process's control plane: current epoch state plus the
/// machinery to apply admin commands. One per server; shared by the
/// event loop (gate + admin protocol), the scheduler (re-resolves on
/// epoch change), and shutdown.
pub(crate) struct ControlPlane {
    /// Mirror of `current.epoch`, readable without the mutex so hot
    /// loops can detect "nothing changed" with one atomic load.
    epoch: AtomicU64,
    current: Mutex<Arc<EpochState>>,
    /// Server-level policy defaults that per-model overrides resolve
    /// against (same resolution as bind).
    defaults: Policy,
    stats: Arc<ServerStats>,
    doorbell: Arc<Doorbell>,
}

impl ControlPlane {
    /// Wrap the bind-time registry (epoch 0, every slot live) with its
    /// already-resolved policies. Queues are created here — one per
    /// slot, bounded by that slot's policy.
    pub fn new(
        registry: Arc<ModelRegistry>,
        policies: &[Policy],
        defaults: Policy,
        stats: Arc<ServerStats>,
        doorbell: Arc<Doorbell>,
    ) -> ControlPlane {
        let slots = (0..registry.len())
            .map(|id| {
                let entry = registry
                    .get(id as u16)
                    .expect("bind-time registries have no tombstones");
                let policy = policies[id];
                SlotState {
                    queue: Arc::new(BatchQueue::new(policy.queue_images, policy.max_batch)),
                    policy,
                    engine: entry.engine.clone(),
                    stats: stats.model(id as u16).expect("stats row per slot"),
                    live: true,
                }
            })
            .collect();
        let epoch = registry.epoch();
        stats.registry_epoch.store(epoch, Ordering::Relaxed);
        ControlPlane {
            epoch: AtomicU64::new(epoch),
            current: Mutex::new(Arc::new(EpochState {
                epoch,
                registry,
                slots,
            })),
            defaults,
            stats,
            doorbell,
        }
    }

    /// Current epoch (cheap; hot loops compare it against their cached
    /// state's epoch before taking the mutex).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The current epoch snapshot.
    pub fn current(&self) -> Arc<EpochState> {
        self.current.lock().unwrap().clone()
    }

    /// Shut down every slot's queue (latest epoch — includes
    /// tombstoned slots still draining) and wake the scheduler so it
    /// can drain and exit.
    pub fn shutdown(&self) {
        for slot in &self.current().slots {
            slot.queue.shutdown();
        }
        self.doorbell.ring();
    }

    /// Apply one admin command line and return the full reply line
    /// (no trailing newline): `ok epoch=N models=M` or `err <reason>`.
    /// Only the event-loop thread calls this, so commands are applied
    /// one at a time in arrival order.
    pub fn apply_line(&self, line: &str) -> String {
        match self.apply(line) {
            Ok(reply) => reply,
            // {:#} renders the whole anyhow chain on one line
            Err(e) => format!("{ADMIN_ERR} {:#}", e).replace('\n', " "),
        }
    }

    fn apply(&self, line: &str) -> Result<String> {
        let line = line.trim();
        let (cmd, rest) = match line.split_once(char::is_whitespace) {
            Some((c, r)) => (c, r.trim()),
            None => (line, ""),
        };
        let cur = self.current();
        let registry = match cmd {
            c if c == ADMIN_CMD_ADD => {
                let spec = ModelSpec::parse(rest, None, None)
                    .with_context(|| format!("add: parsing spec {rest:?}"))?;
                let engine = match &spec.source {
                    ModelSource::Synth { kind, seed } => synth::engine_from_spec(kind, *seed)
                        .with_context(|| format!("add: building {rest:?}"))?,
                    ModelSource::Manifest { .. } => bail!(
                        "add: hot-add supports synth: specs only (manifest models need \
                         calibration artifacts resolved at startup)"
                    ),
                };
                cur.registry
                    .with_added(&spec.name, Arc::new(engine), spec.policy.clone())?
            }
            c if c == ADMIN_CMD_REMOVE => {
                if rest.is_empty() || rest.contains(char::is_whitespace) {
                    bail!("remove: want exactly one model name, got {rest:?}");
                }
                cur.registry.with_removed(rest)?
            }
            c if c == ADMIN_CMD_POLICY => {
                let (name, pairs) = rest
                    .split_once(char::is_whitespace)
                    .ok_or_else(|| anyhow::anyhow!("policy: want NAME key=value..., got {rest:?}"))?;
                let over = PolicyOverrides::parse_pairs(pairs.split_whitespace(), rest)?;
                if over.is_empty() {
                    bail!("policy: no keys given for {name:?}");
                }
                cur.registry.with_policy(name, &over)?
            }
            c if c == ADMIN_CMD_RELOAD => {
                if !rest.is_empty() {
                    bail!("reload takes no arguments, got {rest:?}");
                }
                cur.registry.reloaded()
            }
            other => bail!("unknown admin command {other:?} (want add|remove|policy|reload)"),
        };
        self.swap(&cur, Arc::new(registry))
    }

    /// Publish `registry` as the next epoch: re-resolve every live
    /// slot's policy (any failure rejects the whole command — nothing
    /// is published), carry queue/stats/engine Arcs for surviving
    /// slots, create queue + stats row for new slots, tombstone the
    /// rest. Ends by bumping the epoch mirror and ringing the
    /// scheduler's doorbell.
    fn swap(&self, cur: &EpochState, registry: Arc<ModelRegistry>) -> Result<String> {
        // Phase 1: validate everything fallible before touching any
        // shared state (stats rows are append-only — a failed swap
        // must not leak one).
        let mut resolved = Vec::with_capacity(registry.len());
        for id in 0..registry.len() {
            resolved.push(match registry.get(id as u16) {
                Some(entry) => Some((
                    Policy::resolve(&self.defaults, &entry.policy)
                        .with_context(|| format!("model {id} ({:?}) serving policy", entry.name))?,
                    entry,
                )),
                None => None,
            });
        }
        // Phase 2: build the slot table (infallible from here on).
        let epoch = registry.epoch();
        let mut slots = Vec::with_capacity(registry.len());
        let mut live = 0usize;
        for (id, r) in resolved.into_iter().enumerate() {
            let slot = match r {
                Some((policy, entry)) => {
                    live += 1;
                    let (queue, stats) = match cur.slots.get(id) {
                        // Surviving slot: same queue + counters, new
                        // policy. Retune the push-side bound in place.
                        Some(old) => {
                            old.queue.set_bounds(policy.queue_images, policy.max_batch);
                            (old.queue.clone(), old.stats.clone())
                        }
                        // Hot-added slot: fresh queue + stats row.
                        None => (
                            Arc::new(BatchQueue::new(policy.queue_images, policy.max_batch)),
                            self.stats.register_row(&entry.name, entry.added_at_epoch),
                        ),
                    };
                    stats.weight.store(policy.weight as u64, Ordering::Relaxed);
                    stats
                        .slo_us
                        .store(policy.slo_us.unwrap_or(0), Ordering::Relaxed);
                    stats
                        .effective_weight_milli
                        .store(policy.weight as u64 * 1000, Ordering::Relaxed);
                    SlotState {
                        queue,
                        policy,
                        engine: entry.engine.clone(),
                        stats,
                        live: true,
                    }
                }
                // Tombstoned slot: everything carries over so the
                // queue drains on the old engine; only `live` flips.
                None => {
                    let old = &cur.slots[id];
                    SlotState {
                        queue: old.queue.clone(),
                        policy: old.policy,
                        engine: old.engine.clone(),
                        stats: old.stats.clone(),
                        live: false,
                    }
                }
            };
            slots.push(slot);
        }
        let state = Arc::new(EpochState {
            epoch,
            registry,
            slots,
        });
        *self.current.lock().unwrap() = state;
        self.epoch.store(epoch, Ordering::Release);
        self.stats.note_swap(epoch);
        // Wake the scheduler (it re-resolves on the epoch change) and
        // anything parked on queue room.
        self.doorbell.ring();
        Ok(format!("{ADMIN_OK} epoch={epoch} models={live}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(specs: &[&str]) -> ControlPlane {
        let specs: Vec<ModelSpec> = specs
            .iter()
            .map(|s| ModelSpec::parse(s, None, None).unwrap())
            .collect();
        let registry =
            Arc::new(ModelRegistry::from_specs(&specs, |_| unreachable!("synth only")).unwrap());
        let defaults = Policy {
            max_batch: 8,
            batch_wait_us: 0,
            queue_images: 64,
            weight: 1,
            slo_us: None,
        };
        let policies: Vec<Policy> = registry
            .iter()
            .map(|(_, e)| Policy::resolve(&defaults, &e.policy).unwrap())
            .collect();
        let stats = Arc::new(ServerStats::new(&registry));
        ControlPlane::new(
            registry,
            &policies,
            defaults,
            stats,
            Arc::new(Doorbell::new()),
        )
    }

    #[test]
    fn add_assigns_a_fresh_slot_with_fresh_queue_and_stats() {
        let cp = plane(&["a=synth:tiny"]);
        let before = cp.current();
        let reply = cp.apply_line("add b=synth:tiny:7;weight=3");
        assert_eq!(reply, "ok epoch=1 models=2");
        let after = cp.current();
        assert_eq!(cp.epoch(), 1);
        assert_eq!(after.slots.len(), 2);
        // surviving slot keeps its Arcs
        assert!(Arc::ptr_eq(&before.slots[0].queue, &after.slots[0].queue));
        assert!(Arc::ptr_eq(&before.slots[0].stats, &after.slots[0].stats));
        assert!(Arc::ptr_eq(&before.slots[0].engine, &after.slots[0].engine));
        // new slot got a row, the right policy, and live = true
        assert!(after.slots[1].live);
        assert_eq!(after.slots[1].policy.weight, 3);
        assert_eq!(cp.stats.n_models(), 2);
        assert_eq!(cp.stats.model_name(1).as_deref(), Some("b"));
        assert_eq!(
            cp.stats.model(1).unwrap().weight.load(Ordering::Relaxed),
            3
        );
        assert_eq!(cp.stats.reloads.load(Ordering::Relaxed), 1);
        assert_eq!(cp.stats.registry_epoch.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn remove_tombstones_but_keeps_the_drain_state() {
        let cp = plane(&["a=synth:tiny", "b=synth:tiny:7"]);
        let before = cp.current();
        assert_eq!(cp.apply_line("remove b"), "ok epoch=1 models=1");
        let after = cp.current();
        assert_eq!(after.slots.len(), 2);
        assert!(!after.slots[1].live);
        // the dead slot keeps queue/engine/stats so queued work drains
        assert!(Arc::ptr_eq(&before.slots[1].queue, &after.slots[1].queue));
        assert!(Arc::ptr_eq(&before.slots[1].engine, &after.slots[1].engine));
        assert!(after.registry.get(1).is_none());
        // stats rows are append-only: the dead model stays visible
        assert_eq!(cp.stats.n_models(), 2);
    }

    #[test]
    fn policy_retunes_in_place_and_updates_gauges() {
        let cp = plane(&["a=synth:tiny;weight=2"]);
        let before = cp.current();
        assert_eq!(cp.apply_line("policy a weight=5 slo_us=9000"), "ok epoch=1 models=1");
        let after = cp.current();
        assert!(Arc::ptr_eq(&before.slots[0].queue, &after.slots[0].queue));
        assert_eq!(after.slots[0].policy.weight, 5);
        assert_eq!(after.slots[0].policy.slo_us, Some(9000));
        let s = cp.stats.model(0).unwrap();
        assert_eq!(s.weight.load(Ordering::Relaxed), 5);
        assert_eq!(s.slo_us.load(Ordering::Relaxed), 9000);
        assert_eq!(s.effective_weight_milli.load(Ordering::Relaxed), 5000);
    }

    #[test]
    fn rejected_commands_change_nothing() {
        let cp = plane(&["a=synth:tiny"]);
        for bad in [
            "frobnicate",
            "add",                         // empty spec
            "add a=synth:tiny",            // duplicate live name
            "add m:nearest:W32A32",        // manifest source
            "add b=synth:tiny;weight=0",   // invalid policy value
            "remove nope",
            "remove a b",
            "policy a",                    // no pairs
            "policy a nope=3",             // unknown key
            "reload now",
        ] {
            let reply = cp.apply_line(bad);
            assert!(reply.starts_with(ADMIN_ERR), "{bad:?} -> {reply}");
        }
        assert_eq!(cp.epoch(), 0);
        assert_eq!(cp.current().slots.len(), 1);
        assert_eq!(cp.stats.n_models(), 1);
        assert_eq!(cp.stats.reloads.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn reload_bumps_the_epoch_and_wakes_the_scheduler() {
        let cp = plane(&["a=synth:tiny"]);
        let bell_before = cp.doorbell.epoch();
        assert_eq!(cp.apply_line("reload"), "ok epoch=1 models=1");
        assert_eq!(cp.epoch(), 1);
        assert!(cp.doorbell.epoch() > bell_before);
        // removing the last live model is refused at the registry
        let reply = cp.apply_line("remove a");
        assert!(reply.starts_with(ADMIN_ERR), "{reply}");
    }

    #[test]
    fn readd_after_remove_gets_a_new_id() {
        let cp = plane(&["a=synth:tiny", "b=synth:tiny:7"]);
        assert_eq!(cp.apply_line("remove b"), "ok epoch=1 models=1");
        assert_eq!(cp.apply_line("add b=synth:tiny:8"), "ok epoch=2 models=2");
        let cur = cp.current();
        assert_eq!(cur.slots.len(), 3);
        assert!(!cur.slots[1].live);
        assert!(cur.slots[2].live);
        assert_eq!(cur.registry.id_of("b"), Some(2));
        assert_eq!(cur.registry.get(2).unwrap().added_at_epoch, 2);
    }
}
