//! `aquant` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   info                               manifest / artifact summary
//!   calibrate --model M --method X --bits WaAb [--iters N]
//!   eval      --model M --method X --bits WaAb
//!   exp       <table1|table2|table3|table4|fig1|fig2|fig3|overhead|all>
//!   serve     --model SPEC [--model SPEC ...] [--addr HOST:PORT]
//!             [--workers N] [--max-batch N] [--batch-wait-us N]
//!
//! All subcommands accept --artifacts DIR (default: artifacts).
//!
//! The calibration / evaluation / experiment subcommands execute AOT
//! HLO programs and need the PJRT runtime (`--features pjrt`). Serving
//! synthetic models (`--model synth:...`) is pure Rust and works in
//! every build.

use std::sync::Arc;

use anyhow::{bail, Result};

use aquant::config::{Bits, Method, ModelSpec};
use aquant::nn::registry::ModelRegistry;
use aquant::util::cli::Args;

#[cfg(feature = "pjrt")]
use aquant::config::RunConfig;
#[cfg(feature = "pjrt")]
use aquant::exp::{cell::Ctx, figs, tables};

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "info" => info(&args),
        "calibrate" => calibrate(&args),
        "eval" => eval_cmd(&args),
        "exp" => exp(&args),
        "serve" => serve(&args),
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}; see `aquant help`"),
    }
}

const HELP: &str = "\
aquant — adaptive activation-rounding-border PTQ (AQuant reproduction)

USAGE: aquant <subcommand> [flags]

  info                           artifact / manifest summary
  calibrate --model M --method X --bits WaAb [--iters N]
  eval      --model M --method X --bits WaAb [--iters N]
  exp       <table1|table2|table3|table4|fig1|fig2|fig3|overhead|all>
            [--iters N] [--models a,b] [--table1-limit N]
  serve     --model SPEC [--model SPEC ...] [--method X] [--bits WaAb]
            [--addr H:P] [--iters N] [--workers N|auto] [--max-batch N]
            [--batch-wait-us N] [--queue-images N] [--max-conns N]
            [--conn-timeout-ms N] [--max-accepts N] [--io-poll]
            [--stats-every-s N] [--stats-addr H:P]
            [--stats-history PATH] [--stats-history-every-s N]
            [--admin-addr H:P] [--fast-kernels]
  serve     --route MODEL=H:P [--route MODEL=H:P ...] [--addr H:P]
            [--route-pool N] [--route-inflight N] [--max-conns N]
            [--conn-timeout-ms N] [--max-accepts N] [--io-poll]
            [--stats-every-s N] [--stats-addr H:P]      (router mode)

methods: nearest adaround brecq qdrop aquant aquant-linear aquant-nofusion
bits:    e.g. W4A4, W2A2, W32A2 (32 = full precision)

serve hosts every --model SPEC behind one port and one worker pool
(protocol v2 routes by model id; v1 clients get the first spec):
  SPEC = [NAME=]synth:KIND[:SEED]     synthetic model (tiny|bench|rand),
                                      pure Rust — no artifacts needed
       | [NAME=]MODEL[:METHOD:BITS]   calibrated manifest model; METHOD/
                                      BITS default to --method/--bits
  Either form takes a per-model serving-policy tail `;key=value...`
  (keys: max_batch, batch_wait_us, queue_images, weight, slo_us);
  anything not set inherits the server-level knobs below. weight
  (default 1) is the model's fair share of worker-pool admission when
  several models are backlogged (weighted deficit-round-robin — a
  weight-3 model gets 3 images admitted per 1 of a weight-1 model, so
  a hot model can no longer starve a latency-sensitive one). slo_us
  (default: none) is a p99 end-to-end latency target: while the
  model's observed p99 misses it, the scheduler boosts the model's
  effective weight (never below the static weight, at most 8x, never
  past the weight cap) and decays back once the target is met —
  predictions are bit-identical either way, only admission order moves.
  Quote specs with a policy tail — ';' is a shell separator.
  e.g.  --model 'prod=mobiles:aquant:W4A4;weight=3' \
        --model 'canary=mobiles:qdrop:W4A4;max_batch=8;batch_wait_us=0'
        --model a=synth:tiny --model b=synth:bench

serve knobs: --workers (inference threads shared by all models; auto =
  cores-1), --max-batch (images coalesced per engine batch, default 64),
  --batch-wait-us (per-model straggler deadline once a request is
  pending, default 200), --queue-images (per-model queue bound before
  connections backpressure, default 8192), --stats-every-s (periodic
  stats, default 30, 0 = off), --fast-kernels (opt into the relaxed
  FMA GEMM kernels, same as AQUANT_FAST=fma; faster but outside the
  cross-backend bit-identity contract — results are allclose, not
  bit-identical; off by default)

router mode (mutually exclusive with --model): --route MODEL=HOST:PORT
turns the process into a front-end that forwards framed requests to
backend serving processes over small pools of persistent, pipelined
connections — payload bytes forward as received, no decode/re-encode.
Route order assigns the router-visible model ids (first --route is
id 0, serving v1 clients), and each backend must host the routed
model at that SAME id. A dead backend fails only its own in-flight
requests (clients see a closed connection); other backends keep
serving while the router reconnects with backoff. --route-pool
(connections per backend, default 2, max 64), --route-inflight
(in-flight requests per backend connection before clients
backpressure, default 32, max 4096). /stats gains per-backend
forwarded/answered/failed counts, in-flight gauge, reconnects, and
round-trip quantiles.
  e.g.  aquant serve --route tiny=10.0.0.2:7000 \
                     --route bench=10.0.0.3:7000 --addr 0.0.0.0:7000

connection I/O (one epoll event loop owns every socket — connections
cost state, not threads): --max-conns (concurrent-connection cap;
accepts beyond it are closed immediately and counted; default
unbounded), --conn-timeout-ms (idle/read deadline for connections the
server owes nothing — slow-loris & dead-peer reclamation; default 0 =
never), --max-accepts (accept N connections then drain and exit;
bounded runs for tests/benches; default: run forever), --io-poll
(force the portable poll(2) backend instead of epoll)

observability: --stats-addr H:P binds a read-only stats endpoint on
the same event loop (per-model request/image counters, queue depth,
deficit, and p50/p90/p99 for queue-wait, batch service, and
end-to-end latency); --stats-history PATH appends a JSON-line
snapshot every --stats-history-every-s seconds (default 5) plus one
at shutdown, so perf history survives restarts.
  curl -s http://HOST:PORT/stats | python3 -m json.tool
  curl -s 'http://HOST:PORT/stats?fmt=text'

control plane: --admin-addr H:P binds a line-oriented admin endpoint
on the same event loop for zero-downtime registry swaps. Commands
(one per line, one reply line each, `ok ...` or `err ...`):
  add NAME=synth:KIND[:SEED][;key=value...]   hot-add a model
  remove NAME                                 tombstone a model (new
                                              requests rejected, queued
                                              work drains on the old
                                              engine)
  policy NAME key=value [key=value ...]       retune a live model's
                                              serving policy
  reload                                      bump the registry epoch
In-flight batches always finish on the engine they started on, and
unchanged models' predictions are bit-identical across swaps. Bind it
to localhost: the protocol is unauthenticated by design.
  printf 'add c=synth:tiny:7\\n' | nc HOST PORT
";

#[cfg(feature = "pjrt")]
fn ctx_from(args: &Args) -> Result<Ctx> {
    let dir = args.str_flag("artifacts", "artifacts");
    let iters = match args.str_flag_opt("iters") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    let mut ctx = Ctx::new(&dir, iters)?;
    ctx.verbose = args.bool_flag("verbose");
    Ok(ctx)
}

#[cfg(not(feature = "pjrt"))]
fn needs_pjrt(what: &str) -> Result<()> {
    bail!(
        "`{what}` executes AOT HLO programs and needs the PJRT runtime; \
         rebuild with `--features pjrt` (serving synthetic models with \
         `serve --model synth:...` works in this build)"
    )
}

#[cfg(not(feature = "pjrt"))]
fn info(_args: &Args) -> Result<()> {
    needs_pjrt("info")
}

#[cfg(feature = "pjrt")]
fn info(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let manifest = ctx.rt.manifest().unwrap();
    println!("platform: {}", ctx.rt.platform());
    println!("producer: {}", manifest.producer);
    println!("programs: {}", manifest.programs.len());
    println!(
        "dataset: train {} / calib {} / test {} ({} classes, {}x{}x{})",
        ctx.dataset.train.n,
        ctx.dataset.calib.n,
        ctx.dataset.test.n,
        ctx.dataset.n_classes,
        ctx.dataset.test.c,
        ctx.dataset.test.h,
        ctx.dataset.test.w,
    );
    for model in ctx.models() {
        let topo = ctx.topo(&model)?;
        let n_params: usize = topo.all_layers().iter().map(|l| l.weight_elems()).sum();
        println!(
            "model {model}: {} blocks, {} layers, {} weight params, FP acc {:.2}%",
            topo.blocks.len(),
            topo.all_layers().len(),
            n_params,
            aquant::nn::loader::fp_accuracy(manifest, &model)? * 100.0
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn parse_cell(args: &Args) -> Result<(String, Method, Bits)> {
    Ok((
        args.req_flag("model")?,
        Method::parse(&args.req_flag("method")?)?,
        Bits::parse(&args.req_flag("bits")?)?,
    ))
}

#[cfg(not(feature = "pjrt"))]
fn calibrate(_args: &Args) -> Result<()> {
    needs_pjrt("calibrate")
}

#[cfg(feature = "pjrt")]
fn calibrate(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let (model, method, bits) = parse_cell(args)?;
    let cfg = RunConfig::new(&model, method, bits);
    let t0 = std::time::Instant::now();
    let _st = ctx.calibrated_state(&cfg)?;
    println!(
        "calibrated {} in {:.1}s (state cached under artifacts/qstate/{})",
        cfg.tag(),
        t0.elapsed().as_secs_f64(),
        cfg.tag()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn eval_cmd(_args: &Args) -> Result<()> {
    needs_pjrt("eval")
}

#[cfg(feature = "pjrt")]
fn eval_cmd(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let (model, method, bits) = parse_cell(args)?;
    let fp = ctx.fp_accuracy(&model)?;
    let acc = ctx.run_cell(&model, method, bits)?;
    println!(
        "{model} {} {}: top-1 {:.2}% (FP {:.2}%)",
        method.name(),
        bits.name(),
        acc * 100.0,
        fp * 100.0
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn exp(_args: &Args) -> Result<()> {
    needs_pjrt("exp")
}

#[cfg(feature = "pjrt")]
fn exp(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let models = match args.str_flag_opt("models") {
        Some(m) => m.split(',').map(str::to_string).collect(),
        None => ctx.models(),
    };
    let t1_limit = args.num_flag("table1-limit", 512usize)?;
    let run = |name: &str| -> Result<()> {
        let t0 = std::time::Instant::now();
        match name {
            "table1" => ctx.emit("table1.txt", &tables::table1(&ctx, t1_limit)?)?,
            "table2" => ctx.emit("table2.txt", &tables::table2(&ctx, &models)?)?,
            "table3" => ctx.emit("table3.txt", &tables::table3(&ctx, &models)?)?,
            "table4" => ctx.emit("table4.txt", &tables::table4(&ctx, &models)?)?,
            "fig1" => ctx.emit("fig1.txt", &figs::fig1())?,
            "fig2" => ctx.emit("fig2.txt", &figs::fig2(&ctx, &models[0])?)?,
            "fig3" => ctx.emit("fig3.txt", &figs::fig3(&ctx, &models[0], 4, 20)?)?,
            "overhead" => ctx.emit("overhead.txt", &figs::overhead_table(&ctx)?)?,
            other => bail!("unknown experiment {other:?}"),
        }
        eprintln!("[{name} done in {:.1}s]", t0.elapsed().as_secs_f64());
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig1", "overhead", "fig3", "table1", "fig2", "table2", "table3", "table4",
        ] {
            run(name)?;
        }
    } else {
        run(which)?;
    }
    Ok(())
}

/// Build the registry for `serve`: synthetic specs are pure Rust; the
/// manifest path is build-dependent inside
/// `server::registry_from_specs` (quantized via PJRT with the `pjrt`
/// feature, full-precision `nearest:W32A32` loading otherwise).
fn build_registry(args: &Args, specs: &[ModelSpec]) -> Result<ModelRegistry> {
    let iters = match args.str_flag_opt("iters") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    aquant::server::registry_from_specs(
        specs,
        &args.str_flag("artifacts", "artifacts"),
        iters,
        args.bool_flag("verbose"),
    )
}

fn serve(args: &Args) -> Result<()> {
    let routes = args.multi_flag("route");
    if !routes.is_empty() {
        if !args.multi_flag("model").is_empty() {
            bail!(
                "--route and --model are mutually exclusive: a process either \
                 routes to backends or serves models locally"
            );
        }
        return serve_router(args, routes);
    }
    let default_method = match args.str_flag_opt("method") {
        Some(m) => Some(Method::parse(m)?),
        None => None,
    };
    let default_bits = match args.str_flag_opt("bits") {
        Some(b) => Some(Bits::parse(b)?),
        None => None,
    };
    let specs = ModelSpec::parse_all(args.multi_flag("model"), default_method, default_bits)?;
    let addr = args.str_flag("addr", "127.0.0.1:7000");
    let cfg = aquant::config::ServeConfig::from_args(args)?;
    let every = args.num_flag("stats-every-s", 30u64)?;
    let registry = Arc::new(build_registry(args, &specs)?);
    let srv = aquant::server::Server::bind(registry, &addr, cfg)?;
    let stats = srv.stats();
    if every > 0 {
        // A long-lived server never returns from run(); the live stats
        // handle is the only way to observe it.
        let s = stats.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(every));
            println!("{}", s.report());
        });
    }
    srv.run()?;
    // reached only for bounded runs (--max-conns)
    println!("{}", stats.report());
    Ok(())
}

/// Router mode: no local models, no registry — forward framed requests
/// to the backends named by the `--route` table.
fn serve_router(args: &Args, routes: &[String]) -> Result<()> {
    let routes = aquant::config::RouteSpec::parse_all(routes)?;
    let addr = args.str_flag("addr", "127.0.0.1:7000");
    let cfg = aquant::config::ServeConfig::from_args(args)?;
    let every = args.num_flag("stats-every-s", 30u64)?;
    let srv = aquant::server::RouterServer::bind(routes, &addr, cfg)?;
    let stats = srv.stats();
    if every > 0 {
        let s = stats.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(every));
            println!("{}", s.report());
        });
    }
    srv.run()?;
    // reached only for bounded runs (--max-accepts)
    println!("{}", stats.report());
    Ok(())
}
