//! Minimal CLI argument parsing (clap is unavailable offline).
//!
//! Supports `aquant <subcommand> [--flag value] [--bool-flag] positional...`.
//! Flags may repeat (`--model a --model b`): every occurrence is kept in
//! order. Scalar accessors read the **last** occurrence (so a repeated
//! scalar flag behaves like "last one wins"); [`Args::multi_flag`]
//! returns them all (multi-model serving routes on this).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: subcommand, flags (every occurrence, in order),
/// positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut flags: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare -- not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    flags.entry(k.to_string()).or_default().push(v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags
                        .entry(name.to_string())
                        .or_default()
                        .push(it.next().unwrap());
                } else {
                    flags
                        .entry(name.to_string())
                        .or_default()
                        .push("true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Args {
            subcommand,
            flags,
            positional,
        })
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// String flag with default (last occurrence wins).
    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.str_flag_opt(name)
            .map(str::to_string)
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string flag (None when absent) — lets callers tell
    /// "flag omitted" apart from "flag set to the default's value".
    pub fn str_flag_opt(&self, name: &str) -> Option<&str> {
        self.flags
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of a repeated flag, in command-line order
    /// (empty slice when absent).
    pub fn multi_flag(&self, name: &str) -> &[String] {
        self.flags.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Required string flag.
    pub fn req_flag(&self, name: &str) -> Result<String> {
        self.str_flag_opt(name)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    /// Numeric flag with default.
    pub fn num_flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.str_flag_opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{name}={v} is not a valid number")),
        }
    }

    /// Boolean flag (present or explicit true/false).
    pub fn bool_flag(&self, name: &str) -> bool {
        matches!(self.str_flag_opt(name), Some("true") | Some("1"))
    }
}

/// Split one `key=value` token (both sides non-empty). Used by the
/// `;key=value` policy tails of `--model` specs, kept here so every
/// key/value mini-grammar in the CLI reports the same shape of error.
pub fn split_kv(pair: &str) -> Result<(&str, &str)> {
    match pair.split_once('=') {
        Some((k, v)) if !k.is_empty() && !v.is_empty() => Ok((k, v)),
        _ => bail!("expected key=value, got {pair:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_subcommand_flags_positionals() {
        // NOTE: a bare `--flag` greedily consumes a following non-flag token
        // as its value, so boolean flags go last or use `--flag=true`.
        let a = Args::parse(v(&[
            "calibrate",
            "extra",
            "--model",
            "resnet10s",
            "--bits=2",
            "--verbose",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, "calibrate");
        assert_eq!(a.str_flag("model", ""), "resnet10s");
        assert_eq!(a.num_flag::<u32>("bits", 0).unwrap(), 2);
        assert!(a.bool_flag("verbose"));
        assert!(!a.bool_flag("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
        assert_eq!(a.str_flag_opt("model"), Some("resnet10s"));
        assert_eq!(a.str_flag_opt("workers"), None);
    }

    #[test]
    fn repeated_flags_keep_every_occurrence_in_order() {
        let a = Args::parse(v(&[
            "serve",
            "--model",
            "a=synth:tiny",
            "--workers",
            "2",
            "--model=b=synth:bench",
            "--model",
            "c",
        ]))
        .unwrap();
        assert_eq!(a.multi_flag("model"), &["a=synth:tiny", "b=synth:bench", "c"]);
        // scalar accessors see the last occurrence
        assert_eq!(a.str_flag_opt("model"), Some("c"));
        assert_eq!(a.req_flag("model").unwrap(), "c");
        // absent flag: empty slice, no panic
        assert!(a.multi_flag("nope").is_empty());
        assert_eq!(a.num_flag::<usize>("workers", 0).unwrap(), 2);
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = Args::parse(v(&["eval"])).unwrap();
        assert!(a.req_flag("model").is_err());
        assert_eq!(a.num_flag("iters", 7u32).unwrap(), 7);
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(v(&["x", "--n", "abc"])).unwrap();
        assert!(a.num_flag::<u32>("n", 0).is_err());
    }

    #[test]
    fn split_kv_accepts_pairs_and_rejects_malformed() {
        assert_eq!(split_kv("weight=3").unwrap(), ("weight", "3"));
        // value may itself contain '=' (split at the first one)
        assert_eq!(split_kv("k=a=b").unwrap(), ("k", "a=b"));
        assert!(split_kv("weight").is_err());
        assert!(split_kv("=3").is_err());
        assert!(split_kv("weight=").is_err());
        assert!(split_kv("").is_err());
    }
}
