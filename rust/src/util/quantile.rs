//! Quantile estimation helpers for the serving-metrics tier.
//!
//! Two estimators live here, both dependency-free:
//!
//! - [`bucket_quantile`] reads the log2-bucketed histograms the server
//!   records per model (`server::metrics::LatencyHist`): bucket `i`
//!   counts observations in `[2^i, 2^(i+1))` microseconds (bucket 0
//!   additionally absorbs 0), and the estimator interpolates linearly
//!   *within* the winning bucket. Relative error is therefore bounded
//!   by the bucket width (< 2x, typically much tighter after
//!   interpolation) — the right trade for lock-free atomic recording
//!   on the serving path.
//! - [`quantile_sorted`] is the exact linear-interpolation quantile
//!   over an already-sorted sample slice, for offline tooling and for
//!   cross-checking the bucket estimator in tests.
//!
//! Both return `None` on empty input rather than inventing a number;
//! callers render that as an explicit gap ("-") instead of a fake 0.

/// Estimate the `q`-quantile (0.0..=1.0) from log2 bucket counts:
/// `counts[i]` is the number of observations in `[2^i, 2^(i+1))`
/// (with bucket 0 covering `[0, 2)`). Linear interpolation inside the
/// winning bucket; the result is monotone non-decreasing in `q`, so
/// p50 <= p90 <= p99 holds by construction.
pub fn bucket_quantile(counts: &[u64], q: f64) -> Option<f64> {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    let q = q.clamp(0.0, 1.0);
    // rank in 1..=total: the observation index the quantile names
    let rank = (q * total as f64).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= rank {
            let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
            let hi = (1u64 << (i + 1).min(63)) as f64;
            // fraction of the way through this bucket's observations
            let frac = (rank - cum) as f64 / c as f64;
            return Some(lo + frac * (hi - lo));
        }
        cum += c;
    }
    // unreachable while total > 0, but stay total-panic-free
    None
}

/// Exact `q`-quantile of a sorted slice via linear interpolation
/// between the two straddling order statistics (the "R-7" definition
/// numpy defaults to). `None` on an empty slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return Some(sorted[lo]);
    }
    let frac = pos - lo as f64;
    Some(sorted[lo] + frac * (sorted[hi] - sorted[lo]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_inputs_yield_none() {
        assert_eq!(bucket_quantile(&[], 0.5), None);
        assert_eq!(bucket_quantile(&[0, 0, 0], 0.99), None);
        assert_eq!(quantile_sorted(&[], 0.5), None);
    }

    #[test]
    fn single_observation_is_every_quantile() {
        // one observation in bucket 3 -> every quantile lands in [8, 16)
        let mut counts = [0u64; 8];
        counts[3] = 1;
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            let v = bucket_quantile(&counts, q).unwrap();
            assert!((8.0..=16.0).contains(&v), "q={q} -> {v}");
        }
        assert_eq!(quantile_sorted(&[42.0], 0.99), Some(42.0));
    }

    #[test]
    fn bucket_quantiles_are_monotone_in_q() {
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let counts: Vec<u64> = (0..20).map(|_| rng.next_u64() % 100).collect();
            if counts.iter().all(|&c| c == 0) {
                continue;
            }
            let mut prev = f64::MIN;
            for pct in 0..=100 {
                let v = bucket_quantile(&counts, pct as f64 / 100.0).unwrap();
                assert!(v >= prev, "quantile dipped at p{pct}: {v} < {prev}");
                prev = v;
            }
        }
    }

    #[test]
    fn bucket_estimate_brackets_the_exact_quantile() {
        // bucket the samples, then check the estimator stays within the
        // winning bucket's bounds of the exact sample quantile
        let mut rng = Rng::new(11);
        let mut samples: Vec<f64> = (0..500)
            .map(|_| (1 + rng.next_u64() % 100_000) as f64)
            .collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut counts = [0u64; 32];
        for &s in &samples {
            let b = (63 - (s as u64).max(1).leading_zeros()).min(31) as usize;
            counts[b] += 1;
        }
        for q in [0.5, 0.9, 0.99] {
            let est = bucket_quantile(&counts, q).unwrap();
            let exact = quantile_sorted(&samples, q).unwrap();
            // same bucket => within one power of two of each other
            assert!(
                est <= exact * 2.0 + 2.0 && exact <= est * 2.0 + 2.0,
                "q={q}: estimate {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn sorted_quantile_interpolates() {
        let v = [0.0, 10.0, 20.0, 30.0, 40.0];
        assert_eq!(quantile_sorted(&v, 0.0), Some(0.0));
        assert_eq!(quantile_sorted(&v, 1.0), Some(40.0));
        assert_eq!(quantile_sorted(&v, 0.5), Some(20.0));
        assert_eq!(quantile_sorted(&v, 0.25), Some(10.0));
        // between order statistics: 0.6 * 4 = 2.4 -> 20 + 0.4 * 10
        assert!((quantile_sorted(&v, 0.6).unwrap() - 24.0).abs() < 1e-9);
    }
}
