//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases and reports the seed of
//! the first failing case so it can be replayed deterministically.

use super::rng::Rng;

/// Number of cases per property (override with `AQUANT_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("AQUANT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(256)
}

/// Run `prop(rng)` over `cases` deterministic seeds; panic with the failing
/// seed on the first failure. `prop` should panic (assert!) on violation.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0xA0_5EED ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default case count.
pub fn check_default<F: FnMut(&mut Rng)>(name: &str, prop: F) {
    check(name, default_cases(), prop);
}

/// Generate a random tensor of len `n` with values in [lo, hi).
pub fn vec_f32(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 64, |rng| {
            let a = rng.f32();
            let b = rng.f32();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check("always-false", 4, |_| {
            assert!(false, "boom");
        });
    }
}
