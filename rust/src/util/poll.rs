//! Minimal readiness-polling wrapper: epoll on Linux with a portable
//! `poll(2)` fallback, plus a self-pipe [`Waker`] for cross-thread
//! wakeups. mio is unavailable offline, so the syscalls are declared
//! directly against the system C library (std already links it) —
//! nothing here adds a dependency.
//!
//! Scope is exactly what the serving event loop
//! ([`crate::server::conn`]) needs:
//!
//! * **level-triggered** readiness (both backends — epoll is used
//!   without `EPOLLET`, and `poll(2)` is level-triggered by nature), so
//!   the loop may do partial reads/writes and simply wait again;
//! * per-fd read/write [`Interest`] that can be changed on the fly
//!   (connections toggle write interest as their output buffer fills
//!   and drains, and drop read interest while parked on a full queue —
//!   that is what turns a full [`crate::server::sched::BatchQueue`]
//!   into plain TCP backpressure);
//! * a [`Waker`] other threads can ring to interrupt a blocked
//!   [`Poller::wait`] (pool completions ring it so responses flush).
//!
//! On Linux [`Poller::new`] picks epoll; [`Poller::with_poll_backend`]
//! forces the portable backend so the fallback is exercised by tests on
//! the same host. Both backends present identical semantics, pinned by
//! the unit tests below.

use std::collections::BTreeMap;
use std::io;
use std::os::fd::{FromRawFd, OwnedFd};
use std::os::raw::{c_int, c_void};
use std::os::unix::io::{AsRawFd, RawFd};
use std::time::Duration;

/// Which readiness a registered fd is watched for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    pub readable: bool,
    pub writable: bool,
}

impl Interest {
    pub const READ: Interest = Interest {
        readable: true,
        writable: false,
    };
    pub const WRITE: Interest = Interest {
        readable: false,
        writable: true,
    };
    pub const BOTH: Interest = Interest {
        readable: true,
        writable: true,
    };
}

/// One readiness event. `error`/`hangup` are reported even when not
/// asked for (as the OS does); the loop treats them as "attend to this
/// fd now" — the subsequent read/write surfaces the actual error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub error: bool,
    pub hangup: bool,
}

// ---------------------------------------------------------------------
// libc declarations (shared)
// ---------------------------------------------------------------------

extern "C" {
    fn pipe(fds: *mut c_int) -> c_int;
    fn fcntl(fd: c_int, cmd: c_int, arg: c_int) -> c_int;
    fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
    fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
}

const F_GETFL: c_int = 3;
const F_SETFL: c_int = 4;
#[cfg(target_os = "linux")]
const O_NONBLOCK: c_int = 0o4000;
#[cfg(not(target_os = "linux"))]
const O_NONBLOCK: c_int = 0x4;

fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    // SAFETY: plain fcntl on a valid fd; no memory is passed.
    unsafe {
        let flags = fcntl(fd, F_GETFL, 0);
        if flags < 0 {
            return Err(io::Error::last_os_error());
        }
        if fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
            return Err(io::Error::last_os_error());
        }
    }
    Ok(())
}

fn duration_to_ms(timeout: Option<Duration>) -> c_int {
    match timeout {
        // -1 = block forever (both epoll_wait and poll)
        None => -1,
        // round UP so a 100µs deadline cannot spin at timeout 0; clamp
        // into c_int range (~24 days — any longer blocks in slices)
        Some(d) => {
            let ms = d.as_millis().saturating_add(u128::from(d.subsec_nanos() % 1_000_000 != 0));
            ms.min(c_int::MAX as u128) as c_int
        }
    }
}

// ---------------------------------------------------------------------
// epoll backend (Linux)
// ---------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll {
    use super::*;

    // x86-64 is the one ABI where the kernel's epoll_event is packed.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    // Pin the kernel ABI at compile time: packed 12/1 on x86-64,
    // natural 16/8 everywhere else (incl. aarch64, where the u64 pads
    // `events` to an 8-byte boundary). A layout drift here corrupts
    // every readiness token the kernel hands back.
    const _: () = {
        let (size, align) = if cfg!(target_arch = "x86_64") {
            (12, 1)
        } else {
            (16, 8)
        };
        assert!(std::mem::size_of::<EpollEvent>() == size);
        assert!(std::mem::align_of::<EpollEvent>() == align);
    };

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
    }

    pub const EPOLLIN: u32 = 0x1;
    pub const EPOLLOUT: u32 = 0x4;
    pub const EPOLLERR: u32 = 0x8;
    pub const EPOLLHUP: u32 = 0x10;
    pub const EPOLLRDHUP: u32 = 0x2000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0x80000;

    pub struct Epoll {
        epfd: OwnedFd,
        /// Scratch reused across waits (epoll reports into it).
        buf: Vec<EpollEvent>,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            // SAFETY: epoll_create1 returns a fresh fd we then own.
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll {
                // SAFETY: fd is valid and owned by no one else.
                epfd: unsafe { OwnedFd::from_raw_fd(fd) },
                buf: vec![EpollEvent { events: 0, data: 0 }; 256],
            })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: {
                    let mut e = 0;
                    if interest.readable {
                        // RDHUP rides with read interest only: a parked
                        // connection (read interest off) must not be
                        // woken — and level-triggered, re-woken forever
                        // — by a half-close it isn't ready to act on.
                        e |= EPOLLIN | EPOLLRDHUP;
                    }
                    if interest.writable {
                        e |= EPOLLOUT;
                    }
                    e
                },
                data: token,
            };
            // SAFETY: ev lives across the call; fds are caller-valid.
            if unsafe { epoll_ctl(self.epfd.as_raw_fd(), op, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            let mut ev = EpollEvent { events: 0, data: 0 };
            // SAFETY: pre-2.6.9 kernels want a non-null event even for DEL.
            if unsafe { epoll_ctl(self.epfd.as_raw_fd(), EPOLL_CTL_DEL, fd, &mut ev) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            let n = loop {
                // SAFETY: buf is a live, writable array of buf.len() events.
                let r = unsafe {
                    epoll_wait(
                        self.epfd.as_raw_fd(),
                        self.buf.as_mut_ptr(),
                        self.buf.len() as c_int,
                        duration_to_ms(timeout),
                    )
                };
                if r >= 0 {
                    break r as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
                // EINTR: retry. (A signal may shorten the effective
                // timeout; the event loop re-derives deadlines anyway.)
            };
            for ev in &self.buf[..n] {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLRDHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    error: bits & EPOLLERR != 0,
                    // full close only; a read-side half-close shows up
                    // as readable (EOF), same as the poll(2) backend
                    hangup: bits & EPOLLHUP != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// poll(2) backend (portable fallback; also compiled on Linux for tests)
// ---------------------------------------------------------------------

mod pollfb {
    use super::*;

    #[derive(Clone, Copy)]
    #[repr(C)]
    pub struct PollFd {
        pub fd: c_int,
        pub events: i16,
        pub revents: i16,
    }

    #[cfg(target_os = "linux")]
    type Nfds = std::os::raw::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type Nfds = std::os::raw::c_uint;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: Nfds, timeout_ms: c_int) -> c_int;
    }

    pub const POLLIN: i16 = 0x1;
    pub const POLLOUT: i16 = 0x4;
    pub const POLLERR: i16 = 0x8;
    pub const POLLHUP: i16 = 0x10;
    const POLLNVAL: i16 = 0x20;

    /// Registration table + a pollfd array rebuilt per wait. O(n) per
    /// call — the portability floor, fine at fallback scale.
    pub struct PollVec {
        regs: BTreeMap<RawFd, (u64, Interest)>,
        fds: Vec<PollFd>,
    }

    impl PollVec {
        pub fn new() -> PollVec {
            PollVec {
                regs: BTreeMap::new(),
                fds: Vec::new(),
            }
        }

        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            if self.regs.insert(fd, (token, interest)).is_some() {
                return Err(io::Error::new(
                    io::ErrorKind::AlreadyExists,
                    "fd already registered",
                ));
            }
            Ok(())
        }

        pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            match self.regs.get_mut(&fd) {
                Some(slot) => {
                    *slot = (token, interest);
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            match self.regs.remove(&fd) {
                Some(_) => Ok(()),
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
            self.fds.clear();
            for (&fd, &(_, interest)) in &self.regs {
                let mut events = 0i16;
                if interest.readable {
                    events |= POLLIN;
                }
                if interest.writable {
                    events |= POLLOUT;
                }
                self.fds.push(PollFd {
                    fd,
                    events,
                    revents: 0,
                });
            }
            let n = loop {
                // SAFETY: fds is a live, writable array of fds.len() entries.
                let r = unsafe {
                    poll(
                        self.fds.as_mut_ptr(),
                        self.fds.len() as Nfds,
                        duration_to_ms(timeout),
                    )
                };
                if r >= 0 {
                    break r as usize;
                }
                let err = io::Error::last_os_error();
                if err.kind() != io::ErrorKind::Interrupted {
                    return Err(err);
                }
            };
            if n == 0 {
                return Ok(()); // timeout
            }
            for pfd in &self.fds {
                if pfd.revents == 0 {
                    continue;
                }
                let (token, _) = self.regs[&pfd.fd];
                out.push(Event {
                    token,
                    readable: pfd.revents & (POLLIN | POLLHUP) != 0,
                    writable: pfd.revents & POLLOUT != 0,
                    error: pfd.revents & (POLLERR | POLLNVAL) != 0,
                    hangup: pfd.revents & POLLHUP != 0,
                });
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// Poller facade
// ---------------------------------------------------------------------

enum Backend {
    #[cfg(target_os = "linux")]
    Epoll(epoll::Epoll),
    Poll(pollfb::PollVec),
}

/// Readiness poller over one of the OS backends. All methods take
/// `&mut self`: the event loop is single-threaded by design and other
/// threads interact only through a [`Waker`].
pub struct Poller {
    backend: Backend,
}

impl Poller {
    /// Platform-best backend: epoll on Linux, `poll(2)` elsewhere.
    pub fn new() -> io::Result<Poller> {
        #[cfg(target_os = "linux")]
        {
            Ok(Poller {
                backend: Backend::Epoll(epoll::Epoll::new()?),
            })
        }
        #[cfg(not(target_os = "linux"))]
        {
            Self::with_poll_backend()
        }
    }

    /// Force the portable `poll(2)` backend (lets Linux tests exercise
    /// the fallback the other platforms run on).
    pub fn with_poll_backend() -> io::Result<Poller> {
        Ok(Poller {
            backend: Backend::Poll(pollfb::PollVec::new()),
        })
    }

    /// Backend name, for startup logging.
    pub fn backend_name(&self) -> &'static str {
        match &self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(_) => "epoll",
            Backend::Poll(_) => "poll",
        }
    }

    /// Watch `fd` (must already be non-blocking) under `token`. The fd
    /// must stay open until [`Poller::deregister`].
    pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.register(fd, token, interest),
            Backend::Poll(p) => p.register(fd, token, interest),
        }
    }

    /// Change an existing registration's token/interest.
    pub fn modify(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.modify(fd, token, interest),
            Backend::Poll(p) => p.modify(fd, token, interest),
        }
    }

    /// Stop watching `fd`. Call BEFORE closing the fd (a closed fd is
    /// auto-removed by epoll but turns into POLLNVAL under poll).
    pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.deregister(fd),
            Backend::Poll(p) => p.deregister(fd),
        }
    }

    /// Block until at least one registered fd is ready or `timeout`
    /// expires (None = forever), appending readiness to `out` (which is
    /// cleared first). A timeout leaves `out` empty.
    pub fn wait(&mut self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        out.clear();
        match &mut self.backend {
            #[cfg(target_os = "linux")]
            Backend::Epoll(e) => e.wait(out, timeout),
            Backend::Poll(p) => p.wait(out, timeout),
        }
    }
}

// ---------------------------------------------------------------------
// Waker (self-pipe)
// ---------------------------------------------------------------------

/// Cross-thread wakeup for a [`Poller`]: a non-blocking self-pipe. The
/// owning loop registers [`Waker::read_fd`] and calls [`Waker::drain`]
/// when it fires; any thread calls [`Waker::wake`]. Wakes coalesce: a
/// full pipe means a wake is already pending, which is all the loop
/// needs to know (same contract as the scheduler's epoch doorbell).
pub struct Waker {
    read: OwnedFd,
    write: OwnedFd,
}

impl Waker {
    pub fn new() -> io::Result<Waker> {
        let mut fds = [0 as c_int; 2];
        // SAFETY: pipe fills the two-element array on success.
        if unsafe { pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        // SAFETY: both fds are fresh and owned here on out.
        let (read, write) =
            unsafe { (OwnedFd::from_raw_fd(fds[0]), OwnedFd::from_raw_fd(fds[1])) };
        set_nonblocking(read.as_raw_fd())?;
        set_nonblocking(write.as_raw_fd())?;
        Ok(Waker { read, write })
    }

    /// The fd the event loop registers for read interest.
    pub fn read_fd(&self) -> RawFd {
        self.read.as_raw_fd()
    }

    /// Ring the loop. Never blocks: EAGAIN (pipe already full) means a
    /// wake is already pending — success either way. Safe from any
    /// thread and from completion callbacks.
    pub fn wake(&self) {
        let b = [1u8];
        // SAFETY: one byte from a live buffer into an owned fd; short
        // writes and EAGAIN/EINTR are all acceptable outcomes.
        unsafe {
            let _ = write(self.write.as_raw_fd(), b.as_ptr() as *const c_void, 1);
        }
    }

    /// Swallow all pending wake bytes (loop-side, after the fd fires).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            // SAFETY: reads into a live local buffer from an owned fd.
            let n = unsafe {
                read(
                    self.read.as_raw_fd(),
                    buf.as_mut_ptr() as *mut c_void,
                    buf.len(),
                )
            };
            if n <= 0 {
                return; // EAGAIN (drained), EOF, or EINTR — all fine here
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::{TcpListener, TcpStream};
    use std::time::Instant;

    fn pollers() -> Vec<Poller> {
        let mut v = vec![Poller::with_poll_backend().unwrap()];
        if cfg!(target_os = "linux") {
            v.push(Poller::new().unwrap());
        }
        v
    }

    #[test]
    fn timeout_expires_with_no_events() {
        for mut p in pollers() {
            let name = p.backend_name();
            let w = Waker::new().unwrap();
            p.register(w.read_fd(), 1, Interest::READ).unwrap();
            let mut out = Vec::new();
            let t0 = Instant::now();
            p.wait(&mut out, Some(Duration::from_millis(30))).unwrap();
            assert!(out.is_empty(), "{name}: {out:?}");
            assert!(t0.elapsed() >= Duration::from_millis(25), "{name}");
        }
    }

    #[test]
    fn waker_wakes_and_coalesces() {
        for mut p in pollers() {
            let name = p.backend_name();
            let w = std::sync::Arc::new(Waker::new().unwrap());
            p.register(w.read_fd(), 7, Interest::READ).unwrap();
            // many wakes, from another thread, before the wait
            let w2 = w.clone();
            std::thread::spawn(move || {
                for _ in 0..1000 {
                    w2.wake();
                }
            })
            .join()
            .unwrap();
            let mut out = Vec::new();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(out.len(), 1, "{name}");
            assert_eq!(out[0].token, 7, "{name}");
            assert!(out[0].readable, "{name}");
            w.drain();
            // drained: the next wait times out
            p.wait(&mut out, Some(Duration::from_millis(20))).unwrap();
            assert!(out.is_empty(), "{name}: wake bytes survived drain");
            // and a post-drain wake still fires
            w.wake();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert_eq!(out.len(), 1, "{name}");
        }
    }

    #[test]
    fn socket_readable_writable_and_interest_changes() {
        for mut p in pollers() {
            let name = p.backend_name();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            let (server, _) = listener.accept().unwrap();
            server.set_nonblocking(true).unwrap();
            let fd = server.as_raw_fd();

            // write-interest on a fresh socket: instantly writable
            p.register(fd, 3, Interest::BOTH).unwrap();
            let mut out = Vec::new();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(out.iter().any(|e| e.token == 3 && e.writable), "{name}: {out:?}");
            assert!(!out.iter().any(|e| e.readable), "{name}: nothing sent yet");

            // read interest only: no spurious writable, readable on data
            p.modify(fd, 3, Interest::READ).unwrap();
            client.write_all(b"hi").unwrap();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(out.iter().any(|e| e.token == 3 && e.readable), "{name}: {out:?}");
            assert!(!out.iter().any(|e| e.writable), "{name}: {out:?}");
            // level-triggered: unread data keeps reporting
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(out.iter().any(|e| e.token == 3 && e.readable), "{name}");
            let mut buf = [0u8; 8];
            let mut sref = &server;
            assert_eq!(sref.read(&mut buf).unwrap(), 2);

            // peer close: readable (EOF) and hangup-ish
            drop(client);
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            let ev = out.iter().find(|e| e.token == 3).expect("close event");
            assert!(ev.readable || ev.hangup, "{name}: {ev:?}");
            assert_eq!(sref.read(&mut buf).unwrap(), 0, "{name}: EOF");

            p.deregister(fd).unwrap();
            p.register(fd, 9, Interest::READ).unwrap(); // re-register works
            p.deregister(fd).unwrap();
        }
    }

    #[test]
    fn listener_accept_readiness() {
        for mut p in pollers() {
            let name = p.backend_name();
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            listener.set_nonblocking(true).unwrap();
            p.register(listener.as_raw_fd(), 0, Interest::READ).unwrap();
            let mut out = Vec::new();
            p.wait(&mut out, Some(Duration::from_millis(20))).unwrap();
            assert!(out.is_empty(), "{name}: no pending connection yet");
            let _c = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
            p.wait(&mut out, Some(Duration::from_secs(5))).unwrap();
            assert!(
                out.iter().any(|e| e.token == 0 && e.readable),
                "{name}: {out:?}"
            );
            let (s, _) = listener.accept().unwrap();
            drop(s);
        }
    }

    #[test]
    fn duration_rounds_up_not_to_zero() {
        assert_eq!(duration_to_ms(None), -1);
        assert_eq!(duration_to_ms(Some(Duration::from_millis(5))), 5);
        // sub-millisecond deadlines must not become a busy-spin 0
        assert_eq!(duration_to_ms(Some(Duration::from_micros(100))), 1);
        assert_eq!(duration_to_ms(Some(Duration::ZERO)), 0);
    }
}
