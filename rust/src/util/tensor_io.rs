//! Raw tensor I/O: the interchange format between `python/compile` and the
//! Rust side for weights, quant-state init, and the dataset.
//!
//! Format: little-endian flat array, no header; shape and dtype live in the
//! manifest (`meta` sections). Python writes with `ndarray.tofile()`.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a whole file of little-endian f32.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let mut buf = Vec::new();
    File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut buf)?;
    if buf.len() % 4 != 0 {
        bail!("{path:?}: size {} not a multiple of 4", buf.len());
    }
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a whole file of little-endian u32.
pub fn read_u32(path: &Path) -> Result<Vec<u32>> {
    let mut buf = Vec::new();
    File::open(path)
        .with_context(|| format!("opening {path:?}"))?
        .read_to_end(&mut buf)?;
    if buf.len() % 4 != 0 {
        bail!("{path:?}: size {} not a multiple of 4", buf.len());
    }
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write little-endian f32 (used to persist learned quant state).
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(path).with_context(|| format!("creating {path:?}"))?);
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read exactly `n` f32 elements, erroring on mismatch.
pub fn read_f32_exact(path: &Path, n: usize) -> Result<Vec<f32>> {
    let v = read_f32(path)?;
    if v.len() != n {
        bail!("{path:?}: expected {n} f32 elems, found {}", v.len());
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("aquant_tensor_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        write_f32(&p, &data).unwrap();
        assert_eq!(read_f32(&p).unwrap(), data);
        assert_eq!(read_f32_exact(&p, 4).unwrap(), data);
        assert!(read_f32_exact(&p, 5).is_err());
        std::fs::remove_file(&p).unwrap();
    }
}
