//! Deterministic RNG (SplitMix64 + xoshiro256**) used by the coordinator
//! (QDrop masks, data shuffling) and the property-test harness.
//!
//! Not cryptographic; chosen for reproducibility across runs and platforms.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.f32() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }
}
