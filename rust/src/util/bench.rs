//! Micro-bench harness (criterion is unavailable offline).
//!
//! Warms up, then runs timed iterations until a wall-clock budget is spent,
//! and reports min / median / p95 / mean per-iteration times. Used by the
//! `cargo bench` targets (harness = false) and by `aquant exp fig3`.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub min: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub mean: Duration,
}

impl BenchResult {
    /// Median in milliseconds.
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10} iters  min {:>9.3?}  med {:>9.3?}  p95 {:>9.3?}  mean {:>9.3?}",
            self.name, self.iters, self.min, self.median, self.p95, self.mean
        )
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then measured runs until
/// `budget` elapses (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: 3 runs or 10% of budget, whichever first.
    let warm_deadline = Instant::now() + budget / 10;
    for _ in 0..3 {
        f();
        if Instant::now() > warm_deadline {
            break;
        }
    }
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 10 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        min: samples[0],
        median: samples[n / 2],
        p95: samples[((n as f64 * 0.95) as usize).min(n - 1)],
        mean,
    }
}

/// Default per-benchmark budget (override with `AQUANT_BENCH_MS`).
pub fn default_budget() -> Duration {
    let ms = std::env::var("AQUANT_BENCH_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(700u64);
    Duration::from_millis(ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.min <= r.median && r.median <= r.p95);
    }
}
