//! Minimal data-parallel helpers over std::thread (rayon is unavailable
//! offline). Used by the eval harness to fan batches across cores.

/// Map `f` over `items` with up to `threads` worker threads, preserving
/// order. `f` must be `Sync`; items are processed by index.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads == 1 || items.len() <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let out_ptr = std::sync::Mutex::new(&mut out);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                out_ptr.lock().unwrap()[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .saturating_sub(1)
        .max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(&items, 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_single_item() {
        assert_eq!(par_map(&[5], 8, |x| x + 1), vec![6]);
        let empty: Vec<i32> = vec![];
        assert!(par_map(&empty, 8, |x| x + 1).is_empty());
    }
}
