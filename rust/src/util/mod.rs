//! In-tree substrate utilities.
//!
//! This build environment is offline with only the `xla` crate closure
//! available, so the pieces a production crate would pull from the
//! ecosystem (serde_json, clap, criterion, proptest, rayon) are
//! implemented here, scoped to what the system needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod poll;
pub mod prop;
pub mod quantile;
pub mod rng;
pub mod tensor_io;
pub mod threadpool;
