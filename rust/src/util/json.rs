//! Minimal JSON parser / serializer for the artifact manifest and config
//! files. Supports the full JSON grammar; numbers are f64 (the manifest
//! only carries shapes and names, well within f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup, erroring with the key name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]`.
    pub fn as_i64_vec(&self) -> Result<Vec<i64>> {
        let arr = self.as_arr().ok_or_else(|| anyhow!("expected array"))?;
        arr.iter()
            .map(|v| v.as_i64().ok_or_else(|| anyhow!("expected number")))
            .collect()
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected byte {:?} at {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: manifest never emits them, but
                            // handle the common case.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    s.push(char::from_u32(c).ok_or_else(|| anyhow!("bad pair"))?);
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| anyhow!("bad codepoint {cp:#x}"))?,
                                );
                            }
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: re-decode from the byte slice.
                    let start = self.i - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

/// Builder helpers for emitting JSON from Rust (used by the coordinator to
/// persist learned quant state descriptions and experiment reports).
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_i64_vec().unwrap(), vec![1, 2, -3]);
        assert_eq!(j.get("b").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(j.get("c").unwrap().as_bool().unwrap(), true);
        assert_eq!(*j.get("d").unwrap(), Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"programs":{"step":{"args":[{"dtype":"f32","name":"w","shape":[3,2]}],"path":"p.hlo.txt"}},"version":1}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn nested_empty() {
        let j = Json::parse(r#"{"a":{},"b":[],"c":[[]]}"#).unwrap();
        assert!(j.get("a").unwrap().as_obj().unwrap().is_empty());
        assert!(j.get("b").unwrap().as_arr().unwrap().is_empty());
    }
}
