//! Dataset access: the synthetic corpus exported by `python/compile/data.py`
//! (identical bytes on both sides — raw little-endian f32 NCHW images and
//! u32 labels).

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::runtime::Manifest;
use crate::util::tensor_io;

/// One split, images flattened NCHW.
#[derive(Debug, Clone)]
pub struct Split {
    pub images: Vec<f32>,
    pub labels: Vec<u32>,
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Split {
    /// Per-image element count.
    pub fn img_elems(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Borrow image i.
    pub fn image(&self, i: usize) -> &[f32] {
        let e = self.img_elems();
        &self.images[i * e..(i + 1) * e]
    }

    /// Gather a batch of images by indices into a flat buffer.
    pub fn gather(&self, idx: &[usize]) -> Vec<f32> {
        let e = self.img_elems();
        let mut out = Vec::with_capacity(idx.len() * e);
        for &i in idx {
            out.extend_from_slice(self.image(i));
        }
        out
    }
}

/// The three canonical splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub train: Split,
    pub calib: Split,
    pub test: Split,
    pub n_classes: usize,
}

impl Dataset {
    /// Load from the artifacts directory using the manifest's data meta.
    pub fn load(artifacts_dir: &Path, manifest: &Manifest) -> Result<Dataset> {
        let meta = manifest.meta_section("data")?;
        let h = meta.req("h")?.as_usize().ok_or_else(|| anyhow!("h"))?;
        let w = meta.req("w")?.as_usize().ok_or_else(|| anyhow!("w"))?;
        let c = meta.req("c")?.as_usize().ok_or_else(|| anyhow!("c"))?;
        let n_classes = meta
            .req("n_classes")?
            .as_usize()
            .ok_or_else(|| anyhow!("n_classes"))?;
        let splits = meta.req("splits")?;
        let load_split = |name: &str| -> Result<Split> {
            let s = splits.req(name)?;
            let n = s.req("n")?.as_usize().ok_or_else(|| anyhow!("n"))?;
            let images = tensor_io::read_f32_exact(
                &artifacts_dir.join(s.req("images")?.as_str().unwrap()),
                n * c * h * w,
            )?;
            let labels =
                tensor_io::read_u32(&artifacts_dir.join(s.req("labels")?.as_str().unwrap()))?;
            if labels.len() != n {
                return Err(anyhow!("{name}: {} labels for {n} images", labels.len()));
            }
            Ok(Split {
                images,
                labels,
                n,
                c,
                h,
                w,
            })
        };
        Ok(Dataset {
            train: load_split("train")?,
            calib: load_split("calib")?,
            test: load_split("test")?,
            n_classes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_accessors() {
        let s = Split {
            images: (0..2 * 3 * 2 * 2).map(|i| i as f32).collect(),
            labels: vec![1, 2],
            n: 2,
            c: 3,
            h: 2,
            w: 2,
        };
        assert_eq!(s.img_elems(), 12);
        assert_eq!(s.image(1)[0], 12.0);
        let b = s.gather(&[1, 0]);
        assert_eq!(b.len(), 24);
        assert_eq!(b[0], 12.0);
        assert_eq!(b[12], 0.0);
    }
}
