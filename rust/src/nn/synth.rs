//! Synthetic model builders for tests, benches, and examples that must
//! run without AOT artifacts (fresh clone, no `make artifacts`).
//!
//! All builders are deterministic in the passed [`Rng`], so a seed fully
//! pins topology, weights, and border parameters — the property tests
//! rely on this to replay failures.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::nn::engine::{ActQuant, Engine, LayerWeights};
use crate::nn::topology::{BlockTopo, LayerTopo, ModelTopo};
use crate::quant::border::BorderFn;
use crate::util::rng::Rng;

/// Conv layer topo with the usual `pad = k/2` same-ish padding.
pub fn conv_layer(
    name: &str,
    ic: usize,
    oc: usize,
    k: usize,
    stride: usize,
    h: usize,
    w: usize,
    relu: bool,
) -> LayerTopo {
    let pad = k / 2;
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    LayerTopo {
        name: name.into(),
        kind: "conv".into(),
        ic,
        oc,
        k,
        stride,
        pad,
        groups: 1,
        relu,
        gap_input: false,
        rows: ic * k * k,
        in_chw: (ic, h, w),
        out_chw: (oc, ho, wo),
    }
}

/// Global-average-pool + fully-connected head.
pub fn fc_layer(name: &str, ic: usize, n_classes: usize, h: usize, w: usize) -> LayerTopo {
    LayerTopo {
        name: name.into(),
        kind: "fc".into(),
        ic,
        oc: n_classes,
        k: 1,
        stride: 1,
        pad: 0,
        groups: 1,
        relu: false,
        gap_input: true,
        rows: ic,
        in_chw: (ic, h, w),
        out_chw: (n_classes, 1, 1),
    }
}

fn random_layer_weights(rng: &mut Rng, l: &LayerTopo) -> LayerWeights {
    LayerWeights {
        w: (0..l.weight_elems()).map(|_| rng.normal() * 0.3).collect(),
        b: (0..l.oc).map(|_| rng.normal() * 0.1).collect(),
    }
}

/// The fixed 3-block model used across the engine property tests:
/// conv(3->4) / residual conv(4->4) / gap-fc(4->5) on 8x8 inputs.
pub fn tiny_model(rng: &mut Rng) -> (ModelTopo, HashMap<String, LayerWeights>) {
    let l1 = conv_layer("c1", 3, 4, 3, 1, 8, 8, true);
    let l2 = conv_layer("c2", 4, 4, 3, 1, 8, 8, false);
    let fc = fc_layer("fc", 4, 5, 8, 8);
    let mut weights = HashMap::new();
    for l in [&l1, &l2, &fc] {
        weights.insert(l.name.clone(), random_layer_weights(rng, l));
    }
    let topo = ModelTopo {
        name: "tiny".into(),
        in_c: 3,
        in_hw: (8, 8),
        n_classes: 5,
        blocks: vec![
            BlockTopo {
                name: "b0".into(),
                residual: false,
                downsample: None,
                layers: vec![l1],
            },
            BlockTopo {
                name: "b1".into(),
                residual: true,
                downsample: None,
                layers: vec![l2],
            },
            BlockTopo {
                name: "head".into(),
                residual: false,
                downsample: None,
                layers: vec![fc],
            },
        ],
    };
    (topo, weights)
}

/// A random small topology: 1–3 conv blocks (random channels, kernel,
/// stride, 1–2 main layers, optionally residual — identity skip when
/// shapes allow, or a 1×1 downsample projection) and a gap-fc head.
/// Inputs stay tiny (6x6 or 8x8) so property tests can afford hundreds
/// of cases while still covering every engine branch (multi-layer
/// relu-deferral, identity skip, downsample skip).
pub fn random_model(rng: &mut Rng) -> (ModelTopo, HashMap<String, LayerWeights>) {
    let hw = [6, 8][rng.below(2)];
    let in_c = [2, 3, 4][rng.below(3)];
    let (mut c, mut h, mut w) = (in_c, hw, hw);
    let mut blocks = Vec::new();
    let mut weights = HashMap::new();
    let n_blocks = 1 + rng.below(3);
    for bi in 0..n_blocks {
        let oc = [2, 4, 6, 8][rng.below(4)];
        let k = [1, 3][rng.below(2)];
        let stride = if h >= 4 && rng.bernoulli(0.3) { 2 } else { 1 };
        let mut layers = Vec::new();
        let l1 = conv_layer(&format!("b{bi}_c1"), c, oc, k, stride, h, w, true);
        let (_, mut bh, mut bw) = l1.out_chw;
        let mut bc = oc;
        weights.insert(l1.name.clone(), random_layer_weights(rng, &l1));
        layers.push(l1);
        if rng.bernoulli(0.4) {
            // second main layer (stride 1): exercises the mid-block
            // relu / end-of-block relu-deferral distinction
            let oc2 = [2, 4, 6, 8][rng.below(4)];
            let k2 = [1, 3][rng.below(2)];
            let l2 = conv_layer(&format!("b{bi}_c2"), bc, oc2, k2, 1, bh, bw, true);
            (bc, bh, bw) = (oc2, l2.out_chw.1, l2.out_chw.2);
            weights.insert(l2.name.clone(), random_layer_weights(rng, &l2));
            layers.push(l2);
        }
        let shape_preserved = bc == c && bh == h && bw == w;
        let (residual, downsample) = if shape_preserved && rng.bernoulli(0.5) {
            (true, None)
        } else if rng.bernoulli(0.35) {
            // 1x1 skip projection; with pad 0 it lands on the same
            // integer output dims as the k∈{1,3} main path (only the
            // first main layer strides)
            let ds = conv_layer(&format!("b{bi}_ds"), c, bc, 1, stride, h, w, false);
            debug_assert_eq!(ds.out_chw, (bc, bh, bw));
            weights.insert(ds.name.clone(), random_layer_weights(rng, &ds));
            let name = ds.name.clone();
            layers.push(ds);
            (true, Some(name))
        } else {
            (false, None)
        };
        blocks.push(BlockTopo {
            name: format!("b{bi}"),
            residual,
            downsample,
            layers,
        });
        (c, h, w) = (bc, bh, bw);
    }
    let n_classes = 3 + rng.below(3);
    let fc = fc_layer("fc", c, n_classes, h, w);
    weights.insert(fc.name.clone(), random_layer_weights(rng, &fc));
    blocks.push(BlockTopo {
        name: "head".into(),
        residual: false,
        downsample: None,
        layers: vec![fc],
    });
    let topo = ModelTopo {
        name: "synth".into(),
        in_c,
        in_hw: (hw, hw),
        n_classes,
        blocks,
    };
    (topo, weights)
}

/// A heavier stack for throughput benches: 3 convs (3->16->16->16) on
/// 16x16 inputs + fc head, enough arithmetic per image for thread
/// scaling to dominate dispatch overhead.
pub fn bench_model(rng: &mut Rng) -> (ModelTopo, HashMap<String, LayerWeights>) {
    let l1 = conv_layer("c1", 3, 16, 3, 1, 16, 16, true);
    let l2 = conv_layer("c2", 16, 16, 3, 1, 16, 16, true);
    let l3 = conv_layer("c3", 16, 16, 3, 2, 16, 16, true);
    let fc = fc_layer("fc", 16, 10, 8, 8);
    let mut weights = HashMap::new();
    for l in [&l1, &l2, &l3, &fc] {
        weights.insert(l.name.clone(), random_layer_weights(rng, l));
    }
    let blocks = [l1, l2, l3, fc]
        .into_iter()
        .enumerate()
        .map(|(i, l)| BlockTopo {
            name: format!("b{i}"),
            residual: false,
            downsample: None,
            layers: vec![l],
        })
        .collect();
    let topo = ModelTopo {
        name: "synthbench".into(),
        in_c: 3,
        in_hw: (16, 16),
        n_classes: 10,
        blocks,
    };
    (topo, weights)
}

/// Build a served synthetic engine from a `synth:KIND[:SEED]` model
/// spec (see `config::ModelSpec`): deterministic in `seed`, with random
/// learned borders on every layer so the full quantized hot path is
/// what gets served. Distinct seeds give distinct weights/borders, so a
/// multi-model registry of same-kind engines still routes observably.
pub fn engine_from_spec(kind: &str, seed: u64) -> Result<Engine> {
    let mut rng = Rng::new(seed);
    let (mut topo, weights) = match kind {
        "tiny" => tiny_model(&mut rng),
        "bench" => bench_model(&mut rng),
        "rand" => random_model(&mut rng),
        other => bail!("unknown synth model kind {other:?} (want tiny|bench|rand)"),
    };
    topo.name = format!("synth-{kind}-{seed}");
    Ok(engine_with_random_borders(
        &topo, &weights, &mut rng, true, true,
    ))
}

/// Engine with a random learned border on every layer — puts the full
/// border-quantization path (the serving hot loop) under test/bench.
pub fn engine_with_random_borders(
    topo: &ModelTopo,
    weights: &HashMap<String, LayerWeights>,
    rng: &mut Rng,
    fuse_en: bool,
    b2_en: bool,
) -> Engine {
    let mut eng = Engine::new(topo.clone(), weights.clone());
    for l in topo.all_layers() {
        let params: Vec<f32> = (0..l.rows * 4).map(|_| rng.normal() * 0.2).collect();
        eng.set_act_quant(
            &l.name,
            ActQuant::Border {
                border: BorderFn::from_params(params, l.k2(), fuse_en, b2_en)
                    .expect("synth border table is well-formed by construction"),
                s: 0.1,
                qmin: 0.0,
                qmax: 15.0,
            },
        );
    }
    eng
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_model_shapes_chain() {
        let (mut saw_multi, mut saw_ds, mut saw_identity) = (false, false, false);
        for seed in 0..200 {
            let mut rng = Rng::new(seed);
            let (topo, weights) = random_model(&mut rng);
            let mut chw = (topo.in_c, topo.in_hw.0, topo.in_hw.1);
            for b in &topo.blocks {
                let block_in = chw;
                let mut cur = block_in;
                for l in b.main_layers() {
                    assert_eq!(l.in_chw, cur, "layer {} input mismatch", l.name);
                    cur = l.out_chw;
                }
                if let Some(ds) = b.downsample_layer() {
                    assert!(b.residual, "downsample in non-residual block {}", b.name);
                    assert_eq!(ds.in_chw, block_in, "downsample {} input", ds.name);
                    assert_eq!(ds.out_chw, cur, "downsample {} must project to block output", ds.name);
                    saw_ds = true;
                } else if b.residual {
                    assert_eq!(cur, block_in, "identity-skip block {} must preserve shape", b.name);
                    saw_identity = true;
                }
                if b.main_layers().count() > 1 {
                    saw_multi = true;
                }
                for l in &b.layers {
                    assert_eq!(
                        weights[&l.name].w.len(),
                        l.weight_elems(),
                        "layer {} weights",
                        l.name
                    );
                }
                chw = cur;
            }
        }
        // the generator must actually produce every engine branch
        assert!(saw_multi, "no multi-layer block in 200 seeds");
        assert!(saw_ds, "no downsample residual in 200 seeds");
        assert!(saw_identity, "no identity residual in 200 seeds");
    }

    #[test]
    fn random_model_forward_runs() {
        let mut rng = Rng::new(3);
        let (topo, weights) = random_model(&mut rng);
        let elems = topo.in_c * topo.in_hw.0 * topo.in_hw.1;
        let image: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
        let eng = engine_with_random_borders(&topo, &weights, &mut rng, true, true);
        let logits = eng.forward(&image, None).unwrap();
        assert_eq!(logits.len(), topo.n_classes);
    }
}
