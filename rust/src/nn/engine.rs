//! Model execution over the pure-Rust im2col engine.
//!
//! The engine runs a whole model (or a single layer, for profiling) with
//! per-layer activation-quantization hooks. It is used for:
//!   * Table 1 (A-rounding vs nearest, W32A2) — `ActQuant::ARound`,
//!   * Figure 3 (latency breakdown: fused vs unfused border) — `forward_timed`,
//!   * the serving example (quantized inference without PJRT).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::im2col;
use super::topology::{LayerTopo, ModelTopo};
use crate::quant::arounding::around_column;
use crate::quant::border::BorderFn;
use crate::quant::tensor::Tensor;

/// Activation quantization applied to each im2col column of a layer.
#[derive(Debug, Clone)]
pub enum ActQuant {
    /// Full precision.
    None,
    /// Border-function quantization (nearest when params are zero /
    /// border_en = false).
    Border {
        border: BorderFn,
        s: f32,
        qmin: f32,
        qmax: f32,
    },
    /// The SQuant-style flip algorithm (Table 1's A-rounding).
    ARound { s: f32, qmin: f32, qmax: f32 },
}

impl ActQuant {
    fn apply(&self, col: &mut [f32], k2: usize, scratch: &mut Vec<f32>) {
        match self {
            ActQuant::None => {}
            ActQuant::Border {
                border,
                s,
                qmin,
                qmax,
            } => border.quant_column(col, *s, *qmin, *qmax, scratch),
            ActQuant::ARound { s, qmin, qmax } => around_column(col, *s, *qmin, *qmax, k2),
        }
    }
}

/// One layer's (possibly pre-quantized) weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Border-fusion strategy for the conv loop (Figure 3's configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionMode {
    /// Quantize each column inside the im2col gather (hot in cache).
    Fused,
    /// Gather everything, then a second quantization pass over the buffer.
    Unfused,
}

/// The inference engine: topology + weights + per-layer activation quant.
pub struct Engine {
    pub topo: ModelTopo,
    pub weights: HashMap<String, LayerWeights>,
    pub act_quant: HashMap<String, ActQuant>,
    pub fusion: FusionMode,
}

/// Per-layer timing sample from `forward_timed`.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub layer: String,
    pub im2col_quant_us: f64,
    pub gemm_us: f64,
}

impl Engine {
    pub fn new(topo: ModelTopo, weights: HashMap<String, LayerWeights>) -> Self {
        Engine {
            topo,
            weights,
            act_quant: HashMap::new(),
            fusion: FusionMode::Fused,
        }
    }

    /// Set one layer's activation quantization.
    pub fn set_act_quant(&mut self, layer: &str, q: ActQuant) {
        self.act_quant.insert(layer.to_string(), q);
    }

    fn layer_weights(&self, name: &str) -> Result<&LayerWeights> {
        self.weights
            .get(name)
            .ok_or_else(|| anyhow!("engine missing weights for {name}"))
    }

    /// Run one layer on one image (no relu). Returns (C,H,W) output and
    /// fills `timing` when given.
    fn run_layer(
        &self,
        l: &LayerTopo,
        x: &[f32],
        timing: Option<&mut LayerTiming>,
    ) -> Result<Vec<f32>> {
        let lw = self.layer_weights(&l.name)?;
        let aq = self.act_quant.get(&l.name).unwrap_or(&ActQuant::None);
        if l.kind == "fc" {
            // GAP + matmul; the "patches" are the C-vector (R = ic, k2 = 1).
            let (c, h, w) = l.in_chw;
            let mut v = vec![0.0f32; c];
            if l.gap_input && h * w > 1 {
                for ci in 0..c {
                    let plane = &x[ci * h * w..(ci + 1) * h * w];
                    v[ci] = plane.iter().sum::<f32>() / (h * w) as f32;
                }
            } else {
                v.copy_from_slice(&x[..c]);
            }
            let mut scratch = Vec::new();
            aq.apply(&mut v, 1, &mut scratch);
            let mut out = vec![0.0f32; l.oc];
            for o in 0..l.oc {
                let wrow = &lw.w[o * c..(o + 1) * c];
                out[o] = wrow.iter().zip(&v).map(|(a, b)| a * b).sum::<f32>() + lw.b[o];
            }
            return Ok(out);
        }
        let (_, ho, wo) = l.out_chw;
        let np = ho * wo;
        let mut patches = vec![0.0f32; np * l.rows];
        let k2 = l.k2();
        let mut scratch = Vec::new();
        let t0 = Instant::now();
        match (self.fusion, matches!(aq, ActQuant::None)) {
            (_, true) => im2col::extract(l, x, &mut patches),
            (FusionMode::Fused, false) => {
                im2col::extract_fused(l, x, &mut patches, |col| aq.apply(col, k2, &mut scratch))
            }
            (FusionMode::Unfused, false) => {
                im2col::extract(l, x, &mut patches);
                for p in 0..np {
                    aq.apply(&mut patches[p * l.rows..(p + 1) * l.rows], k2, &mut scratch);
                }
            }
        }
        let t_im2col = t0.elapsed();
        let mut out = vec![0.0f32; l.oc * np];
        let t1 = Instant::now();
        im2col::gemm(l, &lw.w, &lw.b, &patches, &mut out);
        if let Some(t) = timing {
            t.layer = l.name.clone();
            t.im2col_quant_us = t_im2col.as_secs_f64() * 1e6;
            t.gemm_us = t1.elapsed().as_secs_f64() * 1e6;
        }
        Ok(out)
    }

    /// Forward one image (C,H,W) -> logits. Optionally capture every
    /// layer's *input* feature map into `taps` (for Fig. 2 profiling).
    pub fn forward(
        &self,
        image: &[f32],
        mut taps: Option<&mut HashMap<String, Tensor>>,
    ) -> Result<Vec<f32>> {
        let mut h = image.to_vec();
        for blk in &self.topo.blocks {
            let block_input = h.clone();
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                if let Some(t) = taps.as_deref_mut() {
                    t.insert(
                        l.name.clone(),
                        Tensor::new(vec![l.in_chw.0, l.in_chw.1, l.in_chw.2], h.clone())?,
                    );
                }
                let mut out = self.run_layer(l, &h, None)?;
                let is_last = i == main.len() - 1;
                let defer_relu = is_last && blk.residual;
                if l.relu && !defer_relu {
                    for v in &mut out {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                h = out;
            }
            if blk.residual {
                let skip = if let Some(ds) = blk.downsample_layer() {
                    if let Some(t) = taps.as_deref_mut() {
                        t.insert(
                            ds.name.clone(),
                            Tensor::new(
                                vec![ds.in_chw.0, ds.in_chw.1, ds.in_chw.2],
                                block_input.clone(),
                            )?,
                        );
                    }
                    self.run_layer(ds, &block_input, None)?
                } else {
                    block_input
                };
                for (a, b) in h.iter_mut().zip(&skip) {
                    *a += b;
                    if *a < 0.0 {
                        *a = 0.0;
                    }
                }
            }
        }
        Ok(h)
    }

    /// Forward one image, timing each conv layer (Figure 3).
    pub fn forward_timed(&self, image: &[f32]) -> Result<Vec<LayerTiming>> {
        let mut h = image.to_vec();
        let mut timings = Vec::new();
        for blk in &self.topo.blocks {
            let block_input = h.clone();
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                let mut t = LayerTiming {
                    layer: String::new(),
                    im2col_quant_us: 0.0,
                    gemm_us: 0.0,
                };
                let mut out = self.run_layer(l, &h, Some(&mut t))?;
                if l.kind == "conv" {
                    timings.push(t);
                }
                let is_last = i == main.len() - 1;
                if l.relu && !(is_last && blk.residual) {
                    for v in &mut out {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                h = out;
            }
            if blk.residual {
                let skip = if let Some(ds) = blk.downsample_layer() {
                    self.run_layer(ds, &block_input, None)?
                } else {
                    block_input
                };
                for (a, b) in h.iter_mut().zip(&skip) {
                    *a += b;
                    if *a < 0.0 {
                        *a = 0.0;
                    }
                }
            }
        }
        Ok(timings)
    }

    /// Batch forward -> argmax class per image.
    pub fn classify_batch(&self, images: &[&[f32]]) -> Result<Vec<usize>> {
        images
            .iter()
            .map(|img| {
                let logits = self.forward(img, None)?;
                Ok(logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap())
            })
            .collect()
    }
}
