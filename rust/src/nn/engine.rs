//! Model execution over the pure-Rust im2col engine.
//!
//! The engine runs a whole model (or a single layer, for profiling) with
//! per-layer activation-quantization hooks. It is used for:
//!   * Table 1 (A-rounding vs nearest, W32A2) — `ActQuant::ARound`,
//!   * Figure 3 (latency breakdown: fused vs unfused border) — `forward_timed`,
//!   * the serving example (quantized inference without PJRT).

use std::collections::HashMap;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::im2col;
use super::kernels;
use super::pool::{IntraCtx, IntraWait};
use super::topology::{LayerTopo, ModelTopo};
use crate::quant::arounding::around_column;
use crate::quant::border::BorderFn;
use crate::quant::tensor::Tensor;

/// `unwrap_or(&ActQuant::None)` can't borrow a temporary (`ActQuant`
/// has drop glue, so the unit variant is not const-promotable); this
/// static is the layer default.
static ACT_NONE: ActQuant = ActQuant::None;

/// Activation quantization applied to each im2col column of a layer.
#[derive(Debug, Clone)]
pub enum ActQuant {
    /// Full precision.
    None,
    /// Border-function quantization (nearest when params are zero /
    /// border_en = false).
    Border {
        border: BorderFn,
        s: f32,
        qmin: f32,
        qmax: f32,
    },
    /// The SQuant-style flip algorithm (Table 1's A-rounding).
    ARound { s: f32, qmin: f32, qmax: f32 },
}

impl ActQuant {
    fn apply(&self, col: &mut [f32], k2: usize, scratch: &mut Vec<f32>) {
        match self {
            ActQuant::None => {}
            ActQuant::Border {
                border,
                s,
                qmin,
                qmax,
            } => border.quant_column(col, *s, *qmin, *qmax, scratch),
            ActQuant::ARound { s, qmin, qmax } => around_column(col, *s, *qmin, *qmax, k2),
        }
    }
}

/// One layer's (possibly pre-quantized) weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

/// Border-fusion strategy for the conv loop (Figure 3's configurations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusionMode {
    /// Quantize each column inside the im2col gather (hot in cache).
    Fused,
    /// Gather everything, then a second quantization pass over the buffer.
    Unfused,
}

/// The inference engine: topology + weights + per-layer activation quant.
pub struct Engine {
    pub topo: ModelTopo,
    pub weights: HashMap<String, LayerWeights>,
    pub act_quant: HashMap<String, ActQuant>,
    pub fusion: FusionMode,
    /// Per-conv-layer B-panel weight packs for the tiled GEMM, built
    /// once (`ensure_packed` — `ModelRegistry` calls it at registration
    /// so the pack cost is off the serving path; bare `forward` users
    /// get it lazily on first use). Packed from the weights as they
    /// were at that moment — mutate `weights` only before first use.
    packed: OnceLock<HashMap<String, im2col::PackedGemm>>,
}

/// Reusable buffers for the allocation-free forward path. One scratch per
/// worker thread: after the first image every buffer is reused, so the
/// serving hot loop does no per-image allocation (ISSUE 2 / the paper's
/// runtime-overhead claim depends on the border staying cheap online).
///
/// A scratch is model-agnostic: no buffer carries an exact-size
/// assumption, so the same scratch serves engines of different shapes
/// back to back (multi-model serving shares one worker pool). The
/// activation buffers (`h`/`out`/`block_in`/`skip`) track semantic
/// lengths via `resize`; the pure work buffers (`patches`/`quant`) only
/// ever grow, and every user slices exactly the region it overwrites.
#[derive(Debug, Default)]
pub struct EngineScratch {
    /// Current activation (ping) and next layer's output (pong).
    h: Vec<f32>,
    out: Vec<f32>,
    /// Residual block input, retained for the skip path.
    block_in: Vec<f32>,
    /// Downsample-projection output.
    skip: Vec<f32>,
    /// im2col patch buffer (grow-only; sized to the largest layer seen).
    patches: Vec<f32>,
    /// Packed-A scratch for the tiled GEMM (grow-only; the patch buffer
    /// re-laid out in KC strips per conv group by `im2col::pack_patches`).
    apanel: Vec<f32>,
    /// Border-function scratch (grow-only; 2·R for the fused-border pass).
    /// `pub(crate)` so pool workers can lend it to intra-image helper
    /// chunks without a fresh allocation.
    pub(crate) quant: Vec<f32>,
    /// When set (pool workers with intra-image parallelism enabled),
    /// conv layers big enough to clear the threshold shard their gather
    /// and GEMM phases across idle pool workers. `None` (the default)
    /// keeps the forward pass single-threaded.
    pub(crate) intra: Option<IntraCtx>,
}

impl EngineScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Scratch with capacity pre-reserved for `dims` (typically the
    /// max-dims union over a model registry), so a worker serving
    /// heterogeneous models never reallocates on the hot path, not even
    /// on its first image of the largest model.
    pub fn with_dims(dims: ScratchDims) -> Self {
        EngineScratch {
            h: Vec::with_capacity(dims.acts),
            out: Vec::with_capacity(dims.acts),
            block_in: Vec::with_capacity(dims.acts),
            skip: Vec::with_capacity(dims.acts),
            patches: Vec::with_capacity(dims.patches),
            apanel: Vec::with_capacity(dims.apanel),
            quant: Vec::with_capacity(dims.quant),
            intra: None,
        }
    }
}

/// One parallel phase of a conv layer, executed chunk-wise by the
/// submitting pool worker plus any idle helpers (see
/// [`crate::nn::pool::IntraTask`]). Chunks are disjoint ranges of
/// output pixels (gather) or B-panel tile strips (GEMM — whole panels,
/// which map to disjoint output-channel ranges), so each executor
/// reconstructs a non-aliasing slice from the raw base pointers.
///
/// Safety contract: the pointers reference the submitting worker's
/// scratch and the engine it is running; the submitter blocks
/// ([`IntraWait`]) until every *claimed* chunk completes before those
/// borrows end, and a late helper that finds the chunk cursor exhausted
/// never dereferences the pointers at all.
pub(crate) enum IntraOp {
    /// im2col gather over output-pixel chunks, with the column
    /// activation quant applied fused (inside the gather hook) or as a
    /// per-chunk second pass.
    Gather {
        layer: *const LayerTopo,
        aq: *const ActQuant,
        fused: bool,
        x: *const f32,
        x_len: usize,
        /// Base of the FULL (P·R) patch buffer; chunk c takes
        /// `[p0·R, p1·R)`.
        patches: *mut f32,
        np: usize,
    },
    /// Tiled GEMM over B-panel chunks: chunk c covers panel range
    /// `[t0, t1)`, i.e. output channels `[panel_channel(t0),
    /// panel_channel(t1))` — helpers always operate on whole panels, so
    /// no register tile is ever split across executors.
    Gemm {
        layer: *const LayerTopo,
        /// The engine's cached B-panel pack (address stable: it lives in
        /// the engine's `OnceLock`, and the submitter holds `&Engine`).
        packed: *const im2col::PackedGemm,
        bias: *const f32,
        bias_len: usize,
        /// Packed-A scratch, fully written by the submitter *before*
        /// spawning, then shared read-only by every chunk executor.
        apanel: *const f32,
        apanel_len: usize,
        /// Base of the FULL (oc·P) output buffer; chunk c takes
        /// `[o0·P, o1·P)`.
        out: *mut f32,
    },
}

// The raw pointers are only dereferenced while the submitting worker
// blocks on task completion (see the safety contract above).
unsafe impl Send for IntraOp {}
unsafe impl Sync for IntraOp {}

/// Even split of `n` items into `chunks` ranges: chunk `ci` covers
/// `[ci·n/chunks, (ci+1)·n/chunks)`.
#[inline]
fn chunk_range(ci: usize, chunks: usize, n: usize) -> (usize, usize) {
    (ci * n / chunks, (ci + 1) * n / chunks)
}

impl IntraOp {
    /// Run chunk `ci` of `chunks`. `quant` is the *executor's* border
    /// scratch (caller and helpers each bring their own), so the fused
    /// quant hook stays allocation-free on every thread.
    pub(crate) fn run_chunk(&self, ci: usize, chunks: usize, quant: &mut Vec<f32>) {
        match self {
            // SAFETY: the raw pointers reference the submitting worker's
            // borrows, which outlive every claimed chunk (the submitter
            // blocks on IntraWait); chunk ranges are disjoint, so the
            // `&mut` patch slice reconstructed here never aliases
            // another executor's.
            IntraOp::Gather {
                layer,
                aq,
                fused,
                x,
                x_len,
                patches,
                np,
            } => unsafe {
                let l = &**layer;
                let aq = &**aq;
                let x = std::slice::from_raw_parts(*x, *x_len);
                let (p0, p1) = chunk_range(ci, chunks, *np);
                if p0 == p1 {
                    return;
                }
                let r = l.rows;
                let out = std::slice::from_raw_parts_mut(patches.add(p0 * r), (p1 - p0) * r);
                let k2 = l.k2();
                // `ActQuant::None.apply` is a no-op, so the unfused arm
                // covers it — only a real quant wants the fused hook.
                if *fused && !matches!(aq, ActQuant::None) {
                    im2col::extract_range(l, x, out, p0, p1, |col| aq.apply(col, k2, quant));
                } else {
                    im2col::extract_range(l, x, out, p0, p1, |_col| {});
                    for p in 0..p1 - p0 {
                        aq.apply(&mut out[p * r..(p + 1) * r], k2, quant);
                    }
                }
            },
            // SAFETY: same pointer contract as Gather; `packed` points
            // into the engine's OnceLock (stable while the submitter's
            // `&Engine` borrow lives), `apanel` is read-only here, and
            // panel ranges map to disjoint output-channel row slices.
            IntraOp::Gemm {
                layer,
                packed,
                bias,
                bias_len,
                apanel,
                apanel_len,
                out,
            } => unsafe {
                let l = &**layer;
                let pg = &**packed;
                let bias = std::slice::from_raw_parts(*bias, *bias_len);
                let ap = std::slice::from_raw_parts(*apanel, *apanel_len);
                let (_, ho, wo) = l.out_chw;
                let np = ho * wo;
                let nt = im2col::n_panels(l);
                let (t0, t1) = chunk_range(ci, chunks, nt);
                if t0 == t1 {
                    return;
                }
                let o0 = im2col::panel_channel(l, t0);
                let o1 = im2col::panel_channel(l, t1);
                let orows = std::slice::from_raw_parts_mut(out.add(o0 * np), (o1 - o0) * np);
                im2col::gemm_panels(l, pg, bias, ap, orows, t0, t1);
            },
        }
    }
}

/// Worst-case buffer sizes (in f32 elements) an [`EngineScratch`] needs
/// to run a model allocation-free. Unions over several engines give the
/// shared-pool sizing for multi-model serving.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScratchDims {
    /// Largest activation map (max over layers of in/out C·H·W).
    pub acts: usize,
    /// Largest im2col patch buffer (conv: P·R; fc: pooled C).
    pub patches: usize,
    /// Largest packed-A GEMM scratch (conv layers only: P·R).
    pub apanel: usize,
    /// Largest border scratch (2·R for the fused segment pass).
    pub quant: usize,
}

impl ScratchDims {
    /// Element-wise max of two requirements.
    pub fn union(self, other: ScratchDims) -> ScratchDims {
        ScratchDims {
            acts: self.acts.max(other.acts),
            patches: self.patches.max(other.patches),
            apanel: self.apanel.max(other.apanel),
            quant: self.quant.max(other.quant),
        }
    }
}

/// Grow-only view of a scratch buffer: extends the backing Vec when the
/// request exceeds it, never shrinks, and hands back exactly the `n`
/// elements the caller will overwrite. This is what lets one scratch
/// serve models of different dims without per-model length bookkeeping.
fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

/// Per-layer timing sample from `forward_timed`.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub layer: String,
    pub im2col_quant_us: f64,
    pub gemm_us: f64,
}

impl Engine {
    pub fn new(topo: ModelTopo, weights: HashMap<String, LayerWeights>) -> Self {
        Engine {
            topo,
            weights,
            act_quant: HashMap::new(),
            fusion: FusionMode::Fused,
            packed: OnceLock::new(),
        }
    }

    /// Build the per-conv-layer B-panel weight packs (idempotent).
    /// `ModelRegistry` calls this at registration so the one-time
    /// O(oc·rg) pack never runs on the serving path; `packed_for` calls
    /// it lazily for bare `forward` users.
    pub fn ensure_packed(&self) {
        self.packed.get_or_init(|| {
            let mut map = HashMap::new();
            for l in self.topo.all_layers() {
                if l.kind != "conv" {
                    continue;
                }
                if let Some(lw) = self.weights.get(&l.name) {
                    map.insert(l.name.clone(), im2col::pack_weights(l, &lw.w));
                }
            }
            map
        });
    }

    fn packed_for(&self, l: &LayerTopo) -> Result<&im2col::PackedGemm> {
        self.ensure_packed();
        self.packed
            .get()
            .and_then(|m| m.get(&l.name))
            .ok_or_else(|| anyhow!("engine missing packed weights for {}", l.name))
    }

    /// Set one layer's activation quantization.
    pub fn set_act_quant(&mut self, layer: &str, q: ActQuant) {
        self.act_quant.insert(layer.to_string(), q);
    }

    fn layer_weights(&self, name: &str) -> Result<&LayerWeights> {
        self.weights
            .get(name)
            .ok_or_else(|| anyhow!("engine missing weights for {name}"))
    }

    /// Run one layer on one image (no relu). Returns (C,H,W) output and
    /// fills `timing` when given. Thin allocating wrapper over
    /// [`Engine::run_layer_into`], so there is exactly one copy of the
    /// layer math regardless of buffer strategy.
    fn run_layer(
        &self,
        l: &LayerTopo,
        x: &[f32],
        timing: Option<&mut LayerTiming>,
    ) -> Result<Vec<f32>> {
        let (mut out, mut patches, mut apanel, mut scratch) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        self.run_layer_into(l, x, &mut out, &mut patches, &mut apanel, &mut scratch, timing, None)?;
        Ok(out)
    }

    /// Run one layer writing into caller-owned buffers (the serving hot
    /// path reuses them via [`EngineScratch`]). Every element of `out`
    /// (and of the reused `patches` region) is overwritten, so buffers
    /// carry no state between calls. Timing clock reads only happen when
    /// `timing` is given, keeping the hot loop clean.
    ///
    /// When `intra` is set and the layer clears the work threshold, the
    /// gather and GEMM phases are split into chunks claimed by this
    /// thread plus any idle pool workers; the phases are still barriers
    /// (GEMM starts only after every gather chunk completed), so the
    /// result is bit-identical to the sequential path for any chunk
    /// count — pinned by the pool property tests.
    #[allow(clippy::too_many_arguments)]
    fn run_layer_into(
        &self,
        l: &LayerTopo,
        x: &[f32],
        out: &mut Vec<f32>,
        patches: &mut Vec<f32>,
        apanel: &mut Vec<f32>,
        quant_scratch: &mut Vec<f32>,
        timing: Option<&mut LayerTiming>,
        intra: Option<&IntraCtx>,
    ) -> Result<()> {
        let lw = self.layer_weights(&l.name)?;
        let aq = self.act_quant.get(&l.name).unwrap_or(&ACT_NONE);
        if l.kind == "fc" {
            // GAP + matmul; `patches` doubles as the pooled C-vector.
            let (c, h, w) = l.in_chw;
            let v = grow(patches, c);
            if l.gap_input && h * w > 1 {
                for ci in 0..c {
                    let plane = &x[ci * h * w..(ci + 1) * h * w];
                    v[ci] = plane.iter().sum::<f32>() / (h * w) as f32;
                }
            } else {
                v.copy_from_slice(&x[..c]);
            }
            aq.apply(v, 1, quant_scratch);
            out.resize(l.oc, 0.0);
            for o in 0..l.oc {
                let wrow = &lw.w[o * c..(o + 1) * c];
                out[o] = kernels::dot(wrow, v) + lw.b[o];
            }
            return Ok(());
        }
        let (_, ho, wo) = l.out_chw;
        let np = ho * wo;
        let patches = grow(patches, np * l.rows);
        let k2 = l.k2();
        // Shard only when the layer is big enough for the fan-out to
        // pay for itself (helper wake-ups + the two phase barriers).
        let intra = intra.filter(|c| c.split > 1 && np * l.rows >= c.min_elems);
        let t0 = timing.is_some().then(Instant::now);
        match intra {
            None => match (self.fusion, matches!(aq, ActQuant::None)) {
                (_, true) => im2col::extract(l, x, patches),
                (FusionMode::Fused, false) => {
                    im2col::extract_fused(l, x, patches, |col| aq.apply(col, k2, quant_scratch))
                }
                (FusionMode::Unfused, false) => {
                    im2col::extract(l, x, patches);
                    for p in 0..np {
                        aq.apply(&mut patches[p * l.rows..(p + 1) * l.rows], k2, quant_scratch);
                    }
                }
            },
            Some(ctx) => {
                let chunks = ctx.split.min(np);
                let task = ctx.spawn(
                    IntraOp::Gather {
                        layer: l,
                        aq,
                        fused: self.fusion == FusionMode::Fused,
                        x: x.as_ptr(),
                        x_len: x.len(),
                        patches: patches.as_mut_ptr(),
                        np,
                    },
                    chunks,
                );
                // The wait guard quiesces helpers even if a chunk
                // panics on this thread (the borrows behind the raw
                // pointers must outlive every claimed chunk).
                let wait = IntraWait::new(&task);
                task.execute(quant_scratch);
                if wait.finish() {
                    return Err(anyhow!("intra-image gather helper panicked"));
                }
            }
        }
        let t_im2col = t0.map(|t| t.elapsed());
        out.resize(l.oc * np, 0.0);
        let t1 = timing.is_some().then(Instant::now);
        // Repack the gathered patches into A-panel strip layout (serial
        // — a pure copy the submitter does once), then tile over the
        // engine's cached B panels. Bit-identical to the old
        // dot-per-row `gemm` in the default exact mode.
        let apanel = grow(apanel, np * l.rows);
        im2col::pack_patches(l, patches, apanel);
        let pg = self.packed_for(l)?;
        let nt = im2col::n_panels(l);
        match intra {
            None => im2col::gemm_panels(l, pg, &lw.b, apanel, out, 0, nt),
            Some(ctx) => {
                let chunks = ctx.split.min(nt);
                let task = ctx.spawn(
                    IntraOp::Gemm {
                        layer: l,
                        packed: pg,
                        bias: lw.b.as_ptr(),
                        bias_len: lw.b.len(),
                        apanel: apanel.as_ptr(),
                        apanel_len: apanel.len(),
                        out: out.as_mut_ptr(),
                    },
                    chunks,
                );
                let wait = IntraWait::new(&task);
                task.execute(quant_scratch);
                if wait.finish() {
                    return Err(anyhow!("intra-image gemm helper panicked"));
                }
            }
        }
        if let Some(t) = timing {
            t.layer = l.name.clone();
            t.im2col_quant_us = t_im2col.unwrap().as_secs_f64() * 1e6;
            t.gemm_us = t1.unwrap().elapsed().as_secs_f64() * 1e6;
        }
        Ok(())
    }

    /// Forward one image through reusable buffers; returns the logits as
    /// a view into `scratch`. Bit-identical to `forward(image, None)` —
    /// asserted by the engine property tests — but allocation-free after
    /// the first call.
    ///
    /// Deliberately NOT merged with [`Engine::forward`]: the allocating
    /// walk is kept as an independent implementation so the
    /// `forward_scratch == forward` differential property test
    /// (rust/tests/pool_props.rs) actually tests the buffer-reuse
    /// orchestration instead of comparing a function to itself. Any
    /// change to the block walk must be applied to both (the layer math
    /// itself is shared via `run_layer_into`).
    pub fn forward_scratch<'a>(
        &self,
        image: &[f32],
        scratch: &'a mut EngineScratch,
    ) -> Result<&'a [f32]> {
        let s = scratch;
        s.h.clear();
        s.h.extend_from_slice(image);
        for blk in &self.topo.blocks {
            if blk.residual {
                s.block_in.clear();
                s.block_in.extend_from_slice(&s.h);
            }
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                self.run_layer_into(
                    l,
                    &s.h,
                    &mut s.out,
                    &mut s.patches,
                    &mut s.apanel,
                    &mut s.quant,
                    None,
                    s.intra.as_ref(),
                )?;
                let is_last = i == main.len() - 1;
                let defer_relu = is_last && blk.residual;
                if l.relu && !defer_relu {
                    for v in &mut s.out {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                std::mem::swap(&mut s.h, &mut s.out);
            }
            if blk.residual {
                if let Some(ds) = blk.downsample_layer() {
                    self.run_layer_into(
                        ds,
                        &s.block_in,
                        &mut s.skip,
                        &mut s.patches,
                        &mut s.apanel,
                        &mut s.quant,
                        None,
                        s.intra.as_ref(),
                    )?;
                    for (a, b) in s.h.iter_mut().zip(&s.skip) {
                        *a += b;
                        if *a < 0.0 {
                            *a = 0.0;
                        }
                    }
                } else {
                    for (a, b) in s.h.iter_mut().zip(&s.block_in) {
                        *a += b;
                        if *a < 0.0 {
                            *a = 0.0;
                        }
                    }
                }
            }
        }
        Ok(&s.h)
    }

    /// Argmax class for one image via the scratch path.
    pub fn classify_scratch(&self, image: &[f32], scratch: &mut EngineScratch) -> Result<usize> {
        let logits = self.forward_scratch(image, scratch)?;
        Ok(argmax(logits))
    }

    /// Forward one image (C,H,W) -> logits. Optionally capture every
    /// layer's *input* feature map into `taps` (for Fig. 2 profiling).
    pub fn forward(
        &self,
        image: &[f32],
        mut taps: Option<&mut HashMap<String, Tensor>>,
    ) -> Result<Vec<f32>> {
        let mut h = image.to_vec();
        for blk in &self.topo.blocks {
            let block_input = h.clone();
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                if let Some(t) = taps.as_deref_mut() {
                    t.insert(
                        l.name.clone(),
                        Tensor::new(vec![l.in_chw.0, l.in_chw.1, l.in_chw.2], h.clone())?,
                    );
                }
                let mut out = self.run_layer(l, &h, None)?;
                let is_last = i == main.len() - 1;
                let defer_relu = is_last && blk.residual;
                if l.relu && !defer_relu {
                    for v in &mut out {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                h = out;
            }
            if blk.residual {
                let skip = if let Some(ds) = blk.downsample_layer() {
                    if let Some(t) = taps.as_deref_mut() {
                        t.insert(
                            ds.name.clone(),
                            Tensor::new(
                                vec![ds.in_chw.0, ds.in_chw.1, ds.in_chw.2],
                                block_input.clone(),
                            )?,
                        );
                    }
                    self.run_layer(ds, &block_input, None)?
                } else {
                    block_input
                };
                for (a, b) in h.iter_mut().zip(&skip) {
                    *a += b;
                    if *a < 0.0 {
                        *a = 0.0;
                    }
                }
            }
        }
        Ok(h)
    }

    /// Forward one image, timing each conv layer (Figure 3).
    pub fn forward_timed(&self, image: &[f32]) -> Result<Vec<LayerTiming>> {
        let mut h = image.to_vec();
        let mut timings = Vec::new();
        for blk in &self.topo.blocks {
            let block_input = h.clone();
            let main: Vec<&LayerTopo> = blk.main_layers().collect();
            for (i, l) in main.iter().enumerate() {
                let mut t = LayerTiming {
                    layer: String::new(),
                    im2col_quant_us: 0.0,
                    gemm_us: 0.0,
                };
                let mut out = self.run_layer(l, &h, Some(&mut t))?;
                if l.kind == "conv" {
                    timings.push(t);
                }
                let is_last = i == main.len() - 1;
                if l.relu && !(is_last && blk.residual) {
                    for v in &mut out {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                h = out;
            }
            if blk.residual {
                let skip = if let Some(ds) = blk.downsample_layer() {
                    self.run_layer(ds, &block_input, None)?
                } else {
                    block_input
                };
                for (a, b) in h.iter_mut().zip(&skip) {
                    *a += b;
                    if *a < 0.0 {
                        *a = 0.0;
                    }
                }
            }
        }
        Ok(timings)
    }

    /// Batch forward -> argmax class per image. Sequential reference
    /// implementation: one scratch reused across the batch, so this is
    /// the same per-image code path [`crate::nn::pool::InferencePool`] shards
    /// across workers (which is what makes pooled results bit-identical).
    pub fn classify_batch(&self, images: &[&[f32]]) -> Result<Vec<usize>> {
        let mut scratch = EngineScratch::default();
        images
            .iter()
            .map(|img| self.classify_scratch(img, &mut scratch))
            .collect()
    }

    /// Expected f32 elements per input image (C·H·W).
    pub fn img_elems(&self) -> usize {
        let (h, w) = self.topo.in_hw;
        self.topo.in_c * h * w
    }

    /// Worst-case scratch sizes for running this model allocation-free.
    pub fn scratch_dims(&self) -> ScratchDims {
        let mut d = ScratchDims {
            acts: self.img_elems(),
            ..ScratchDims::default()
        };
        for l in self.topo.all_layers() {
            let (ic, ih, iw) = l.in_chw;
            let (oc, oh, ow) = l.out_chw;
            d.acts = d.acts.max(ic * ih * iw).max(oc * oh * ow);
            let patches = if l.kind == "fc" { ic } else { oh * ow * l.rows };
            d.patches = d.patches.max(patches);
            if l.kind != "fc" {
                d.apanel = d.apanel.max(oh * ow * l.rows);
            }
            d.quant = d.quant.max(2 * l.rows);
        }
        d
    }

    /// Check one layer's internal consistency *before* any arithmetic
    /// that could divide by zero or index out of bounds: fields like
    /// `rows` and `groups` come straight from manifest JSON, and the
    /// im2col/gemm hot loops trust them (`col[c·k²..]` slicing, grouped
    /// row ranges), so a bad value must be a load-time error.
    fn validate_layer(&self, l: &LayerTopo) -> Result<()> {
        let t = &self.topo.name;
        if l.kind != "conv" && l.kind != "fc" {
            return Err(anyhow!("model {t}: layer {} has unknown kind {:?}", l.name, l.kind));
        }
        if l.ic == 0 || l.oc == 0 || l.k == 0 || l.stride == 0 || l.groups == 0 {
            return Err(anyhow!(
                "model {t}: layer {} has zero dim (ic {} oc {} k {} stride {} groups {})",
                l.name, l.ic, l.oc, l.k, l.stride, l.groups
            ));
        }
        if l.ic % l.groups != 0 || l.oc % l.groups != 0 {
            return Err(anyhow!(
                "model {t}: layer {} groups {} must divide ic {} and oc {}",
                l.name, l.groups, l.ic, l.oc
            ));
        }
        // im2col assumes col length == rows == ic·k² (rows == ic for fc,
        // where k2() is 1); a smaller `rows` slices out of range, a
        // larger one feeds gemm stale scratch.
        if l.rows != l.ic * l.k2() {
            return Err(anyhow!(
                "model {t}: layer {} rows {} != ic {} x k2 {}",
                l.name, l.rows, l.ic, l.k2()
            ));
        }
        if l.in_chw.0 != l.ic || l.out_chw.0 != l.oc {
            return Err(anyhow!(
                "model {t}: layer {} channel fields disagree (ic {} in_chw {:?}, oc {} out_chw {:?})",
                l.name, l.ic, l.in_chw, l.oc, l.out_chw
            ));
        }
        if l.kind == "conv" {
            // out dims must match the conv arithmetic the extractor's
            // bounds checks are built around (checked_sub: a kernel
            // larger than the padded input is an error, not underflow)
            let (_, h, w) = l.in_chw;
            let ho = (h + 2 * l.pad)
                .checked_sub(l.k)
                .map(|d| d / l.stride + 1)
                .ok_or_else(|| {
                    anyhow!("model {t}: layer {} kernel {} exceeds padded input", l.name, l.k)
                })?;
            let wo = (w + 2 * l.pad)
                .checked_sub(l.k)
                .map(|d| d / l.stride + 1)
                .ok_or_else(|| {
                    anyhow!("model {t}: layer {} kernel {} exceeds padded input", l.name, l.k)
                })?;
            if l.out_chw != (l.oc, ho, wo) {
                return Err(anyhow!(
                    "model {t}: layer {} out_chw {:?} != computed ({}, {ho}, {wo})",
                    l.name, l.out_chw, l.oc
                ));
            }
        }
        let lw = self.layer_weights(&l.name)?;
        if lw.w.len() != l.weight_elems() || lw.b.len() != l.oc {
            return Err(anyhow!(
                "model {t}: layer {} weights {}x{} want {}x{}",
                l.name, lw.w.len(), lw.b.len(), l.weight_elems(), l.oc
            ));
        }
        Ok(())
    }

    /// Check the topology chains and every layer is internally
    /// consistent with weights of the right shape. Registry
    /// construction runs this up front so a malformed model is a
    /// load-time error, not a mid-request panic in a shared pool worker.
    pub fn validate(&self) -> Result<()> {
        let t = &self.topo;
        if t.blocks.is_empty() || t.n_classes == 0 || self.img_elems() == 0 {
            return Err(anyhow!("model {}: empty topology", t.name));
        }
        let mut chw = (t.in_c, t.in_hw.0, t.in_hw.1);
        for blk in &t.blocks {
            let block_in = chw;
            let mut cur = chw;
            for l in blk.main_layers() {
                if l.in_chw != cur {
                    return Err(anyhow!(
                        "model {}: layer {} expects input {:?} but gets {:?}",
                        t.name, l.name, l.in_chw, cur
                    ));
                }
                cur = l.out_chw;
            }
            if let Some(ds) = blk.downsample_layer() {
                if ds.in_chw != block_in || ds.out_chw != cur {
                    return Err(anyhow!(
                        "model {}: downsample {} must project {:?} -> {:?}",
                        t.name, ds.name, block_in, cur
                    ));
                }
            } else if blk.residual && cur != block_in {
                return Err(anyhow!(
                    "model {}: identity-skip block {} changes shape {:?} -> {:?}",
                    t.name, blk.name, block_in, cur
                ));
            }
            for l in &blk.layers {
                self.validate_layer(l)?;
            }
            chw = cur;
        }
        if chw.0 * chw.1 * chw.2 != t.n_classes {
            return Err(anyhow!(
                "model {}: head emits {:?}, want {} classes",
                t.name, chw, t.n_classes
            ));
        }
        Ok(())
    }
}

/// Index of the max logit. Total ordering (`f32::total_cmp`) so NaN in
/// a hostile request payload yields *some* class instead of panicking —
/// a panic here would kill a long-lived pool worker, turning one bad
/// request into whole-service degradation. Ties keep the last maximum,
/// matching the `max_by(partial_cmp)` idiom this replaced for all
/// non-NaN inputs except the exotic signed-zero tie (`total_cmp` orders
/// -0.0 < +0.0 where `partial_cmp` called them equal). Shared with the
/// eval/coordinator argmax sites.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}
