//! Load topologies, folded FP weights, and qinit tensors from the
//! artifacts directory (manifest `meta.models` / `meta.weights` /
//! `meta.qinit` sections).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use super::engine::LayerWeights;
use super::topology::ModelTopo;
use crate::runtime::Manifest;
use crate::util::tensor_io;

/// Parse a model's topology from the manifest.
pub fn load_topology(manifest: &Manifest, model: &str) -> Result<ModelTopo> {
    let j = manifest.meta_section("models")?.req(model)?;
    ModelTopo::from_json(j)
}

/// Load a model's folded FP weights.
pub fn load_weights(
    artifacts_dir: &Path,
    manifest: &Manifest,
    model: &str,
) -> Result<HashMap<String, LayerWeights>> {
    let meta = manifest.meta_section("weights")?.req(model)?;
    let topo = load_topology(manifest, model)?;
    let mut out = HashMap::new();
    for l in topo.all_layers() {
        let m = meta.req(&l.name)?;
        let w = tensor_io::read_f32_exact(
            &artifacts_dir.join(m.req("w")?.as_str().unwrap()),
            l.weight_elems(),
        )?;
        let b = tensor_io::read_f32_exact(
            &artifacts_dir.join(m.req("b")?.as_str().unwrap()),
            l.oc,
        )?;
        out.insert(l.name.clone(), LayerWeights { w, b });
    }
    Ok(out)
}

/// Load a model's per-bit-width weight-quantization init (s_w, V).
pub fn load_qinit(
    artifacts_dir: &Path,
    manifest: &Manifest,
    model: &str,
    layer: &str,
    wbits: u32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let meta = manifest
        .meta_section("qinit")?
        .req(model)?
        .req(&wbits.to_string())?
        .req(layer)?;
    let topo = load_topology(manifest, model)?;
    let l = topo.layer(layer)?;
    let s_w = tensor_io::read_f32_exact(
        &artifacts_dir.join(meta.req("s_w")?.as_str().unwrap()),
        l.oc,
    )?;
    let v = tensor_io::read_f32_exact(
        &artifacts_dir.join(meta.req("V")?.as_str().unwrap()),
        l.weight_elems(),
    )?;
    Ok((s_w, v))
}

/// FP test accuracy recorded by the trainer (manifest `meta.fp_acc`).
pub fn fp_accuracy(manifest: &Manifest, model: &str) -> Result<f64> {
    manifest
        .meta_section("fp_acc")?
        .req(model)?
        .as_f64()
        .ok_or_else(|| anyhow!("fp_acc not a number"))
}
