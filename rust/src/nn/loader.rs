//! Load topologies, folded FP weights, and qinit tensors from the
//! artifacts directory (manifest `meta.models` / `meta.weights` /
//! `meta.qinit` sections).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::engine::{Engine, LayerWeights};
use super::topology::ModelTopo;
use crate::config::{Method, ModelSource, ModelSpec};
use crate::runtime::Manifest;
use crate::util::tensor_io;

/// Parse a model's topology from the manifest.
pub fn load_topology(manifest: &Manifest, model: &str) -> Result<ModelTopo> {
    let j = manifest.meta_section("models")?.req(model)?;
    ModelTopo::from_json(j)
}

/// Load a model's folded FP weights.
pub fn load_weights(
    artifacts_dir: &Path,
    manifest: &Manifest,
    model: &str,
) -> Result<HashMap<String, LayerWeights>> {
    let meta = manifest.meta_section("weights")?.req(model)?;
    let topo = load_topology(manifest, model)?;
    let mut out = HashMap::new();
    for l in topo.all_layers() {
        let m = meta.req(&l.name)?;
        let w = tensor_io::read_f32_exact(
            &artifacts_dir.join(m.req("w")?.as_str().unwrap()),
            l.weight_elems(),
        )?;
        let b = tensor_io::read_f32_exact(
            &artifacts_dir.join(m.req("b")?.as_str().unwrap()),
            l.oc,
        )?;
        out.insert(l.name.clone(), LayerWeights { w, b });
    }
    Ok(out)
}

/// Load a model's per-bit-width weight-quantization init (s_w, V).
pub fn load_qinit(
    artifacts_dir: &Path,
    manifest: &Manifest,
    model: &str,
    layer: &str,
    wbits: u32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let meta = manifest
        .meta_section("qinit")?
        .req(model)?
        .req(&wbits.to_string())?
        .req(layer)?;
    let topo = load_topology(manifest, model)?;
    let l = topo.layer(layer)?;
    let s_w = tensor_io::read_f32_exact(
        &artifacts_dir.join(meta.req("s_w")?.as_str().unwrap()),
        l.oc,
    )?;
    let v = tensor_io::read_f32_exact(
        &artifacts_dir.join(meta.req("V")?.as_str().unwrap()),
        l.weight_elems(),
    )?;
    Ok((s_w, v))
}

/// Build a full-precision [`Engine`] for one manifest model (topology +
/// folded FP weights, no activation quantization). This is the
/// PJRT-free manifest serving path — `aquant serve` uses it for
/// `MODEL:nearest:W32A32` specs in builds without the `pjrt` feature;
/// quantized engines come from `exp::cell::build_quantized_engine`
/// (calibration needs the runtime). Registry construction
/// ([`crate::nn::registry::ModelRegistry::new`]) validates each engine
/// and sizes shared scratch over whatever mix of loaded and synthetic
/// engines the caller assembles.
pub fn load_engine(artifacts_dir: &Path, manifest: &Manifest, model: &str) -> Result<Engine> {
    let topo = load_topology(manifest, model)
        .with_context(|| format!("loading topology for model {model:?}"))?;
    let weights = load_weights(artifacts_dir, manifest, model)
        .with_context(|| format!("loading weights for model {model:?}"))?;
    Ok(Engine::new(topo, weights))
}

/// Manifest-engine builder for `ModelRegistry::from_specs` in builds
/// without PJRT: manifest specs are served **full-precision** only
/// (`nearest` + W32A32 — without the runtime there is no calibration,
/// so a quantized spec is a configuration error pointing at the `pjrt`
/// feature). Loads `manifest.json` lazily on first use, once.
pub struct FpManifestBuilder {
    artifacts_dir: PathBuf,
    manifest: Option<Manifest>,
}

impl FpManifestBuilder {
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Self {
        FpManifestBuilder {
            artifacts_dir: artifacts_dir.into(),
            manifest: None,
        }
    }

    /// Build the engine for one manifest spec (see type docs).
    pub fn build(&mut self, spec: &ModelSpec) -> Result<Engine> {
        let ModelSource::Manifest {
            model,
            method,
            bits,
        } = &spec.source
        else {
            bail!("spec {:?} is not a manifest model", spec.name);
        };
        if *method != Method::Nearest || bits.w_quantized() || bits.a_quantized() {
            bail!(
                "model spec {:?} ({model} {} {}) needs calibration and the PJRT \
                 runtime; rebuild with `--features pjrt`, serve it full-precision \
                 as {model}:nearest:W32A32, or use synth:...",
                spec.name,
                method.name(),
                bits.name()
            );
        }
        if self.manifest.is_none() {
            self.manifest = Some(Manifest::load(&self.artifacts_dir.join("manifest.json"))?);
        }
        load_engine(
            &self.artifacts_dir,
            self.manifest.as_ref().expect("manifest just loaded"),
            model,
        )
    }
}

/// FP test accuracy recorded by the trainer (manifest `meta.fp_acc`).
pub fn fp_accuracy(manifest: &Manifest, model: &str) -> Result<f64> {
    manifest
        .meta_section("fp_acc")?
        .req(model)?
        .as_f64()
        .ok_or_else(|| anyhow!("fp_acc not a number"))
}
