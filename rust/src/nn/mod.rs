//! Pure-Rust integer inference engine.
//!
//! Mirrors the im2col-conv formulation of the JAX side (verified
//! numerically in integration tests against the PJRT `fp_*`/`q_*`
//! programs) and is the measurable substrate for Figure 3: the border
//! function either **fused into the im2col gather** (the paper's kernel-
//! fusion claim) or run as a separate pass.

pub mod engine;
pub mod im2col;
pub mod kernels;
pub mod loader;
pub mod pool;
pub mod registry;
pub mod synth;
pub mod topology;

pub use engine::{ActQuant, Engine, EngineScratch, LayerWeights, ScratchDims};
pub use pool::InferencePool;
pub use registry::{ModelEntry, ModelRegistry};
pub use topology::{BlockTopo, LayerTopo, ModelTopo};
